"""Poisson-arrival traffic benchmark: goodput under offered-load sweeps.

The other serve benchmarks measure closed-loop capacity (drain a queue as
fast as possible); this one measures the *open-loop* overload behavior
ISSUE 7 added — requests arrive on a Poisson clock the engine does not
control, carry priorities and TTFT/TPOT targets, and the scheduler must
degrade gracefully when the offered load exceeds capacity (skip-ahead
admission, preemption, per-request failure) instead of crashing. The
overloaded (hi) leg runs with chunked-prefill interleaving ON
(``prefill_chunk_tokens=32``) so the overload machinery — preemption of
mid-ingest slots included — is exercised against the chunked ingest
path under the same exact-accounting gates.

SLO attainment is computed **from the lifecycle trace** (repro.obs.trace):
each leg's goodput/preemption/rejection counts are reconstructed from the
per-request trace outcomes and asserted *exactly equal* to both the
request-field accounting and the scheduler counters — silent event loss
(or a lifecycle-invariant violation: any submitted request without
exactly one terminal event) fails the bench. Set ``--trace-out`` (or
``REPRO_TRACE_OUT``) to save the overloaded leg's Perfetto timeline; the
hi-leg metrics snapshot is written as a markdown table to
``BENCH_metrics.md`` (the CI bench-smoke job appends it to the step
summary).

Reports two gated rows:

  serve/traffic_goodput   us_per_call = p50 TTFT (microseconds) of the
                          under-capacity leg. Derived counters:
                            goodput_lo / goodput_hi  fraction of arrivals
                              that finished AND met their targets at
                              ~0.5x and ~3x measured capacity (derived
                              from the trace, cross-checked as above)
                            p50_ttft_ms / p99_ttft_ms / p50_tpot_ms /
                              p99_tpot_ms  latency tails (lo leg)
                            cap_rps / rate_lo / rate_hi  measured
                              capacity + offered rates (requests/s)
                            rejected / preempted  overload-machinery
                              activity across both legs
                            lost  requests neither finished nor failed
                              (MUST be 0: nothing vanishes)

  serve/obs_overhead      us_per_call = us per decoded token with
                          observability ON. Derived counters:
                            tok_s_on / tok_s_off  steady-state decode
                              tok/s with the obs stack enabled vs
                              disabled (one engine, arms alternated
                              per wave, trimmed-mean wave time)
                            overhead  on/off wave time - 1, asserted
                              <= 3% here and re-asserted (<= 5%,
                              noise headroom) by check_regression

The run itself raises when lost != 0 or when the under-capacity leg's
goodput drops below 0.9 — a lightly loaded engine that misses generous
SLOs is a scheduling regression, not noise.
``benchmarks.check_regression`` re-asserts both rows from the emitted
JSON (check_traffic_goodput / check_obs_overhead) so a stale CI artifact
cannot pass the gate.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from benchmarks.common import CSV
from repro.models import transformer
from repro.obs import trace as trace_mod
from repro.serve.engine import Request, ServeEngine
from benchmarks.bench_serve import serve_rcfg

MAX_LEN = 64
BATCH = 4
PAGE = 8
NEW_TOKENS = 8
N_REQS = 24               # arrivals per leg
TTFT_TARGET = 2.0         # generous targets: a healthy engine at 0.5x
TPOT_TARGET = 0.25        # capacity clears them easily on any CI host
GOODPUT_FLOOR = 0.9
OBS_OVERHEAD_CEIL = 0.03  # enabled-vs-disabled throughput cost contract

METRICS_MD = "BENCH_metrics.md"

N_POOL_PAGES = 7          # < pages_needed(MAX_LEN): a max_len request is
                          # rejected at submit; ~2-3 normal requests
                          # co-reside, so the hi leg hits page pressure


def _mk_engine(rcfg, params, **kw) -> ServeEngine:
    return ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=BATCH,
                       page_size=PAGE, n_pages=1 + N_POOL_PAGES, **kw)


def _requests(rng, n: int, oversized: bool = False):
    reqs = []
    for i in range(n):
        prompt = rng.integers(0, 256, size=int(rng.integers(8, 17))).astype(
            np.int32)
        reqs.append(Request(prompt=prompt, max_new_tokens=NEW_TOKENS,
                            priority=i % 2, ttft_target_s=TTFT_TARGET,
                            tpot_target_s=TPOT_TARGET))
    if oversized:
        # can never fit the pool: must be rejected alone, not crash the leg
        reqs[n // 2] = Request(
            prompt=rng.integers(0, 256, size=MAX_LEN - 1).astype(np.int32),
            max_new_tokens=MAX_LEN, priority=0,
            ttft_target_s=TTFT_TARGET, tpot_target_s=TPOT_TARGET)
    return reqs


def _measure_capacity(eng: ServeEngine, rng) -> float:
    """Closed-loop requests/s on warm traces: drain a full-batch queue
    back-to-back — the denominator the offered-load sweep scales."""
    reqs = _requests(rng, 2 * BATCH)
    t0 = time.perf_counter()
    eng.generate(reqs)
    return len(reqs) / (time.perf_counter() - t0)


def _run_leg(eng: ServeEngine, reqs, rate: float, rng):
    """Open-loop: submit each request at its Poisson arrival time while
    the scheduler steps in between; returns the finished
    ScheduledRequests paired with their arrival-order index."""
    sched = eng.scheduler
    eng._validate(reqs)
    gaps = rng.exponential(1.0 / rate, size=len(reqs))
    arrivals = np.cumsum(gaps)
    handles = [None] * len(reqs)
    i = 0
    t0 = time.perf_counter()
    while i < len(reqs) or sched.queue or sched.n_active:
        now = time.perf_counter() - t0
        while i < len(reqs) and arrivals[i] <= now:
            handles[i] = eng._submit_one(reqs[i])
            i += 1
        if not sched.step() and i < len(reqs):
            # idle engine, next arrival still in the future
            time.sleep(max(arrivals[i] - (time.perf_counter() - t0), 0.0))
    return handles


def _trace_accounting(eng: ServeEngine, handles, leg: str):
    """Reconstruct the leg's goodput/preemption/rejection counts purely
    from the lifecycle trace and assert exact agreement with the
    request-field accounting and the scheduler counters — the
    silent-event-loss gate. Returns (goodput, preempted, rejected)."""
    tr = eng.obs.trace
    rids = {h.rid for h in handles}
    if tr.dropped:
        raise RuntimeError(
            f"traffic leg {leg}: trace ring dropped {tr.dropped} events — "
            f"size the buffer for the workload before trusting it")
    violations = trace_mod.lifecycle_violations(tr.events(), rids)
    if violations:
        raise RuntimeError(
            f"traffic leg {leg}: lifecycle invariant violated: "
            + "; ".join(violations))
    outcomes = [o for rid, o in
                trace_mod.request_outcomes(tr.events()).items()
                if rid in rids]
    if len(outcomes) != len(handles):
        raise RuntimeError(
            f"traffic leg {leg}: {len(handles)} submitted, "
            f"{len(outcomes)} in the trace")
    good_trace = sum(o.slo_met for o in outcomes)
    good_req = sum(h.slo_met for h in handles)
    if good_trace != good_req:
        raise RuntimeError(
            f"traffic leg {leg}: trace-derived goodput {good_trace} != "
            f"request-field goodput {good_req} — events were lost or "
            f"mis-attributed")
    st = eng.scheduler.stats
    preempted = sum(o.preemptions for o in outcomes)
    rejected = sum(o.rejected for o in outcomes)
    if preempted != st["preemptions"]:
        raise RuntimeError(
            f"traffic leg {leg}: trace preemptions {preempted} != "
            f"counter {st['preemptions']}")
    if rejected != st["requests_rejected"]:
        raise RuntimeError(
            f"traffic leg {leg}: trace rejections {rejected} != "
            f"counter {st['requests_rejected']}")
    return good_trace / len(outcomes), preempted, rejected


def _metrics_table(eng: ServeEngine) -> str:
    """Markdown metrics-snapshot table (CI appends it to the bench-smoke
    step summary)."""
    snap = eng.metrics_snapshot()

    def pcts(name):
        h = snap[name]
        if not h["count"]:
            return "—"
        return " / ".join(f"{h[p] * 1e3:.1f}" for p in ("p50", "p95",
                                                        "p99"))

    rows = [
        ("TTFT p50 / p95 / p99 (ms)", pcts("request.ttft_s")),
        ("TPOT p50 / p95 / p99 (ms)", pcts("request.tpot_s")),
        ("latency p50 / p95 / p99 (ms)", pcts("request.latency_s")),
        ("preemptions", snap["scheduler.preemptions"]),
        ("requests rejected", snap["scheduler.requests_rejected"]),
        ("trie hit rate", f"{snap['trie.hit_rate']:.3f}"),
        ("compiles per callable",
         f"{snap['engine.compiles_per_callable']:.2f}"),
    ]
    lines = ["### serve metrics snapshot (traffic bench, overloaded leg)",
             "", "| metric | value |", "| --- | --- |"]
    lines += [f"| {k} | {v} |" for k, v in rows]
    return "\n".join(lines) + "\n"


def _obs_overhead(csv: CSV) -> None:
    """The ≤3% observability-cost contract: decode tok/s with the obs
    stack enabled vs disabled, measured on ONE engine by toggling the
    exact branches a disabled engine skips (``scheduler.trace is None``
    and ``metrics.enabled``). Two separate engine instances differ by a
    few percent from allocation/compile-cache luck alone — a bias no
    amount of interleaving averages out — so the toggle is the only way
    to isolate the host-side emission cost. The arms alternate PER WAVE
    inside the same drain so host-load epochs (which outlive a wave by
    orders of magnitude) hit both equally, and a 10%-trimmed mean over
    the pure decode waves (full batch, empty queue) strips scheduler
    jitter and GC pauses. The model is a d256 scale-up of the bench
    config: emission cost is a fixed ~10-20us of host work per wave,
    so dividing it by the tiny shared bench model's ~1ms waves would
    overstate the cost of any realistic deployment — ~5ms waves are
    the smallest honest denominator this host can measure against.
    Full default page pool — emission cost, not overload machinery."""
    rcfg = serve_rcfg(name="bench_obs", d_model=256, d_ff=512, n_heads=8,
                      n_kv_heads=4, head_dim=32)
    params = transformer.init_model(jax.random.PRNGKey(0), rcfg)
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=BATCH,
                      page_size=PAGE, observability=True)
    sched = eng.scheduler

    def set_obs(on: bool) -> None:
        # branch-for-branch what ``observability=False`` construction
        # does to the hot path: trace guards see None, observe() no-ops
        sched.trace = eng.obs.trace if on else None
        eng.obs.metrics.enabled = on

    def drain(seed: int):
        """Drain 2 batches of requests, alternating the obs arm on every
        pure decode wave; returns (on_times, off_times)."""
        rng = np.random.default_rng(seed)
        for _ in range(2 * BATCH):
            eng.submit(Request(prompt=rng.integers(0, 256, size=12).astype(
                np.int32), max_new_tokens=24))
        on_t, off_t = [], []
        alive, i = True, 0
        while alive:
            if not (sched.n_active == BATCH and not sched.queue):
                set_obs(True)       # admission/reap waves: not sampled
                alive = sched.step()
                continue
            on = (i + seed) % 2 == 0    # parity flips drain to drain
            i += 1
            set_obs(on)
            t0 = time.perf_counter()
            alive = sched.step()
            dt = time.perf_counter() - t0
            (on_t if on else off_t).append(dt)
        sched.finished.clear()
        return on_t, off_t

    def trimmed_mean(times) -> float:
        a = np.sort(np.asarray(times))
        k = len(a) // 10
        return float(a[k:len(a) - k].mean())

    drain(0)                            # compile + warm both arms
    on_times, off_times = [], []
    for seed in range(1, 9):
        on_t, off_t = drain(seed)
        on_times += on_t
        off_times += off_t
    set_obs(True)
    wave_on = trimmed_mean(on_times)
    wave_off = trimmed_mean(off_times)
    tok_on = BATCH / wave_on
    tok_off = BATCH / wave_off
    overhead = wave_on / wave_off - 1.0
    if overhead > OBS_OVERHEAD_CEIL:
        raise RuntimeError(
            f"observability overhead {overhead:.1%} exceeds the "
            f"{OBS_OVERHEAD_CEIL:.0%} contract "
            f"({tok_on:.1f} vs {tok_off:.1f} tok/s)")
    csv.add("serve/obs_overhead", 1e6 / tok_on,
            f"tok_s_on={tok_on:.1f};tok_s_off={tok_off:.1f};"
            f"overhead={overhead:.4f}")


def run(csv: CSV, trace_out: str = ""):
    trace_out = trace_out or os.environ.get("REPRO_TRACE_OUT", "")
    rcfg = serve_rcfg()
    params = transformer.init_model(jax.random.PRNGKey(0), rcfg)
    rng = np.random.default_rng(0)

    eng = _mk_engine(rcfg, params)
    eng.generate(_requests(rng, BATCH))          # compile the hot traces
    cap = _measure_capacity(eng, rng)

    stats = {"rejected": 0, "preempted": 0}
    legs = {}
    for leg, mult in (("lo", 0.5), ("hi", 3.0)):
        # the overloaded leg interleaves chunked prefill with decode, so
        # page pressure also exercises mid-ingest preemption/recompute
        leg_kw = dict(prefill_chunk_tokens=32) if leg == "hi" else {}
        leg_eng = _mk_engine(rcfg, params, **leg_kw)  # fresh pool per leg
        leg_eng.generate(_requests(rng, BATCH))  # warm (shares jit cache)
        sched = leg_eng.scheduler
        for k in sched.stats:
            sched.stats[k] = type(sched.stats[k])(0)
        reqs = _requests(rng, N_REQS, oversized=(leg == "hi"))
        done = _run_leg(leg_eng, reqs, mult * cap, rng)
        lost = sum(1 for h in done if not h.done)
        if lost:
            raise RuntimeError(
                f"traffic leg {leg}: {lost} requests neither finished nor "
                f"failed — the scheduler dropped them on the floor")
        goodput, preempted, rejected = _trace_accounting(leg_eng, done,
                                                         leg)
        legs[leg] = dict(goodput=goodput, lost=lost, done=done)
        stats["rejected"] += rejected
        stats["preempted"] += preempted
        if leg == "hi":
            if sched.stats["prefill_chunks"] == 0:
                raise RuntimeError(
                    "traffic hi leg: chunked-prefill interleaving never "
                    "engaged (prefill_chunks == 0)")
            with open(METRICS_MD, "w") as f:
                f.write(_metrics_table(leg_eng))
            if trace_out:
                n = leg_eng.save_trace(trace_out)
                print(f"# traffic hi-leg lifecycle trace -> {trace_out} "
                      f"({n} events)")

    if legs["lo"]["goodput"] < GOODPUT_FLOOR:
        raise RuntimeError(
            f"under-capacity goodput {legs['lo']['goodput']:.2f} below "
            f"{GOODPUT_FLOOR} — a lightly loaded engine must meet "
            f"generous SLOs")

    ttfts = np.asarray([h.ttft for h in legs["lo"]["done"]
                        if h.ttft is not None])
    tpots = np.asarray([h.tpot for h in legs["lo"]["done"]
                        if h.tpot is not None])
    csv.add(
        "serve/traffic_goodput", float(np.percentile(ttfts, 50)) * 1e6,
        f"goodput_lo={legs['lo']['goodput']:.3f};"
        f"goodput_hi={legs['hi']['goodput']:.3f};"
        f"p50_ttft_ms={np.percentile(ttfts, 50) * 1e3:.1f};"
        f"p99_ttft_ms={np.percentile(ttfts, 99) * 1e3:.1f};"
        f"p50_tpot_ms={np.percentile(tpots, 50) * 1e3:.2f};"
        f"p99_tpot_ms={np.percentile(tpots, 99) * 1e3:.2f};"
        f"cap_rps={cap:.1f};rate_lo={0.5 * cap:.1f};"
        f"rate_hi={3.0 * cap:.1f};rejected={stats['rejected']};"
        f"preempted={stats['preempted']};"
        f"chunk_hi=32;"
        f"lost={legs['lo']['lost'] + legs['hi']['lost']}")

    _obs_overhead(csv)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--trace-out", default="",
                    help="save the overloaded leg's Perfetto trace JSON "
                         "here (open at https://ui.perfetto.dev)")
    args = ap.parse_args(argv)
    csv = CSV()
    run(csv, trace_out=args.trace_out)
    print("name,us_per_call,derived")
    csv.emit()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
