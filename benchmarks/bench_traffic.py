"""Poisson-arrival traffic benchmark: goodput under offered-load sweeps.

The other serve benchmarks measure closed-loop capacity (drain a queue as
fast as possible); this one measures the *open-loop* overload behavior
ISSUE 7 added — requests arrive on a Poisson clock the engine does not
control, carry priorities and TTFT/TPOT targets, and the scheduler must
degrade gracefully when the offered load exceeds capacity (skip-ahead
admission, preemption, per-request failure) instead of crashing.

Reports one gated row:

  serve/traffic_goodput   us_per_call = p50 TTFT (microseconds) of the
                          under-capacity leg. Derived counters:
                            goodput_lo / goodput_hi  fraction of arrivals
                              that finished AND met their targets at
                              ~0.5x and ~3x measured capacity
                            p50_ttft_ms / p99_ttft_ms / p50_tpot_ms /
                              p99_tpot_ms  latency tails (lo leg)
                            cap_rps / rate_lo / rate_hi  measured
                              capacity + offered rates (requests/s)
                            rejected / preempted  overload-machinery
                              activity across both legs
                            lost  requests neither finished nor failed
                              (MUST be 0: nothing vanishes)

The run itself raises when lost != 0 or when the under-capacity leg's
goodput drops below 0.9 — a lightly loaded engine that misses generous
SLOs is a scheduling regression, not noise.
``benchmarks.check_regression`` re-asserts both from the emitted JSON
(check_traffic_goodput) so a stale CI artifact cannot pass the gate.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import CSV
from repro.models import transformer
from repro.serve.engine import Request, ServeEngine
from benchmarks.bench_serve import serve_rcfg

MAX_LEN = 64
BATCH = 4
PAGE = 8
NEW_TOKENS = 8
N_REQS = 24               # arrivals per leg
TTFT_TARGET = 2.0         # generous targets: a healthy engine at 0.5x
TPOT_TARGET = 0.25        # capacity clears them easily on any CI host
GOODPUT_FLOOR = 0.9


N_POOL_PAGES = 7          # < pages_needed(MAX_LEN): a max_len request is
                          # rejected at submit; ~2-3 normal requests
                          # co-reside, so the hi leg hits page pressure


def _mk_engine(rcfg, params) -> ServeEngine:
    return ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=BATCH,
                       page_size=PAGE, n_pages=1 + N_POOL_PAGES)


def _requests(rng, n: int, oversized: bool = False):
    reqs = []
    for i in range(n):
        prompt = rng.integers(0, 256, size=int(rng.integers(8, 17))).astype(
            np.int32)
        reqs.append(Request(prompt=prompt, max_new_tokens=NEW_TOKENS,
                            priority=i % 2, ttft_target_s=TTFT_TARGET,
                            tpot_target_s=TPOT_TARGET))
    if oversized:
        # can never fit the pool: must be rejected alone, not crash the leg
        reqs[n // 2] = Request(
            prompt=rng.integers(0, 256, size=MAX_LEN - 1).astype(np.int32),
            max_new_tokens=MAX_LEN, priority=0,
            ttft_target_s=TTFT_TARGET, tpot_target_s=TPOT_TARGET)
    return reqs


def _measure_capacity(eng: ServeEngine, rng) -> float:
    """Closed-loop requests/s on warm traces: drain a full-batch queue
    back-to-back — the denominator the offered-load sweep scales."""
    reqs = _requests(rng, 2 * BATCH)
    t0 = time.perf_counter()
    eng.generate(reqs)
    return len(reqs) / (time.perf_counter() - t0)


def _run_leg(eng: ServeEngine, reqs, rate: float, rng):
    """Open-loop: submit each request at its Poisson arrival time while
    the scheduler steps in between; returns the finished
    ScheduledRequests paired with their arrival-order index."""
    sched = eng.scheduler
    eng._validate(reqs)
    gaps = rng.exponential(1.0 / rate, size=len(reqs))
    arrivals = np.cumsum(gaps)
    handles = [None] * len(reqs)
    i = 0
    t0 = time.perf_counter()
    while i < len(reqs) or sched.queue or sched.n_active:
        now = time.perf_counter() - t0
        while i < len(reqs) and arrivals[i] <= now:
            handles[i] = eng._submit_one(reqs[i])
            i += 1
        if not sched.step() and i < len(reqs):
            # idle engine, next arrival still in the future
            time.sleep(max(arrivals[i] - (time.perf_counter() - t0), 0.0))
    return handles


def run(csv: CSV):
    rcfg = serve_rcfg()
    params = transformer.init_model(jax.random.PRNGKey(0), rcfg)
    rng = np.random.default_rng(0)

    eng = _mk_engine(rcfg, params)
    eng.generate(_requests(rng, BATCH))          # compile the hot traces
    cap = _measure_capacity(eng, rng)

    stats = {"rejected": 0, "preempted": 0}
    legs = {}
    for leg, mult in (("lo", 0.5), ("hi", 3.0)):
        leg_eng = _mk_engine(rcfg, params)       # fresh pool per leg
        leg_eng.generate(_requests(rng, BATCH))  # warm (shares jit cache)
        sched = leg_eng.scheduler
        for k in sched.stats:
            sched.stats[k] = type(sched.stats[k])(0)
        reqs = _requests(rng, N_REQS, oversized=(leg == "hi"))
        done = _run_leg(leg_eng, reqs, mult * cap, rng)
        lost = sum(1 for h in done if not h.done)
        goodput = sum(h.slo_met for h in done) / len(done)
        legs[leg] = dict(goodput=goodput, lost=lost, done=done)
        stats["rejected"] += sched.stats["requests_rejected"]
        stats["preempted"] += sched.stats["preemptions"]
        if lost:
            raise RuntimeError(
                f"traffic leg {leg}: {lost} requests neither finished nor "
                f"failed — the scheduler dropped them on the floor")

    if legs["lo"]["goodput"] < GOODPUT_FLOOR:
        raise RuntimeError(
            f"under-capacity goodput {legs['lo']['goodput']:.2f} below "
            f"{GOODPUT_FLOOR} — a lightly loaded engine must meet "
            f"generous SLOs")

    ttfts = np.asarray([h.ttft for h in legs["lo"]["done"]
                        if h.ttft is not None])
    tpots = np.asarray([h.tpot for h in legs["lo"]["done"]
                        if h.tpot is not None])
    csv.add(
        "serve/traffic_goodput", float(np.percentile(ttfts, 50)) * 1e6,
        f"goodput_lo={legs['lo']['goodput']:.3f};"
        f"goodput_hi={legs['hi']['goodput']:.3f};"
        f"p50_ttft_ms={np.percentile(ttfts, 50) * 1e3:.1f};"
        f"p99_ttft_ms={np.percentile(ttfts, 99) * 1e3:.1f};"
        f"p50_tpot_ms={np.percentile(tpots, 50) * 1e3:.2f};"
        f"p99_tpot_ms={np.percentile(tpots, 99) * 1e3:.2f};"
        f"cap_rps={cap:.1f};rate_lo={0.5 * cap:.1f};"
        f"rate_hi={3.0 * cap:.1f};rejected={stats['rejected']};"
        f"preempted={stats['preempted']};"
        f"lost={legs['lo']['lost'] + legs['hi']['lost']}")
