"""Paper Fig. 3/4: serial vs layer-parallel vs switched training dynamics.

Trains the same tiny encoder three ways from the same seed and reports the
loss-trajectory gaps. The 'switched' run reproduces the paper's green curve:
LP early, serial after the controller (or a fixed point) switches.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import CSV, tiny_rcfg
from repro.train.trainer import Trainer


def run(csv: CSV, steps: int = 120):
    rcfg_lp = tiny_rcfg(lp=True, fwd=1, bwd=1, steps=steps, check_every=40)
    rcfg_s = dataclasses.replace(
        rcfg_lp, mgrit=dataclasses.replace(rcfg_lp.mgrit, enabled=False))

    t0 = time.perf_counter()
    rep_s = Trainer(rcfg_s, seed=0).train(steps, log_every=0, probe=False)
    t_serial = (time.perf_counter() - t0) / steps

    t0 = time.perf_counter()
    rep_lp = Trainer(rcfg_lp, seed=0).train(steps, log_every=0, probe=False)
    t_lp = (time.perf_counter() - t0) / steps

    # switched: adaptive controller active (paper green curve)
    rep_sw = Trainer(rcfg_lp, seed=0).train(steps, log_every=0, probe=True)

    ls, lp = np.array(rep_s.losses), np.array(rep_lp.losses)
    lsw = np.array(rep_sw.losses)
    early = np.abs(ls[:40] - lp[:40]).max()
    late = np.abs(ls[-20:] - lp[-20:]).max()
    sw_late = np.abs(ls[-20:] - lsw[-20:]).max()
    csv.add("convergence/serial_step", t_serial * 1e6,
            f"final_loss={ls[-5:].mean():.4f}")
    csv.add("convergence/lp_step", t_lp * 1e6,
            f"early_gap={early:.4f};late_gap={late:.4f}")
    csv.add("convergence/switched", 0.0,
            f"late_gap={sw_late:.4f};switched_at={rep_sw.switched_at}")
