"""Kernel microbenchmarks: Pallas (interpret on CPU) vs pure-jnp oracle.

On the CPU container the meaningful number is the *oracle* timing (the jnp
path also runs on TPU); the Pallas kernels' own perf claim comes from the
VMEM/MXU tiling documented in the kernel files and validated for
correctness here and in tests/test_kernels.py."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CSV, time_call
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.rmsnorm import rmsnorm_2d
from repro.kernels.ssm_scan import ssm_scan


def run(csv: CSV):
    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    q = (jax.random.normal(ks[0], (1, 4, 256, 64)) * 0.5).astype(jnp.bfloat16)
    k = (jax.random.normal(ks[1], (1, 2, 256, 64)) * 0.5).astype(jnp.bfloat16)
    v = (jax.random.normal(ks[2], (1, 2, 256, 64)) * 0.5).astype(jnp.bfloat16)
    ref_fn = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c))
    us_ref = time_call(ref_fn, q, k, v)
    out_k = flash_attention_bhsd(q, k, v, causal=True, interpret=True)
    err = float(jnp.max(jnp.abs(out_k.astype(jnp.float32)
                                - ref_fn(q, k, v).astype(jnp.float32))))
    csv.add("kernels/flash_attention_ref", us_ref, f"max_err={err:.4f}")

    x = (jax.random.normal(ks[3], (2048, 1024)) * 0.5).astype(jnp.bfloat16)
    w = jnp.ones((1024,), jnp.float32)
    us_ref = time_call(jax.jit(lambda a, b: ref.rmsnorm_ref(a, b)), x, w)
    out_k = rmsnorm_2d(x, w, interpret=True)
    err = float(jnp.max(jnp.abs(out_k.astype(jnp.float32)
                                - ref.rmsnorm_ref(x, w).astype(jnp.float32))))
    csv.add("kernels/rmsnorm_ref", us_ref, f"max_err={err:.4f}")

    Bb, S, di, ds = 2, 256, 64, 16
    dt = jax.nn.softplus(jax.random.normal(ks[4], (Bb, S, di))) * 0.1
    xs = (jax.random.normal(ks[5], (Bb, S, di)) * 0.5).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[6], (di, ds)) * 0.3)
    B = jax.random.normal(ks[7], (Bb, S, ds)) * 0.5
    C = jax.random.normal(ks[0], (Bb, S, ds)) * 0.5
    D = jnp.ones((di,))
    us_ref = time_call(jax.jit(ref.ssm_scan_ref), dt, xs, A, B, C, D)
    out_k = ssm_scan(dt, xs, A, B, C, D, chunk=64, interpret=True)
    err = float(jnp.max(jnp.abs(out_k - ref.ssm_scan_ref(dt, xs, A, B, C, D))))
    csv.add("kernels/ssm_scan_ref", us_ref, f"max_err={err:.5f}")
