"""Paper App. B / Fig. 12: buffer layers shrink the LP-vs-serial loss gap
for decoder-only models (first/last layers carry the largest Lipschitz
constants and are computed serially)."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import CSV, tiny_rcfg
from repro.train.trainer import Trainer


def _gap(rcfg, steps):
    ser = dataclasses.replace(
        rcfg, mgrit=dataclasses.replace(rcfg.mgrit, enabled=False))
    rs = Trainer(ser, seed=0).train(steps, log_every=0, probe=False)
    rp = Trainer(rcfg, seed=0).train(steps, log_every=0, probe=False)
    ls, lp = np.array(rs.losses), np.array(rp.losses)
    return float(np.abs(ls - lp)[-20:].mean())


def run(csv: CSV, steps: int = 80):
    # 20-layer GPT-style decoder (paper's config, tiny dims)
    no_buf = tiny_rcfg(family="decoder", n_layers=20, lp=True, cf=4,
                       fwd=1, bwd=1, pad_to=20, h=1.0 / 20, steps=steps,
                       lr=5e-3, opt="adamw")
    with_buf = dataclasses.replace(
        no_buf, mgrit=dataclasses.replace(no_buf.mgrit, n_open=2, n_close=2,
                                          pad_to=16, h=1.0 / 16))
    g0 = _gap(no_buf, steps)
    g1 = _gap(with_buf, steps)
    csv.add("buffer/no_buffer", 0.0, f"late_gap={g0:.4f}")
    csv.add("buffer/with_buffer", 0.0,
            f"late_gap={g1:.4f};improved={g1 <= g0}")
