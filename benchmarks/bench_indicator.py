"""Paper Fig. 5: the MGRIT convergence-factor indicator over training.

Runs LP training with periodic doubled-iteration probes and reports the
indicator trajectory (rho_fwd, rho_bwd per probe)."""
from __future__ import annotations


from benchmarks.common import CSV, tiny_rcfg
from repro.train.trainer import Trainer


def run(csv: CSV, steps: int = 120):
    rcfg = tiny_rcfg(lp=True, fwd=1, bwd=1, steps=steps, check_every=25,
                     lr=0.15)  # aggressive lr pushes the indicator up
    tr = Trainer(rcfg, seed=0)
    rep = tr.train(steps, log_every=0, probe=True)
    hist = rep.controller_history
    if not hist:
        csv.add("indicator/probes", 0.0, "no_probes")
        return
    rho_f = [h[1] for h in hist]
    rho_b = [h[2] for h in hist]
    trace = ";".join(f"{s}:{f:.3f}/{b:.3f}" for s, f, b in hist[:8])
    csv.add("indicator/probes", 0.0,
            f"n={len(hist)};max_rho_fwd={max(rho_f):.3f};"
            f"max_rho_bwd={max(rho_b):.3f};switched_at={rep.switched_at};"
            f"trace={trace}")
