"""Paper Table 1: fine-tune delta between a serially pre-trained model and
an adaptively-switched (LP -> serial) pre-trained model.

Pre-trains a tiny encoder both ways, then fine-tunes each on a synthetic
classification-flavored LM objective and reports |delta loss| / |delta acc|."""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import CSV, tiny_rcfg
from repro.train.trainer import Trainer
from repro.models import transformer


def _acc(trainer, steps=4):
    accs = []
    for s in range(steps):
        b = trainer.pipeline.batch_at(10_000 + s)
        logits, _ = jax.jit(lambda p, bb: transformer.forward(
            p, bb, trainer.rcfg, mode="serial"))(trainer.params, b)
        pred = np.asarray(logits.argmax(-1))
        accs.append((pred == b["labels"]).mean())
    return float(np.mean(accs))


def run(csv: CSV, pre_steps: int = 80, ft_steps: int = 40):
    rcfg_lp = tiny_rcfg(lp=True, fwd=1, bwd=1, steps=pre_steps,
                        check_every=30)
    rcfg_s = dataclasses.replace(
        rcfg_lp, mgrit=dataclasses.replace(rcfg_lp.mgrit, enabled=False))

    t_serial = Trainer(rcfg_s, seed=0)
    t_serial.train(pre_steps, log_every=0, probe=False)
    t_switch = Trainer(rcfg_lp, seed=0)
    t_switch.train(pre_steps, log_every=0, probe=True)

    # "fine-tune": continue serially on a different data seed (new task)
    for t in (t_serial, t_switch):
        t.pipeline.seed = 7
        t.controller.state.mode = "serial"
        t.train(ft_steps, log_every=0, probe=False)

    l_s = float(t_serial.train(1, log_every=0, probe=False).losses[0])
    l_p = float(t_switch.train(1, log_every=0, probe=False).losses[0])
    a_s, a_p = _acc(t_serial), _acc(t_switch)
    csv.add("finetune/delta", 0.0,
            f"dloss={abs(l_s - l_p):.4f};dacc={abs(a_s - a_p):.4f};"
            f"acc_serial={a_s:.3f};acc_switched={a_p:.3f}")
