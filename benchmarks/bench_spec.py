"""Coarse-propagator speculative decoding vs plain paged decode.

Per backend family, the same greedy workload runs through a plain paged
engine and a spec engine (``cf=4, k=4`` — the paper's default coarsening
as the draft), asserting token-for-token identical outputs, and reports:

  serve/spec_attn     decode us/token with spec decode, attention backend
  serve/spec_ssm      same, SSM (mamba1) snapshot-page backend
  serve/spec_hybrid   same, hybrid (zamba2-style) backend

Each row's derived field carries ``tok_s`` (spec decode throughput,
steady-state decode phase only), ``plain_tok_s``, ``speedup`` and
``accept`` (fraction of drafted tokens accepted). The bench RAISES if
any greedy output differs from plain decode, or if the acceptance rate
drops below ``ACCEPT_FLOOR`` — the paper's premise that the coarse
propagator tracks the fine model in the trained regime.

``speedup`` is reported but deliberately NOT gated anymore. The original
ISSUE-4 criterion (spec beats plain) held against the gathered decode
path; the fused paged-decode step (PR 6) removed the per-step pool-copy
overhead that speculative waves were amortizing, and at this bench's toy
scale on CPU the comparison now inverts honestly: the SSM verify wave
advances an S-sequential recurrence (~k+1 plain steps of recurrence work
for k+1 tokens), and a coarse draft step costs nearly a full fine step
because per-step pool reads/commits, not layer math, dominate. Both
engines here run the same fused path — including the verify wave and
the k in-jit draft steps — so the speedup column tracks the real gap as
spec decode re-earns its edge (ROADMAP: adaptive/tree speculation);
gating it at >1 would only reward benching spec against a deliberately
unfused baseline.

Weights are initialized into the *trained regime*: residual output
projections are damped so each block is a small perturbation of the
identity — the smooth neural-ODE discretization trained transformers
exhibit and the paper's multilevel coarsening assumes (§2). Raw random
init is adversarial to ANY layer-coarsened draft (layer outputs are
uncorrelated noise), and would measure tie-breaking luck instead of the
mechanism. Acceptance rates are reported, not assumed.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.bench_serve import hybrid_rcfg, serve_rcfg, ssm_rcfg
from benchmarks.common import CSV
from repro.models import transformer
from repro.serve.engine import Request, ServeEngine
from repro.serve.spec import SpecConfig

BATCH = 4
PROMPT = 16
NEW_TOKENS = 48
MAX_LEN = 256
CF, K = 4, 4

# gate floor for the drafted-token acceptance rate: deterministic given
# the fixed seeds/damping (greedy workload), measured 0.89-1.00 per
# family — a drop means the coarse restriction or the verify/rollback
# contract broke, not that a host got slow
ACCEPT_FLOOR = 0.8

# residual output projections (block F -> residual stream); norm_scale is
# mamba2's gated-RMSNorm gain, which otherwise pins |F| at O(1)
_RESIDUAL_OUT = ("out_proj", "wo", "w_out", "norm_scale")

# THE damped-init knob (referenced by repro.serve.spec): per-family
# damping factor applied by ``trained_regime`` to every residual output
# projection. Smaller = closer to identity blocks = higher draft
# acceptance; the hybrid family needs a stronger damp because its shared
# attention block is coarsened in cadence, not just depth.
TRAINED_REGIME_DAMP = {"attn": 0.1, "ssm": 0.1, "hybrid": 0.05}


def trained_regime(params, factor: float):
    """Damp every residual output projection by ``factor``: post-training
    transformer blocks are near-identity maps (the paper's smoothness
    premise); this reproduces that regime from random init."""
    if isinstance(params, dict):
        return {k: (v * factor if k in _RESIDUAL_OUT
                    else trained_regime(v, factor))
                for k, v in params.items()}
    return params


def _requests(rcfg):
    rng = np.random.default_rng(0)
    return [Request(
        prompt=rng.integers(0, rcfg.model.vocab_size,
                            size=PROMPT).astype(np.int32),
        max_new_tokens=NEW_TOKENS) for _ in range(BATCH)]


def _decode_tok_s(engine, reqs):
    """Run the workload and return (decode tokens/s, outputs): throughput
    comes from the scheduler's own decode counters, so prefill
    compile/time is excluded — that path is identical for both engines
    and benched by serve/prefill_chunked."""
    for k in engine.scheduler.stats:
        engine.scheduler.stats[k] = type(engine.scheduler.stats[k])(0)
    out = engine.generate(reqs)
    s = engine.scheduler.stats
    assert all(len(r.output) == NEW_TOKENS for r in out)
    return s["decode_tokens"] / max(s["decode_s"], 1e-9), out


def run(csv: CSV):
    fams = (("serve/spec_attn", serve_rcfg(), TRAINED_REGIME_DAMP["attn"]),
            ("serve/spec_ssm", ssm_rcfg(), TRAINED_REGIME_DAMP["ssm"]),
            ("serve/spec_hybrid", hybrid_rcfg(),
             TRAINED_REGIME_DAMP["hybrid"]))
    failures = []
    for row, rcfg, damp in fams:
        params = trained_regime(
            transformer.init_model(jax.random.PRNGKey(0), rcfg), damp)
        kw = dict(max_len=MAX_LEN, max_batch=BATCH, page_size=16)
        plain = ServeEngine(rcfg, params, **kw)
        spec = ServeEngine(rcfg, params, spec=SpecConfig(cf=CF, k=K), **kw)
        plain.generate(_requests(rcfg))          # warm every trace
        spec.generate(_requests(rcfg))
        best_p, best_s = 0.0, 0.0
        for _ in range(3):                       # medians are too spiky on
            p_tok_s, ref = _decode_tok_s(plain, _requests(rcfg))
            s_tok_s, got = _decode_tok_s(spec, _requests(rcfg))
            best_p = max(best_p, p_tok_s)        # shared CI hosts; compare
            best_s = max(best_s, s_tok_s)        # best-of-3 each
        for a, b in zip(ref, got, strict=True):
            if not np.array_equal(a.output, b.output):
                failures.append(f"{row}: greedy outputs diverged")
                break
        accept = spec.stats["accept_rate"]
        speedup = best_s / max(best_p, 1e-9)
        csv.add(row, 1e6 / best_s,
                f"tok_s={best_s:.0f};plain_tok_s={best_p:.0f};"
                f"speedup={speedup:.2f};accept={accept:.2f}")
        if accept < ACCEPT_FLOOR:
            failures.append(
                f"{row}: acceptance rate {accept:.2f} below floor "
                f"{ACCEPT_FLOOR} — the coarse propagator stopped tracking "
                f"the fine model")
    if failures:
        raise RuntimeError("; ".join(failures))
