"""CI benchmark regression gate.

Compares a fresh ``benchmarks.run --emit-json`` output against the
committed baseline (benchmarks/BENCH_baseline.json) with a generous
multiplicative tolerance — the gate exists to catch order-of-magnitude
regressions on the measured hot paths, not single-digit-percent noise
across heterogeneous CI hosts.

  python -m benchmarks.check_regression BENCH_ci.json \
      benchmarks/BENCH_baseline.json [--tol 2.0] [--prefixes kernels/,serve/]

Also fails if any ``_meta/*`` entry in the current run reports an ERROR
(a benchmark crashed), regardless of timing, and — when the serve
shared-prefix rows are present — if prefix sharing stopped reducing work:
``serve/prefix_shared`` must compute strictly fewer prefill tokens and
allocate strictly fewer pages than ``serve/prefix_baseline`` (these are
exact counters, so no tolerance applies). The fused paged-decode rows
(``serve/decode_*_fused``) likewise carry their gathered-path control
in-row and must report speedup > 1.

Rows in ``REQUIRED_ROWS`` (the CacheBackend coverage rows: paged SSM +
hybrid decode, the shared-prefix counters, the per-family speculative-
decoding rows) may not silently vanish from the current run: a rename or
a deleted benchmark fails the gate instead of downgrading to a WARN.
"""
from __future__ import annotations

import argparse
import json
import sys


# benchmark rows that must exist in every run (not just match baseline):
# the serve stack's per-backend coverage — losing one of these means a
# whole family stopped being measured
REQUIRED_ROWS = (
    "serve/decode_paged",
    "serve/decode_ssm_paged",
    "serve/decode_hybrid_paged",
    # mesh-sharded serving: losing this row means SPMD decode stopped
    # being measured (bench_serve._mesh_row also conformance-checks the
    # mesh output against a single-device engine and raises on drift)
    "serve/decode_mesh_tp2",
    "serve/prefix_shared",
    "serve/prefix_baseline",
    # speculative decoding: one row per backend family (tokens/s +
    # acceptance rate; bench_spec itself raises on greedy divergence or
    # an acceptance-rate drop, which surfaces here as a _meta ERROR —
    # check_spec_accept below re-asserts the floor from the counters)
    "serve/spec_attn",
    "serve/spec_ssm",
    "serve/spec_hybrid",
    # fused paged-decode kernels (PR-6): one row per family, each
    # carrying its own gathered-path control in the derived counters.
    # A missing row means the fused path silently stopped being
    # exercised; a speedup <= 1 means it stopped paying for itself
    # (check_fused_speedup below, and bench_serve raises in-run too).
    "serve/decode_attn_fused",
    "serve/decode_ssm_fused",
    "serve/decode_hybrid_fused",
    # overload-safe scheduling (PR-7): Poisson-arrival goodput. Losing
    # this row means the SLO/preemption machinery stopped being measured
    # under open-loop load (check_traffic_goodput re-asserts the floor
    # and that no request was silently dropped).
    "serve/traffic_goodput",
    # serve observability (PR-9): enabled-vs-disabled engine throughput.
    # Losing this row means the ≤3% observability-cost contract stopped
    # being measured (check_obs_overhead re-asserts a looser ceiling
    # from the counters).
    "serve/obs_overhead",
    # PR-10: token-granular prefix sharing (fork_partial vs whole-page
    # matching, exact recomputed-token counters) and chunked-prefill
    # interleaving (long-prompt TTFT vs the stalled serial control with
    # a decode-throughput floor). check_prefix_partial /
    # check_ttft_interleaved re-assert the in-row gates from the JSON.
    "serve/prefix_partial",
    "serve/ttft_interleaved",
)


def check_required_rows(cur: dict, prefixes=None) -> list:
    """``prefixes=None`` demands every REQUIRED_ROWS entry (the full
    bench run); a prefix tuple scopes the demand to rows a partial
    ``--only`` run can produce (e.g. the kernel-tier CI lane runs no
    spec benchmarks, so serve/spec_* are not required there)."""
    rows = REQUIRED_ROWS if prefixes is None else \
        tuple(r for r in REQUIRED_ROWS if r.startswith(prefixes))
    return [f"required row {name} missing from current run"
            for name in rows if name not in cur]


def _counters(rec) -> dict:
    """Parse a ``k=v;k=v`` derived field into numeric counters (int when
    exact, float otherwise — the fused-speedup rows carry ratios)."""
    out = {}
    for kv in str(rec["derived"]).split(";"):
        k, _, v = kv.partition("=")
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v)
            except ValueError:
                pass
    return out


def check_prefix_sharing(cur: dict) -> list:
    """Exact-count gate: sharing must beat the no-sharing baseline."""
    shared = cur.get("serve/prefix_shared")
    base = cur.get("serve/prefix_baseline")
    if shared is None or base is None:
        return []
    s, b = _counters(shared), _counters(base)
    failures = []
    for key in ("prefill_tok", "pages"):
        if not s.get(key, 0) < b.get(key, 0):
            failures.append(
                f"serve/prefix_shared: {key}={s.get(key)} not strictly "
                f"below no-sharing baseline {b.get(key)}")
        else:
            print(f"ok    serve/prefix_shared: {key} {s[key]} < "
                  f"{b[key]} (no-sharing baseline)")
    return failures


def check_prefix_partial(cur: dict) -> list:
    """Exact-count gate: token-granular matching must recompute strictly
    fewer prompt tokens than whole-page matching (the in-row control)
    and must actually have reused tokens via fork_partial."""
    rec = cur.get("serve/prefix_partial")
    if rec is None:
        return []  # absence is check_required_rows' problem
    c = _counters(rec)
    failures = []
    tok, whole = c.get("prefill_tok"), c.get("whole_page_tok")
    if tok is None or whole is None:
        failures.append("serve/prefix_partial: derived field lacks "
                        "prefill_tok=/whole_page_tok= counters")
    elif not tok < whole:
        failures.append(
            f"serve/prefix_partial: prefill_tok={tok} not strictly below "
            f"whole-page control {whole}")
    else:
        print(f"ok    serve/prefix_partial: prefill_tok {tok} < {whole} "
              f"(whole-page control; {c.get('tok_shared')} tokens reused "
              f"over {c.get('hits')} partial hits)")
    if not c.get("tok_shared", 0) > 0:
        failures.append("serve/prefix_partial: tok_shared="
                        f"{c.get('tok_shared')} — fork_partial never ran")
    return failures


def check_ttft_interleaved(cur: dict, decode_ceil: float = 1.15) -> list:
    """Chunked-prefill interleaving must improve long-prompt TTFT over
    the serial control without slowing the decode calls themselves
    (mean wall time per decode call — occupancy-blind on purpose:
    interleaving runs extra single-occupancy decode waves by design).
    bench_serve raises in-run at a 1.10 per-call ratio; the JSON gate
    re-asserts a looser 1.15 so a stale artifact still fails while CI
    noise does not."""
    rec = cur.get("serve/ttft_interleaved")
    if rec is None:
        return []  # absence is check_required_rows' problem
    c = _counters(rec)
    failures = []
    speedup = c.get("ttft_speedup")
    if speedup is None:
        failures.append(
            "serve/ttft_interleaved: derived field lacks ttft_speedup=")
    elif not speedup > 1.0:
        failures.append(
            f"serve/ttft_interleaved: chunked TTFT not better than the "
            f"serial control (speedup={speedup})")
    else:
        print(f"ok    serve/ttft_interleaved: TTFT {speedup:.2f}x better "
              f"than serial admission")
    ratio = c.get("decode_call_ratio")
    if ratio is None:
        failures.append("serve/ttft_interleaved: derived field lacks "
                        "decode_call_ratio=")
    elif ratio > decode_ceil:
        failures.append(
            f"serve/ttft_interleaved: decode calls {ratio}x slower than "
            f"the serial control (ceiling {decode_ceil}; "
            f"{c.get('decode_us_call')}us vs "
            f"{c.get('serial_decode_us_call')}us per call)")
    else:
        print(f"ok    serve/ttft_interleaved: decode call ratio {ratio} "
              f"<= {decode_ceil}")
    return failures


def check_spec_accept(cur: dict, floor: float = 0.8) -> list:
    """The speculative rows must keep their drafted-token acceptance rate:
    it is deterministic for the bench's fixed greedy workload, so a drop
    means the coarse-propagator draft or the verify/rollback contract
    broke. (Spec tok/s is tracked by the ordinary timing gate; spec is
    NOT required to beat fused plain decode — see bench_spec's module
    docstring for why that comparison inverted at bench scale.)"""
    failures = []
    for fam in ("attn", "ssm", "hybrid"):
        name = f"serve/spec_{fam}"
        rec = cur.get(name)
        if rec is None:
            continue  # absence is check_required_rows' problem
        accept = _counters(rec).get("accept")
        if accept is None:
            failures.append(f"{name}: derived field lacks accept= counter")
        elif accept < floor:
            failures.append(
                f"{name}: acceptance rate {accept} below floor {floor}")
        else:
            print(f"ok    {name}: acceptance rate {accept} >= {floor}")
    return failures


def check_fused_speedup(cur: dict) -> list:
    """The fused paged-decode rows must beat their gathered control: each
    ``serve/decode_*_fused`` row measures both paths in the same process
    and records ``speedup`` (fused tok/s over gathered tok/s). No
    tolerance — a fused path that fails to win has lost its reason to
    exist, and bench_serve itself raises in-run (surfacing as a _meta
    ERROR) so this is a second line of defence against stale JSON."""
    failures = []
    for fam in ("attn", "ssm", "hybrid"):
        name = f"serve/decode_{fam}_fused"
        rec = cur.get(name)
        if rec is None:
            continue  # absence is check_required_rows' problem
        c = _counters(rec)
        speedup = c.get("speedup")
        if speedup is None:
            failures.append(f"{name}: derived field lacks speedup= counter")
        elif not speedup > 1.0:
            failures.append(
                f"{name}: fused path not faster than gathered "
                f"(speedup={speedup}, fused={c.get('tok_s')} tok/s vs "
                f"gathered={c.get('gathered_tok_s')} tok/s)")
        else:
            print(f"ok    {name}: fused beats gathered "
                  f"({speedup:.2f}x, {c.get('tok_s')} vs "
                  f"{c.get('gathered_tok_s')} tok/s)")
    return failures


def check_traffic_goodput(cur: dict, floor: float = 0.5) -> list:
    """The Poisson-traffic row must show (a) zero lost requests — under
    overload every arrival either finishes or fails with an error, none
    may silently vanish — and (b) under-capacity goodput above a floor.
    bench_traffic raises in-run at 0.9; the JSON gate re-asserts a looser
    0.5 so a stale artifact or a pathological host still fails."""
    rec = cur.get("serve/traffic_goodput")
    if rec is None:
        return []  # absence is check_required_rows' problem
    c = _counters(rec)
    failures = []
    if c.get("lost") != 0:
        failures.append(
            f"serve/traffic_goodput: lost={c.get('lost')} requests "
            f"neither finished nor failed (must be 0)")
    else:
        print("ok    serve/traffic_goodput: lost=0 (every arrival "
              "accounted for)")
    lo = c.get("goodput_lo")
    if lo is None:
        failures.append(
            "serve/traffic_goodput: derived field lacks goodput_lo=")
    elif lo < floor:
        failures.append(
            f"serve/traffic_goodput: under-capacity goodput {lo} below "
            f"floor {floor}")
    else:
        print(f"ok    serve/traffic_goodput: goodput_lo {lo} >= {floor} "
              f"(goodput_hi {c.get('goodput_hi')}, "
              f"rejected {c.get('rejected')}, "
              f"preempted {c.get('preempted')})")
    return failures


def check_obs_overhead(cur: dict, ceil: float = 0.05) -> list:
    """The observability stack must stay within its throughput-cost
    contract: bench_traffic measures decode tok/s with the obs stack
    enabled vs disabled (single engine, hot-path toggle alternated per
    decode wave, trimmed-mean wave times) and raises in-run above 3%;
    the JSON gate re-asserts a looser 5% so a stale artifact still
    fails while CI timer noise does not."""
    rec = cur.get("serve/obs_overhead")
    if rec is None:
        return []  # absence is check_required_rows' problem
    c = _counters(rec)
    overhead = c.get("overhead")
    if overhead is None:
        return ["serve/obs_overhead: derived field lacks overhead="]
    if overhead > ceil:
        return [f"serve/obs_overhead: observability costs "
                f"{overhead:.1%} of engine throughput (ceiling "
                f"{ceil:.0%}; {c.get('tok_s_on')} vs "
                f"{c.get('tok_s_off')} tok/s)"]
    print(f"ok    serve/obs_overhead: {overhead:.1%} <= {ceil:.0%} "
          f"({c.get('tok_s_on')} tok/s on vs {c.get('tok_s_off')} off)")
    return []


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument("--tol", type=float, default=2.0,
                    help="fail when us_per_call > tol * baseline")
    ap.add_argument("--prefixes", default="kernels/,serve/",
                    help="comma-separated name prefixes gated on timing")
    ap.add_argument("--required", choices=("all", "gated"), default="all",
                    help="'gated' limits REQUIRED_ROWS to the gated "
                         "prefixes (for partial --only bench runs)")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)
    prefixes = tuple(p for p in args.prefixes.split(",") if p)

    failures = []
    gated = 0
    for name, rec in sorted(cur.items()):
        if name.startswith("_meta/") and str(rec["derived"]).startswith(
                "ERROR"):
            failures.append(f"{name}: crashed ({rec['derived']})")
    failures += check_prefix_sharing(cur)
    failures += check_prefix_partial(cur)
    failures += check_ttft_interleaved(cur)
    failures += check_fused_speedup(cur)
    failures += check_spec_accept(cur)
    failures += check_traffic_goodput(cur)
    failures += check_obs_overhead(cur)
    failures += check_required_rows(
        cur, prefixes if args.required == "gated" else None)
    for name, brec in sorted(base.items()):
        if not name.startswith(prefixes):
            continue
        crec = cur.get(name)
        if crec is None:
            print(f"WARN  {name}: missing from current run (not gated)")
            continue
        gated += 1
        b, c = float(brec["us_per_call"]), float(crec["us_per_call"])
        ratio = c / b if b > 0 else float("inf")
        status = "FAIL" if ratio > args.tol else "ok"
        print(f"{status:5s} {name}: {c:.1f}us vs baseline {b:.1f}us "
              f"({ratio:.2f}x, tol {args.tol:.1f}x)")
        if ratio > args.tol:
            failures.append(f"{name}: {ratio:.2f}x slower than baseline")
    if gated == 0:
        # a row rename or an --only typo must not disable the gate silently
        failures.append(f"no baseline rows matched prefixes {prefixes} in "
                        f"the current run — gate measured nothing")

    if failures:
        print("\nregression gate FAILED:")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print("\nregression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
