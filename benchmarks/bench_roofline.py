"""Roofline summary: aggregates the dry-run JSON records into the
EXPERIMENTS.md §Roofline table (one row per arch x shape x mesh)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import CSV

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def run(csv: CSV):
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        csv.add("roofline/none", 0.0, "run launch/dryrun.py first")
        return
    n_ok = n_fail = n_skip = 0
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        tag = f"roofline/{rec['arch']}.{rec['shape']}.{rec['mesh']}"
        if rec["status"] == "skip":
            n_skip += 1
            continue
        if rec["status"] != "ok":
            n_fail += 1
            csv.add(tag, 0.0, "FAIL")
            continue
        n_ok += 1
        r = rec["roofline"]
        t_step = max(r["t_compute"], r["t_memory"], r["t_collective"])
        csv.add(tag, t_step * 1e6,
                f"bottleneck={r['bottleneck']};useful={r['useful_ratio']:.2f};"
                f"roofline_frac={r['peak_fraction']*100:.1f}%")
    csv.add("roofline/summary", 0.0,
            f"ok={n_ok};fail={n_fail};skip={n_skip}")
