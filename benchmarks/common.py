"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import sys
import time
from typing import List, Tuple

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.base import (MGRITConfig, ModelConfig, OptimizerConfig,
                                RunConfig, ShapeConfig)


def tiny_rcfg(*, family="encoder", n_layers=16, d_model=64, lp=True,
              cf=2, levels=2, fwd=2, bwd=1, n_open=0, n_close=0,
              pad_to=0, h=1.0, seq=32, batch=8, steps=200,
              lr=0.05, opt="sgd", vocab=256, check_every=50) -> RunConfig:
    model = ModelConfig(
        name="bench", family=family, n_layers=n_layers, d_model=d_model,
        n_heads=4, n_kv_heads=4, d_ff=2 * d_model, vocab_size=vocab,
        n_dec_layers=n_layers if family == "encdec" else 0,
        act="gelu", norm="layernorm")
    mgrit = MGRITConfig(enabled=lp, cf=cf, levels=levels, fwd_iters=fwd,
                        bwd_iters=bwd, n_open=n_open, n_close=n_close,
                        pad_to=pad_to or n_layers - n_open - n_close, h=h,
                        check_every=check_every)
    return RunConfig(
        model=model, mgrit=mgrit,
        optimizer=OptimizerConfig(name=opt, lr=lr, warmup_steps=10,
                                  total_steps=steps),
        shape=ShapeConfig("bench", "train", seq, batch))


def time_call(fn, *args, iters: int = 3) -> float:
    """Median wall-time (us) of a blocking call."""
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


class CSV:
    def __init__(self):
        self.rows: List[Tuple[str, float, str]] = []

    def add(self, name: str, us_per_call: float, derived: str):
        self.rows.append((name, us_per_call, derived))

    def emit(self):
        for name, us, derived in self.rows:
            print(f"{name},{us:.1f},{derived}")
