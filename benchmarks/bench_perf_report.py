"""§Perf report: baseline vs optimized roofline per hillclimb cell,
including the Pallas-flash modeled memory term.

The 'pallas_flash' rows substitute the measured attention-scope HBM bytes
with the Pallas kernel's analytic traffic: flash reads/writes Q,K,V,O once
per evaluation, so bytes_flash = scope_attn_flops * 2 / S (derivation in
EXPERIMENTS.md §Perf) — grounded in the *measured* per-scope flop count, so
the number of MGRIT evaluations is taken from the compiled program, not
assumed."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import CSV
from repro.analysis.roofline import HBM_BW, PEAK_FLOPS

PERF_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "perf")


def flash_modeled_memory(rec) -> float:
    """Memory term (s) with attention replaced by the Pallas flash kernel.

    Preferred: bytes_flash = scope_attn_flops * 2/S (flash touches QKVO once
    per evaluation; evaluations counted from measured scope flops).
    Fallback when XLA decomposed the GQA einsum without dot ops (flops
    land untagged): bytes_flash = scope_attn_bytes * 2*hd/(3*S) — dense
    attention makes ~3 HBM passes over the (S,S) logits, flash touches
    ~(2/hd) of that per pass."""
    from repro.configs import registry
    r = rec["roofline"]
    cd = r.get("coll_detail") or {}
    attn_f = cd.get("scope_attn_core_flops", 0.0)
    attn_b = cd.get("scope_attn_core_fused_bytes", 0.0)
    if not attn_b:
        return r["t_memory"]
    seq = {"train_4k": 4096, "prefill_32k": 32768}.get(rec["shape"], 4096)
    if attn_f > 0:
        flash_bytes = attn_f * 2.0 / seq
    else:
        cfg = registry.get_config(rec["arch"], rec["shape"]).model
        flash_bytes = attn_b * (2.0 * cfg.resolved_head_dim) / (3.0 * seq)
    flash_bytes = min(flash_bytes, attn_b)
    total_bytes = r["hlo_bytes"] - attn_b + flash_bytes
    return max(total_bytes, 0.0) / HBM_BW


def run(csv: CSV):
    files = sorted(glob.glob(os.path.join(PERF_DIR, "*.json")))
    if not files:
        csv.add("perf/none", 0.0, "run launch/perf.py first")
        return
    for f in files:
        rec = json.load(open(f))
        if rec.get("status") != "ok":
            csv.add(f"perf/{os.path.basename(f)}", 0.0, "FAIL")
            continue
        r = rec["roofline"]
        t_mem_flash = flash_modeled_memory(rec)
        t_step = max(r["t_compute"], r["t_memory"], r["t_collective"])
        t_step_flash = max(r["t_compute"], t_mem_flash, r["t_collective"])
        useful = r["model_flops"] / r["chips"]
        frac = useful / max(t_step, 1e-30) / PEAK_FLOPS
        frac_flash = useful / max(t_step_flash, 1e-30) / PEAK_FLOPS
        csv.add(f"perf/{rec['arch']}.{rec['shape']}.{rec['variant']}",
                t_step * 1e6,
                f"t_comp={r['t_compute']*1e3:.0f}ms;"
                f"t_mem={r['t_memory']*1e3:.0f}ms;"
                f"t_coll={r['t_collective']*1e3:.0f}ms;"
                f"roof={frac*100:.2f}%;"
                f"mem_pallasflash={t_mem_flash*1e3:.0f}ms;"
                f"roof_pallasflash={frac_flash*100:.2f}%")
