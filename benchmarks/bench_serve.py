"""Serving benchmarks: the continuous-batching engine vs the seed design.

Reports, for a small decoder LM on this host:
  serve/prefill_chunked   chunked prefill us/call + tokens/sec (128-tok
                          prompt in ONE jitted call)
  serve/prefill_loop      seed-style per-token prefill loop over the same
                          prompt (O(T) jitted calls) — the speedup is the
                          tentpole claim
  serve/decode_paged      steady-state paged decode tokens/sec at batch 8
  serve/decode_dense      dense-cache decode tokens/sec at batch 8
  serve/decode_ssm_paged  steady-state paged decode, SSM (mamba1) backend —
                          recurrent state served from snapshot pages
                          through the same CacheBackend protocol
  serve/decode_hybrid_paged  same for the hybrid (zamba2-style) backend
  serve/decode_{attn,ssm,hybrid}_fused  fused paged-decode kernels
                          (page-walking attention / compact-commit SSM /
                          sort-free sampling, ``ServeEngine(fused=True)``,
                          the default) vs the gathered dense-view engine
                          on production-width page tables — derived
                          carries ``gathered_tok_s`` and ``speedup``,
                          and the run fails if fused stops winning
  serve/decode_mesh_tp2   steady-state paged decode on a 2-device host
                          mesh (dp1xtp2: weights TP over 'model', page
                          pools over 'data') — run in a subprocess with
                          XLA_FLAGS=--xla_force_host_platform_device_count=2
                          since the parent's jax is already initialized;
                          the derived field carries the mesh label
  serve/ttft              time-to-first-token through the scheduler
  serve/e2e_sched         mixed-length queue end-to-end through the
                          scheduler: aggregate generated tokens/sec
  serve/prefix_shared     10-request common-prefix workload WITH the
                          prefix trie / copy-on-write pages: derived
                          reports prefill tokens computed + pages
                          allocated (must be strictly below baseline)
  serve/prefix_baseline   same workload with sharing disabled
  serve/prefix_partial    token-granular sharing (fork_partial over a
                          published partial tail page) vs whole-page
                          matching, in-row: partial must recompute
                          strictly fewer prompt tokens
  serve/ttft_interleaved  long-prompt TTFT admitted while another
                          request decodes, chunked-prefill interleaving
                          (budget 64) vs the stalled serial control
                          in-row: TTFT must improve and decode
                          throughput must stay within 3%
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

from benchmarks.common import CSV, time_call
from repro.configs.base import (MGRITConfig, ModelConfig, OptimizerConfig,
                                RunConfig, ShapeConfig)
from repro.launch.hostdev import force_host_device_count
from repro.models import transformer
from repro.serve.engine import Request, ServeEngine

PROMPT = 128
BATCH = 8
MAX_LEN = 256


def serve_rcfg(**model_kw) -> RunConfig:
    kw = dict(name="bench_serve", family="decoder", n_layers=8,
              d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
              vocab_size=256, act="silu", norm="rmsnorm",
              head_dim=16, dtype="float32")
    kw.update(model_kw)
    return RunConfig(
        model=ModelConfig(**kw),
        mgrit=MGRITConfig(enabled=True, cf=2, levels=2, n_open=1, n_close=1,
                          pad_to=2),
        optimizer=OptimizerConfig(),
        shape=ShapeConfig("serve", "decode", MAX_LEN, BATCH))


def ssm_rcfg() -> RunConfig:
    from repro.configs.base import SSMConfig
    return serve_rcfg(name="bench_serve_ssm", family="ssm", n_layers=6,
                      ssm=SSMConfig(version=1, d_state=16, d_conv=4))


def hybrid_rcfg() -> RunConfig:
    from repro.configs.base import SSMConfig
    return serve_rcfg(name="bench_serve_hybrid", family="hybrid",
                      n_layers=6, hybrid_attn_every=3,
                      ssm=SSMConfig(version=2, d_state=16, d_conv=4,
                                    headdim=16))


def mesh_probe(dp: int = 1, tp: int = 2) -> dict:
    """Steady-state mesh-sharded paged decode throughput — called inside
    a subprocess whose host platform was forced to ``dp * tp`` devices
    (see :func:`_mesh_row`). Greedy output is conformance-checked against
    a single-device engine on the same weights before timing."""
    n = dp * tp
    if jax.device_count() < n:
        # an operator-set --xla_force_host_platform_device_count wins
        # over _mesh_row's (hostdev.force_host_device_count contract)
        raise RuntimeError(
            f"mesh_probe needs {n} devices, have {jax.device_count()} "
            "(XLA_FLAGS already forced a smaller host device count?)")
    mesh = jax.make_mesh((dp, tp), ("data", "model"),
                         devices=jax.devices()[:n])
    rcfg = serve_rcfg()
    params = transformer.init_model(jax.random.PRNGKey(0), rcfg)
    kw = dict(max_len=MAX_LEN, max_batch=BATCH, page_size=16)
    eng = ServeEngine(rcfg, params, mesh=mesh, **kw)
    solo = ServeEngine(rcfg, params, **kw)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, 256, size=24).astype(np.int32),
                    max_new_tokens=8) for _ in range(BATCH)]
    got = eng.generate([Request(prompt=r.prompt.copy(), max_new_tokens=8)
                        for r in reqs])
    ref = solo.generate(reqs)
    if any(not np.array_equal(a.output, b.output)
           for a, b in zip(got, ref, strict=True)):
        raise RuntimeError("mesh decode diverged from single-device")
    tok_s = eng.throughput_probe(BATCH, steps=16)
    return {"tok_s": tok_s, "mesh": f"dp{dp}xtp{tp}",
            "devices": int(jax.device_count())}


def _mesh_row(csv: CSV, dp: int = 1, tp: int = 2) -> None:
    """serve/decode_mesh_tp2 in a subprocess: jax in THIS process is
    already initialized with one CPU device, so the forced multi-device
    host platform must come up in a fresh interpreter."""
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    # appends to (not replaces) any operator-set XLA_FLAGS so this row
    # is timed under the same XLA settings as the sibling serve rows
    force_host_device_count(dp * tp, env=env)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    code = ("import json; from benchmarks.bench_serve import mesh_probe; "
            f"print('RESULT ' + json.dumps(mesh_probe({dp}, {tp})))")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=1200)
    if r.returncode != 0:
        raise RuntimeError(f"mesh bench subprocess failed: "
                           f"{r.stderr[-2000:]}")
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    csv.add(f"serve/decode_mesh_tp{tp}", BATCH / out["tok_s"] * 1e6,
            f"tok_s={out['tok_s']:.0f};mesh={out['mesh']};"
            f"devices={out['devices']}")


def run(csv: CSV):
    rcfg = serve_rcfg()
    params = transformer.init_model(jax.random.PRNGKey(0), rcfg)
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=BATCH,
                      page_size=16)

    # -- chunked prefill: one jitted call for the whole prompt -------------
    tps = eng.prefill_probe(PROMPT, batch=1)
    csv.add("serve/prefill_chunked", PROMPT / tps * 1e6,
            f"tok_s={tps:.0f}")

    # -- seed-style per-token prefill loop (the replaced design) ----------
    step = jax.jit(lambda p, c, t: transformer.decode_step(p, c, t, rcfg))
    toks = np.ones((1, 1), np.int32)

    def loop_prefill():
        cache = transformer.init_cache(rcfg, 1, MAX_LEN)
        lg = None
        for _ in range(PROMPT):
            lg, cache = step(params, cache, toks)
        return lg

    us_loop = time_call(loop_prefill, iters=2)
    csv.add("serve/prefill_loop", us_loop,
            f"tok_s={PROMPT / (us_loop * 1e-6):.0f}")

    # -- steady-state decode ----------------------------------------------
    tps_paged = eng.throughput_probe(BATCH, steps=16)
    csv.add("serve/decode_paged", BATCH / tps_paged * 1e6,
            f"tok_s={tps_paged:.0f}")
    tps_dense = eng.throughput_probe(BATCH, steps=16, paged=False)
    csv.add("serve/decode_dense", BATCH / tps_dense * 1e6,
            f"tok_s={tps_dense:.0f}")

    # -- SSM + hybrid through the same CacheBackend protocol ---------------
    # (recurrent-state snapshot pages; previously these families decoded
    # through a greedy-only dense fallback with no paging at all)
    fam_weights = {"attn": (rcfg, params)}
    for fam, fam_rcfg in (("ssm", ssm_rcfg()), ("hybrid", hybrid_rcfg())):
        fparams = transformer.init_model(jax.random.PRNGKey(1), fam_rcfg)
        fam_weights[fam] = (fam_rcfg, fparams)
        feng = ServeEngine(fam_rcfg, fparams, max_len=MAX_LEN,
                           max_batch=BATCH, page_size=16)
        tps_fam = feng.throughput_probe(BATCH, steps=16)
        csv.add(f"serve/decode_{fam}_paged", BATCH / tps_fam * 1e6,
                f"tok_s={tps_fam:.0f}")

    # -- fused paged-decode kernels vs the gathered dense-view path --------
    # Same weights, production-width page tables (a full MAX_LEN of
    # capacity per slot, as a real admission plans), decode mid-sequence:
    # the fused engine walks only the live power-of-two page bucket and
    # commits the compact snapshot window, while the gathered engine
    # re-materializes every page column per step. Greedy conformance
    # (bitwise) lives in tests/test_kernels_paged.py; this row gates the
    # perf claim — a fused row that stops beating gathered fails the run
    # (and check_regression fails CI on the emitted speedup field).
    table_pages = MAX_LEN // 16
    for fam in ("attn", "ssm", "hybrid"):
        f_rcfg, f_params = fam_weights[fam]
        kw = dict(max_len=MAX_LEN, max_batch=BATCH, page_size=16)
        f_eng = ServeEngine(f_rcfg, f_params, **kw)
        g_eng = ServeEngine(f_rcfg, f_params, fused=False, **kw)
        tok_f = f_eng.throughput_probe(BATCH, steps=16,
                                       table_pages=table_pages)
        tok_g = g_eng.throughput_probe(BATCH, steps=16,
                                       table_pages=table_pages)
        csv.add(f"serve/decode_{fam}_fused", BATCH / tok_f * 1e6,
                f"tok_s={tok_f:.0f};gathered_tok_s={tok_g:.0f};"
                f"speedup={tok_f / tok_g:.2f}")
        if tok_f <= tok_g:
            raise RuntimeError(
                f"fused {fam} decode is not faster than the gathered "
                f"path: {tok_f:.0f} vs {tok_g:.0f} tok/s")

    # -- mesh-sharded decode (dp1xtp2 host mesh, subprocess) ---------------
    _mesh_row(csv, dp=1, tp=2)

    # -- scheduler: TTFT + mixed-queue end-to-end -------------------------
    rng = np.random.default_rng(0)
    warm = [Request(prompt=rng.integers(0, 256, size=PROMPT).astype(
        np.int32), max_new_tokens=4) for _ in range(2)]
    eng.generate(warm)                       # compile prefill/decode traces
    sched = eng.scheduler
    for k in sched.stats:
        sched.stats[k] = type(sched.stats[k])(0)
    reqs = [Request(prompt=rng.integers(0, 256, size=int(rng.integers(
                16, PROMPT))).astype(np.int32),
                    max_new_tokens=16) for _ in range(2 * BATCH)]
    t0 = time.perf_counter()
    out = eng.generate(reqs)
    wall = time.perf_counter() - t0
    ttft = float(np.mean([r.ttft_s for r in out]))
    gen_tokens = int(sum(len(r.output) for r in out))
    thr = sched.throughput()
    csv.add("serve/ttft", ttft * 1e6, f"mean_over={len(out)}")
    csv.add("serve/e2e_sched", wall / gen_tokens * 1e6,
            f"gen_tok_s={gen_tokens / wall:.0f};"
            f"prefill_tok_s={thr['prefill_tok_s']:.0f};"
            f"decode_tok_s={thr['decode_tok_s']:.0f}")

    # -- shared-prefix workload: trie + copy-on-write vs no sharing -------
    # 10 requests share a 96-token system prompt (6 pages) with short
    # private tails. The engine publishes the prefix pages on first
    # prefill; later admissions map them read-only and compute only their
    # tail, so both prefill tokens computed and pages allocated must land
    # strictly below the no-sharing baseline (ISSUE 2 acceptance).
    common = rng.integers(0, 256, size=96).astype(np.int32)
    tails = [rng.integers(0, 256, size=int(rng.integers(4, 12))).astype(
        np.int32) for _ in range(10)]

    def prefix_workload(share: bool):
        eng2 = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=4,
                           page_size=16, share_prefix=share)
        reqs2 = [Request(prompt=np.concatenate([common, t]),
                         max_new_tokens=8) for t in tails]
        # warm every prefill bucket the timed run can hit — full prompt
        # (128) for the baseline, tail-remainder buckets (8, 16) for the
        # shared run — plus decode; with sharing on this also publishes
        # the system prefix (the steady-state cache-warm case). The short
        # prompts are < page_size, so they publish nothing themselves.
        warm_long = np.concatenate([common, tails[0]])
        eng2.generate([Request(prompt=warm_long, max_new_tokens=2)])
        for n in (6, 11):                        # buckets 8 and 16
            eng2.generate([Request(prompt=warm_long[-n:],
                                   max_new_tokens=2)])
        for k in eng2.scheduler.stats:           # warm traces, reset stats
            eng2.scheduler.stats[k] = type(eng2.scheduler.stats[k])(0)
        t0 = time.perf_counter()
        eng2.generate(reqs2)
        wall2 = time.perf_counter() - t0
        s = eng2.scheduler.stats
        return wall2, s["prefill_tokens"], s["pages_allocated"]

    w_base, tok_base, pg_base = prefix_workload(share=False)
    w_shared, tok_shared, pg_shared = prefix_workload(share=True)
    csv.add("serve/prefix_baseline", w_base * 1e6,
            f"prefill_tok={tok_base};pages={pg_base}")
    csv.add("serve/prefix_shared", w_shared * 1e6,
            f"prefill_tok={tok_shared};pages={pg_shared}")
    if not (tok_shared < tok_base and pg_shared < pg_base):
        raise RuntimeError(
            f"prefix sharing failed to reduce work: tokens "
            f"{tok_shared} vs {tok_base}, pages {pg_shared} vs {pg_base}")

    # -- token-granular partial sharing vs whole-page matching ------------
    # One finished 105-token prompt publishes 6 full pages plus a 9-token
    # partial tail page. 8 followers share the 6 full pages AND the first
    # 7 tokens of the tail page: whole-page matching recomputes those 7
    # tokens (plus each private tail), token-granular reuses them via
    # ``CacheBackend.fork_partial``. The gate is an exact counter, not a
    # timing: partial-on must recompute strictly fewer prompt tokens.
    seed_prompt = rng.integers(0, 256, size=96 + 9).astype(np.int32)
    follows = [np.concatenate([
        seed_prompt[:96 + 7],
        rng.integers(0, 256, size=int(rng.integers(4, 10))).astype(
            np.int32)]) for _ in range(8)]

    def partial_workload(partial: bool):
        eng3 = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=4,
                           page_size=16, partial_prefix=partial)
        # publish the prefix (and, with partial on, its tail page), then
        # warm the follower remainder buckets (8 and 16) and decode
        eng3.generate([Request(prompt=seed_prompt.copy(),
                               max_new_tokens=4)])
        for n in (6, 14):
            eng3.generate([Request(
                prompt=rng.integers(0, 256, size=n).astype(np.int32),
                max_new_tokens=2)])
        for k in eng3.scheduler.stats:
            eng3.scheduler.stats[k] = type(eng3.scheduler.stats[k])(0)
        t0 = time.perf_counter()
        eng3.generate([Request(prompt=f.copy(), max_new_tokens=8)
                       for f in follows])
        wall3 = time.perf_counter() - t0
        s = eng3.scheduler.stats
        return (wall3, s["prefill_tokens"], s["prefix_partial_hits"],
                s["prefix_partial_tokens_shared"])

    w_whole, tok_whole, _, _ = partial_workload(partial=False)
    w_part, tok_part, hits, tok_reused = partial_workload(partial=True)
    csv.add("serve/prefix_partial", w_part * 1e6,
            f"prefill_tok={tok_part};tok_shared={tok_reused};hits={hits};"
            f"whole_page_tok={tok_whole}")
    if not (tok_part < tok_whole and tok_reused > 0):
        raise RuntimeError(
            f"token-granular sharing failed to reduce recomputation: "
            f"{tok_part} vs whole-page {tok_whole} prefill tokens "
            f"({tok_reused} reused)")

    # -- chunked prefill: long-prompt TTFT while decode is live -----------
    # A 129-token prompt admitted while a short request decodes: serial
    # admission pays one 256-wide bucket call before anything else moves;
    # chunked ingest (budget 64) pays 64+64+8-wide calls with decode
    # waves in between. Gates: chunked TTFT strictly better, and the
    # mean decode-call wall time within 3% of the serial control.
    # (Per-CALL time, not tokens/sec-of-call-time: the chunked arm runs
    # extra decode calls at single occupancy while the long prompt
    # ingests — by design — so tokens per call-second under-reads even
    # when each call is exactly as fast.)
    long_prompt = rng.integers(0, 256, size=PROMPT + 1).astype(np.int32)
    short_prompt = rng.integers(0, 256, size=8).astype(np.int32)

    def interleaved_probe(chunk: int):
        eng4 = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                           page_size=16, share_prefix=False,
                           prefill_chunk_tokens=chunk)
        sched4 = eng4.scheduler

        def once():
            short = eng4._submit_one(
                Request(prompt=short_prompt.copy(), max_new_tokens=48))
            sched4.step()                  # short is admitted + decoding
            long_h = eng4._submit_one(
                Request(prompt=long_prompt.copy(), max_new_tokens=4))
            sched4.run()
            assert short.error is None and long_h.error is None
            return long_h

        once()                             # compile both paths
        ttfts, decs = [], []
        for _ in range(5):
            for k in sched4.stats:
                sched4.stats[k] = type(sched4.stats[k])(0)
            ttfts.append(once().ttft)
            s4 = sched4.stats
            decs.append(s4["decode_s"] / max(s4["decode_steps"], 1))
        # min over repeats for the per-call cost: the decode kernel is
        # identical in both arms, so any repeat-to-repeat spread is host
        # jitter and the best observation is the honest estimate.
        return float(np.median(ttfts)), float(min(decs))

    ttft_c, dec_c = interleaved_probe(chunk=64)
    ttft_s, dec_s = interleaved_probe(chunk=0)
    csv.add("serve/ttft_interleaved", ttft_c * 1e6,
            f"ttft_serial_us={ttft_s * 1e6:.0f};chunk=64;"
            f"ttft_speedup={ttft_s / ttft_c:.2f};"
            f"decode_us_call={dec_c * 1e6:.0f};"
            f"serial_decode_us_call={dec_s * 1e6:.0f};"
            f"decode_call_ratio={dec_c / dec_s:.3f}")
    if ttft_c >= ttft_s:
        raise RuntimeError(
            f"chunked interleaving failed to improve long-prompt TTFT: "
            f"{ttft_c * 1e3:.1f}ms vs serial {ttft_s * 1e3:.1f}ms")
    if dec_c > 1.10 * dec_s:
        raise RuntimeError(
            f"chunked interleaving slowed decode calls: "
            f"{dec_c * 1e6:.0f}us vs serial {dec_s * 1e6:.0f}us per call "
            f"(ceiling 1.10x)")
