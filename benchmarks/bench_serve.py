"""Serving benchmarks: the continuous-batching engine vs the seed design.

Reports, for a small decoder LM on this host:
  serve/prefill_chunked   chunked prefill us/call + tokens/sec (128-tok
                          prompt in ONE jitted call)
  serve/prefill_loop      seed-style per-token prefill loop over the same
                          prompt (O(T) jitted calls) — the speedup is the
                          tentpole claim
  serve/decode_paged      steady-state paged decode tokens/sec at batch 8
  serve/decode_dense      dense-cache decode tokens/sec at batch 8
  serve/ttft              time-to-first-token through the scheduler
  serve/e2e_sched         mixed-length queue end-to-end through the
                          scheduler: aggregate generated tokens/sec
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import CSV, time_call
from repro.configs.base import (MGRITConfig, ModelConfig, OptimizerConfig,
                                RunConfig, ShapeConfig)
from repro.models import transformer
from repro.serve.engine import Request, ServeEngine

PROMPT = 128
BATCH = 8
MAX_LEN = 256


def serve_rcfg() -> RunConfig:
    model = ModelConfig(name="bench_serve", family="decoder", n_layers=8,
                        d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                        vocab_size=256, act="silu", norm="rmsnorm",
                        head_dim=16, dtype="float32")
    return RunConfig(
        model=model,
        mgrit=MGRITConfig(enabled=True, cf=2, levels=2, n_open=1, n_close=1,
                          pad_to=2),
        optimizer=OptimizerConfig(),
        shape=ShapeConfig("serve", "decode", MAX_LEN, BATCH))


def run(csv: CSV):
    rcfg = serve_rcfg()
    params = transformer.init_model(jax.random.PRNGKey(0), rcfg)
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=BATCH,
                      page_size=16)

    # -- chunked prefill: one jitted call for the whole prompt -------------
    tps = eng.prefill_probe(PROMPT, batch=1)
    csv.add("serve/prefill_chunked", PROMPT / tps * 1e6,
            f"tok_s={tps:.0f}")

    # -- seed-style per-token prefill loop (the replaced design) ----------
    step = jax.jit(lambda p, c, t: transformer.decode_step(p, c, t, rcfg))
    toks = np.ones((1, 1), np.int32)

    def loop_prefill():
        cache = transformer.init_cache(rcfg, 1, MAX_LEN)
        lg = None
        for _ in range(PROMPT):
            lg, cache = step(params, cache, toks)
        return lg

    us_loop = time_call(loop_prefill, iters=2)
    csv.add("serve/prefill_loop", us_loop,
            f"tok_s={PROMPT / (us_loop * 1e-6):.0f}")

    # -- steady-state decode ----------------------------------------------
    tps_paged = eng.throughput_probe(BATCH, steps=16)
    csv.add("serve/decode_paged", BATCH / tps_paged * 1e6,
            f"tok_s={tps_paged:.0f}")
    tps_dense = eng.throughput_probe(BATCH, steps=16, paged=False)
    csv.add("serve/decode_dense", BATCH / tps_dense * 1e6,
            f"tok_s={tps_dense:.0f}")

    # -- scheduler: TTFT + mixed-queue end-to-end -------------------------
    rng = np.random.default_rng(0)
    warm = [Request(prompt=rng.integers(0, 256, size=PROMPT).astype(
        np.int32), max_new_tokens=4) for _ in range(2)]
    eng.generate(warm)                       # compile prefill/decode traces
    sched = eng.scheduler
    for k in sched.stats:
        sched.stats[k] = type(sched.stats[k])(0)
    reqs = [Request(prompt=rng.integers(0, 256, size=int(rng.integers(
                16, PROMPT))).astype(np.int32),
                    max_new_tokens=16) for _ in range(2 * BATCH)]
    t0 = time.perf_counter()
    out = eng.generate(reqs)
    wall = time.perf_counter() - t0
    ttft = float(np.mean([r.ttft_s for r in out]))
    gen_tokens = int(sum(len(r.output) for r in out))
    thr = sched.throughput()
    csv.add("serve/ttft", ttft * 1e6, f"mean_over={len(out)}")
    csv.add("serve/e2e_sched", wall / gen_tokens * 1e6,
            f"gen_tok_s={gen_tokens / wall:.0f};"
            f"prefill_tok_s={thr['prefill_tok_s']:.0f};"
            f"decode_tok_s={thr['decode_tok_s']:.0f}")
