"""Serving benchmarks: the continuous-batching engine vs the seed design.

Reports, for a small decoder LM on this host:
  serve/prefill_chunked   chunked prefill us/call + tokens/sec (128-tok
                          prompt in ONE jitted call)
  serve/prefill_loop      seed-style per-token prefill loop over the same
                          prompt (O(T) jitted calls) — the speedup is the
                          tentpole claim
  serve/decode_paged      steady-state paged decode tokens/sec at batch 8
  serve/decode_dense      dense-cache decode tokens/sec at batch 8
  serve/decode_ssm_paged  steady-state paged decode, SSM (mamba1) backend —
                          recurrent state served from snapshot pages
                          through the same CacheBackend protocol
  serve/decode_hybrid_paged  same for the hybrid (zamba2-style) backend
  serve/ttft              time-to-first-token through the scheduler
  serve/e2e_sched         mixed-length queue end-to-end through the
                          scheduler: aggregate generated tokens/sec
  serve/prefix_shared     10-request common-prefix workload WITH the
                          prefix trie / copy-on-write pages: derived
                          reports prefill tokens computed + pages
                          allocated (must be strictly below baseline)
  serve/prefix_baseline   same workload with sharing disabled
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import CSV, time_call
from repro.configs.base import (MGRITConfig, ModelConfig, OptimizerConfig,
                                RunConfig, ShapeConfig)
from repro.models import transformer
from repro.serve.engine import Request, ServeEngine

PROMPT = 128
BATCH = 8
MAX_LEN = 256


def serve_rcfg(**model_kw) -> RunConfig:
    kw = dict(name="bench_serve", family="decoder", n_layers=8,
              d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
              vocab_size=256, act="silu", norm="rmsnorm",
              head_dim=16, dtype="float32")
    kw.update(model_kw)
    return RunConfig(
        model=ModelConfig(**kw),
        mgrit=MGRITConfig(enabled=True, cf=2, levels=2, n_open=1, n_close=1,
                          pad_to=2),
        optimizer=OptimizerConfig(),
        shape=ShapeConfig("serve", "decode", MAX_LEN, BATCH))


def ssm_rcfg() -> RunConfig:
    from repro.configs.base import SSMConfig
    return serve_rcfg(name="bench_serve_ssm", family="ssm", n_layers=6,
                      ssm=SSMConfig(version=1, d_state=16, d_conv=4))


def hybrid_rcfg() -> RunConfig:
    from repro.configs.base import SSMConfig
    return serve_rcfg(name="bench_serve_hybrid", family="hybrid",
                      n_layers=6, hybrid_attn_every=3,
                      ssm=SSMConfig(version=2, d_state=16, d_conv=4,
                                    headdim=16))


def run(csv: CSV):
    rcfg = serve_rcfg()
    params = transformer.init_model(jax.random.PRNGKey(0), rcfg)
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=BATCH,
                      page_size=16)

    # -- chunked prefill: one jitted call for the whole prompt -------------
    tps = eng.prefill_probe(PROMPT, batch=1)
    csv.add("serve/prefill_chunked", PROMPT / tps * 1e6,
            f"tok_s={tps:.0f}")

    # -- seed-style per-token prefill loop (the replaced design) ----------
    step = jax.jit(lambda p, c, t: transformer.decode_step(p, c, t, rcfg))
    toks = np.ones((1, 1), np.int32)

    def loop_prefill():
        cache = transformer.init_cache(rcfg, 1, MAX_LEN)
        lg = None
        for _ in range(PROMPT):
            lg, cache = step(params, cache, toks)
        return lg

    us_loop = time_call(loop_prefill, iters=2)
    csv.add("serve/prefill_loop", us_loop,
            f"tok_s={PROMPT / (us_loop * 1e-6):.0f}")

    # -- steady-state decode ----------------------------------------------
    tps_paged = eng.throughput_probe(BATCH, steps=16)
    csv.add("serve/decode_paged", BATCH / tps_paged * 1e6,
            f"tok_s={tps_paged:.0f}")
    tps_dense = eng.throughput_probe(BATCH, steps=16, paged=False)
    csv.add("serve/decode_dense", BATCH / tps_dense * 1e6,
            f"tok_s={tps_dense:.0f}")

    # -- SSM + hybrid through the same CacheBackend protocol ---------------
    # (recurrent-state snapshot pages; previously these families decoded
    # through a greedy-only dense fallback with no paging at all)
    for row, fam_rcfg in (("serve/decode_ssm_paged", ssm_rcfg()),
                          ("serve/decode_hybrid_paged", hybrid_rcfg())):
        fparams = transformer.init_model(jax.random.PRNGKey(1), fam_rcfg)
        feng = ServeEngine(fam_rcfg, fparams, max_len=MAX_LEN,
                           max_batch=BATCH, page_size=16)
        tps_fam = feng.throughput_probe(BATCH, steps=16)
        csv.add(row, BATCH / tps_fam * 1e6, f"tok_s={tps_fam:.0f}")

    # -- scheduler: TTFT + mixed-queue end-to-end -------------------------
    rng = np.random.default_rng(0)
    warm = [Request(prompt=rng.integers(0, 256, size=PROMPT).astype(
        np.int32), max_new_tokens=4) for _ in range(2)]
    eng.generate(warm)                       # compile prefill/decode traces
    sched = eng.scheduler
    for k in sched.stats:
        sched.stats[k] = type(sched.stats[k])(0)
    reqs = [Request(prompt=rng.integers(0, 256, size=int(rng.integers(
                16, PROMPT))).astype(np.int32),
                    max_new_tokens=16) for _ in range(2 * BATCH)]
    t0 = time.perf_counter()
    out = eng.generate(reqs)
    wall = time.perf_counter() - t0
    ttft = float(np.mean([r.ttft_s for r in out]))
    gen_tokens = int(sum(len(r.output) for r in out))
    thr = sched.throughput()
    csv.add("serve/ttft", ttft * 1e6, f"mean_over={len(out)}")
    csv.add("serve/e2e_sched", wall / gen_tokens * 1e6,
            f"gen_tok_s={gen_tokens / wall:.0f};"
            f"prefill_tok_s={thr['prefill_tok_s']:.0f};"
            f"decode_tok_s={thr['decode_tok_s']:.0f}")

    # -- shared-prefix workload: trie + copy-on-write vs no sharing -------
    # 10 requests share a 96-token system prompt (6 pages) with short
    # private tails. The engine publishes the prefix pages on first
    # prefill; later admissions map them read-only and compute only their
    # tail, so both prefill tokens computed and pages allocated must land
    # strictly below the no-sharing baseline (ISSUE 2 acceptance).
    common = rng.integers(0, 256, size=96).astype(np.int32)
    tails = [rng.integers(0, 256, size=int(rng.integers(4, 12))).astype(
        np.int32) for _ in range(10)]

    def prefix_workload(share: bool):
        eng2 = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=4,
                           page_size=16, share_prefix=share)
        reqs2 = [Request(prompt=np.concatenate([common, t]),
                         max_new_tokens=8) for t in tails]
        # warm every prefill bucket the timed run can hit — full prompt
        # (128) for the baseline, tail-remainder buckets (8, 16) for the
        # shared run — plus decode; with sharing on this also publishes
        # the system prefix (the steady-state cache-warm case). The short
        # prompts are < page_size, so they publish nothing themselves.
        warm_long = np.concatenate([common, tails[0]])
        eng2.generate([Request(prompt=warm_long, max_new_tokens=2)])
        for n in (6, 11):                        # buckets 8 and 16
            eng2.generate([Request(prompt=warm_long[-n:],
                                   max_new_tokens=2)])
        for k in eng2.scheduler.stats:           # warm traces, reset stats
            eng2.scheduler.stats[k] = type(eng2.scheduler.stats[k])(0)
        t0 = time.perf_counter()
        eng2.generate(reqs2)
        wall2 = time.perf_counter() - t0
        s = eng2.scheduler.stats
        return wall2, s["prefill_tokens"], s["pages_allocated"]

    w_base, tok_base, pg_base = prefix_workload(share=False)
    w_shared, tok_shared, pg_shared = prefix_workload(share=True)
    csv.add("serve/prefix_baseline", w_base * 1e6,
            f"prefill_tok={tok_base};pages={pg_base}")
    csv.add("serve/prefix_shared", w_shared * 1e6,
            f"prefill_tok={tok_shared};pages={pg_shared}")
    if not (tok_shared < tok_base and pg_shared < pg_base):
        raise RuntimeError(
            f"prefix sharing failed to reduce work: tokens "
            f"{tok_shared} vs {tok_base}, pages {pg_shared} vs {pg_base}")
