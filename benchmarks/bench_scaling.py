"""Paper Fig. 6/7/8: strong scaling of layer-parallel vs depth N,
coarsening factor cf, levels L, and device count P.

One CPU core cannot time true parallel execution, so this benchmark does
what the roofline methodology prescribes: it *measures* the cost of one
Euler step Phi (the unit of work) and combines it with the exact MGRIT
critical-path operation count per device. The counts are the same algebra
as the paper's speedup model; the output reproduces the shapes of
Fig. 6-8 (speedup grows with N, cf, L).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from benchmarks.common import CSV, tiny_rcfg, time_call
from repro.core import lp as lp_mod
from repro.models.blocks import block_kind, init_block
from repro.models.layers import rope_freqs


def phi_units_serial(N: int) -> float:
    """Phi-equivalents on the critical path of one serial train step
    (fwd N, bwd ~2N for the VJP sweep)."""
    return 3.0 * N


def vcycle_units(N: int, cf: int, P: int, levels: int,
                 distributed_coarse: bool = True) -> float:
    """Phi-equivalents on the critical path of ONE V-cycle at a level with
    N points distributed over P devices.

    distributed_coarse=True models the paper's MPI implementation (every
    level keeps its points distributed until fewer than P remain) — this is
    what reproduces Fig. 8 left (more levels => better scaling). Our GSPMD
    build replicates coarser levels by default (MGRITSpec.shard_levels),
    for which pass False: extra levels then COST critical-path time — the
    measured reason the assigned configs use L=2/L=3 (see DESIGN.md §5)."""
    if levels <= 1 or N % cf:
        return float(N)  # exact serial solve
    per_dev = N / (cf * max(P, 1))
    relax = (3.0 * (cf - 1) + 2.0) * per_dev      # FCF + C re-eval
    final_f = (cf - 1) * per_dev
    P_next = min(P, max(N // (cf * cf), 1)) if distributed_coarse else 1
    coarse = vcycle_units(N // cf, cf, P_next, levels - 1,
                          distributed_coarse)
    return relax + final_f + coarse


def lp_units(N: int, cf: int, P: int, levels: int, fwd: int, bwd: int,
             distributed_coarse: bool = True) -> float:
    init = N / cf                                  # FMG coarse init
    vc = vcycle_units(N, cf, P, levels, distributed_coarse)
    fwd_cost = init + fwd * vc
    bwd_cost = 2.0 * (init + bwd * vc)
    grads = 2.0 * N / P                            # layer-parallel vjps
    return fwd_cost + bwd_cost + grads


def measure_phi_us() -> float:
    rcfg = tiny_rcfg(n_layers=4)
    cfg = rcfg.model
    kind = block_kind(cfg)
    params = init_block(jax.random.PRNGKey(0), cfg, kind)
    z = jax.random.normal(jax.random.PRNGKey(1), (8, 32, cfg.d_model),
                          jnp.bfloat16)
    rope = rope_freqs(cfg.resolved_head_dim, cfg.rope_theta,
                      jnp.arange(32, dtype=jnp.int32))
    static = lp_mod.LPStatic(cfg=cfg, mgrit=rcfg.mgrit, kind=kind,
                             causal=False)
    step = jax.jit(lambda p, zz: lp_mod.make_fwd_step(
        static, {"rope": rope})({"params": p,
                                 "gate": jnp.ones(())}, zz, 1.0))
    return time_call(step, params, z)


def run(csv: CSV):
    phi_us = measure_phi_us()

    # Fig. 8 right: depth sweep at fixed P
    for N in (64, 128, 256, 512, 1024):
        for P in (2, 4, 8, 16, 32):
            s = phi_units_serial(N) / lp_units(N, 4, P, 2, 1, 1)
            csv.add(f"scaling/N{N}_P{P}_cf4_L2",
                    phi_us * lp_units(N, 4, P, 2, 1, 1),
                    f"speedup={s:.2f}")
    # Fig. 8 middle: cf sweep (N=1024, L=2, paper MC setup 2fwd/1bwd)
    for cf in (2, 4, 8, 16):
        s = phi_units_serial(1024) / lp_units(1024, cf, 16, 2, 2, 1)
        csv.add(f"scaling/cf{cf}_N1024_P16_L2", 0.0, f"speedup={s:.2f}")
    # Fig. 8 left: level sweep (cf=2, N=1024) — paper's distributed-coarse
    # implementation vs our replicated-coarse GSPMD default
    for L in (2, 3, 4, 5):
        s = phi_units_serial(1024) / lp_units(1024, 2, 16, L, 2, 1)
        s_rep = phi_units_serial(1024) / lp_units(1024, 2, 16, L, 2, 1,
                                                  distributed_coarse=False)
        csv.add(f"scaling/L{L}_N1024_P16_cf2", 0.0,
                f"speedup={s:.2f};replicated_coarse={s_rep:.2f}")
    # Fig. 7: MT-style depth scaling, cf=4 L=2 2fwd/1bwd
    for N in (80, 160, 320):
        for P in (4, 16, 64):
            s = phi_units_serial(N) / lp_units(N, 4, P, 2, 2, 1)
            csv.add(f"scaling/mt_N{N}_P{P}", 0.0, f"speedup={s:.2f}")
    csv.add("scaling/phi_unit", phi_us, "measured_block_step")
