"""Paper Fig. 9: data-parallel x layer-parallel split under a fixed chip
budget — time per batch is convex in the DP degree.

Uses the measured Phi unit + the MGRIT critical-path model (bench_scaling)
for compute, and an alpha-beta model for the DP gradient all-reduce
(ring: 2 * bytes * (dp-1)/dp / bw)."""
from __future__ import annotations

from benchmarks.bench_scaling import lp_units, measure_phi_us
from benchmarks.common import CSV

PARAM_BYTES = 2 * 64e6          # 64M-param bf16 exemplar (paper: 64L GPT)
LINK_BW = 50e9                  # bytes/s
ALPHA = 5e-6                    # latency per hop (s)
N_LAYERS = 64


def time_per_batch(total: int, dp: int, phi_s: float, batch: int) -> float:
    lp = total // dp
    per_dev_batch = batch / dp
    compute = lp_units(N_LAYERS, 4, lp, 2, 1, 1) * phi_s * per_dev_batch
    allreduce = 2 * PARAM_BYTES * (dp - 1) / dp / LINK_BW + ALPHA * dp
    return compute + allreduce


def run(csv: CSV):
    phi_s = measure_phi_us() * 1e-6 / 8.0   # per batch-element
    for total in (16, 32, 64):
        best = None
        for dp in (1, 2, 4, 8, 16, 32, 64):
            if dp > total:
                continue
            t = time_per_batch(total, dp, phi_s, batch=total)
            csv.add(f"dp_lp/G{total}_dp{dp}", t * 1e6,
                    f"lp={total // dp}")
            if best is None or t < best[1]:
                best = (dp, t)
        csv.add(f"dp_lp/G{total}_optimum", best[1] * 1e6,
                f"dp*={best[0]};convex=True")
