"""Benchmark harness — one benchmark per paper table/figure.

  bench_convergence    Fig. 3/4   serial vs LP vs switched loss dynamics
  bench_indicator      Fig. 5     convergence-factor indicator
  bench_scaling        Fig. 6/7/8 strong scaling vs N / cf / L / P
  bench_dp_lp          Fig. 9     DP x LP split convexity
  bench_finetune_delta Table 1    fine-tune delta serial vs switched
  bench_buffer         Fig. 12    buffer layers
  bench_kernels        (ours)     Pallas kernels vs oracles
  bench_roofline       (ours)     dry-run roofline aggregation

Prints ``name,us_per_call,derived`` CSV.
Usage: PYTHONPATH=src python -m benchmarks.run [--only NAME] [--fast]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

sys.path.insert(0, "src")

from benchmarks.common import CSV  # noqa: E402

ALL = ("kernels", "roofline", "perf_report", "scaling", "dp_lp",
       "convergence", "indicator", "buffer", "finetune_delta")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--fast", action="store_true",
                    help="skip the training-dynamics benchmarks")
    args = ap.parse_args(argv)

    names = [n for n in ALL if not args.only or n in args.only.split(",")]
    if args.fast:
        names = [n for n in names
                 if n in ("kernels", "roofline", "perf_report", "scaling",
                          "dp_lp")]
    csv = CSV()
    for name in names:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        try:
            mod.run(csv)
            csv.add(f"_meta/{name}", (time.time() - t0) * 1e6, "ok")
        except Exception as e:
            traceback.print_exc()
            csv.add(f"_meta/{name}", (time.time() - t0) * 1e6,
                    f"ERROR={type(e).__name__}")
    print("name,us_per_call,derived")
    csv.emit()


if __name__ == "__main__":
    main()
