"""Benchmark harness — one benchmark per paper table/figure.

  bench_convergence    Fig. 3/4   serial vs LP vs switched loss dynamics
  bench_indicator      Fig. 5     convergence-factor indicator
  bench_scaling        Fig. 6/7/8 strong scaling vs N / cf / L / P
  bench_dp_lp          Fig. 9     DP x LP split convexity
  bench_finetune_delta Table 1    fine-tune delta serial vs switched
  bench_buffer         Fig. 12    buffer layers
  bench_kernels        (ours)     Pallas kernels vs oracles
  bench_roofline       (ours)     dry-run roofline aggregation
  bench_serve          (ours)     continuous-batching serve engine
  bench_traffic        (ours)     Poisson-arrival goodput under overload
  bench_spec           (ours)     coarse-propagator speculative decoding

Prints ``name,us_per_call,derived`` CSV; ``--emit-json PATH`` also writes
the rows as JSON for the CI regression gate (benchmarks.check_regression).
Usage: PYTHONPATH=src python -m benchmarks.run [--only NAME] [--fast]
           [--emit-json BENCH_ci.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

sys.path.insert(0, "src")

from benchmarks.common import CSV  # noqa: E402

ALL = ("kernels", "roofline", "perf_report", "scaling", "dp_lp", "serve",
       "traffic", "spec", "convergence", "indicator", "buffer",
       "finetune_delta")

FAST = ("kernels", "roofline", "perf_report", "scaling", "dp_lp", "serve",
        "traffic", "spec")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--fast", action="store_true",
                    help="skip the training-dynamics benchmarks")
    ap.add_argument("--emit-json", default="",
                    help="also write results to this JSON file")
    args = ap.parse_args(argv)

    names = [n for n in ALL if not args.only or n in args.only.split(",")]
    if args.fast:
        names = [n for n in names if n in FAST]
    csv = CSV()
    for name in names:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        try:
            mod.run(csv)
            csv.add(f"_meta/{name}", (time.time() - t0) * 1e6, "ok")
        except Exception as e:
            traceback.print_exc()
            csv.add(f"_meta/{name}", (time.time() - t0) * 1e6,
                    f"ERROR={type(e).__name__}")
    print("name,us_per_call,derived")
    csv.emit()
    if args.emit_json:
        payload = {n: {"us_per_call": us, "derived": derived}
                   for n, us, derived in csv.rows}
        with open(args.emit_json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)


if __name__ == "__main__":
    main()
