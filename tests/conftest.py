"""Shared test configuration.

Registers hypothesis profiles when the optional dependency is present:
CI runs the property suites with the fixed, derandomized ``ci`` profile
(set ``HYPOTHESIS_PROFILE=ci``) so tier-1 results are reproducible; the
default local profile keeps hypothesis's random exploration.
"""
import os

try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci", derandomize=True, max_examples=30, deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "default"))
except ImportError:           # optional dev dependency (see requirements-dev)
    pass
