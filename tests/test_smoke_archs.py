"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs — for all 10 assigned archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.reduce import reduce_config
from repro.models import transformer

ARCHS = [a for a in registry.ARCH_IDS if a not in (
    "bert128", "gpt2_nanogpt", "vit32", "mc_tiny", "mt_marian")]
SEQ, BATCH = 16, 2


def make_batch(rcfg, key):
    cfg = rcfg.model
    ks = jax.random.split(key, 4)
    toks = jax.random.randint(ks[0], (BATCH, SEQ), 0, cfg.vocab_size)
    batch = {"tokens": toks,
             "labels": jax.random.randint(ks[1], (BATCH, SEQ), 0,
                                          cfg.vocab_size)}
    if cfg.family == "encdec":
        if cfg.frontend == "audio":
            batch["src_embeds"] = jax.random.normal(
                ks[2], (BATCH, SEQ, cfg.d_model)) * 0.1
        else:
            batch["src_tokens"] = jax.random.randint(
                ks[2], (BATCH, SEQ), 0, cfg.vocab_size)
    if cfg.frontend == "vision":
        batch["mm_embeds"] = jax.random.normal(
            ks[3], (BATCH, 4, cfg.d_model)) * 0.1
    return batch


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("mode", ["serial", "lp"])
def test_forward_and_grad(arch, mode, rng):
    rcfg = reduce_config(registry.get_config(arch))
    params = transformer.init_model(rng, rcfg)
    batch = make_batch(rcfg, jax.random.fold_in(rng, 1))

    def loss(p):
        l, _ = transformer.loss_fn(p, batch, rcfg, mode=mode)
        return l

    val, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert np.isfinite(float(val)), f"{arch}/{mode}: loss NaN"
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in flat), \
        f"{arch}/{mode}: NaN grads"
    # gradients reach the embedding and at least one real trunk layer
    gsum = sum(float(jnp.sum(jnp.abs(g))) for g in flat)
    assert gsum > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_logits_shape(arch, rng):
    rcfg = reduce_config(registry.get_config(arch))
    cfg = rcfg.model
    params = transformer.init_model(rng, rcfg)
    batch = make_batch(rcfg, jax.random.fold_in(rng, 2))
    logits, _ = jax.jit(
        lambda p, b: transformer.forward(p, b, rcfg, mode="serial"))(
        params, batch)
    expect_s = SEQ + (4 if cfg.frontend == "vision" else 0)
    assert logits.shape == (BATCH, expect_s, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))


@pytest.mark.parametrize("arch", ["deepseek_7b", "falcon_mamba_7b",
                                  "zamba2_1p2b", "seamless_m4t_v2",
                                  "qwen3_moe_235b"])
def test_decode_step(arch, rng):
    rcfg = reduce_config(registry.get_config(arch))
    cfg = rcfg.model
    params = transformer.init_model(rng, rcfg)
    cache = transformer.init_cache(rcfg, BATCH, 32)
    toks = jnp.ones((BATCH, 1), jnp.int32)
    xa = None
    if cfg.family == "encdec":
        xa = jax.random.normal(rng, (BATCH, 8, cfg.d_model),
                               jnp.dtype(cfg.dtype)) * 0.1
    step = jax.jit(lambda p, c, t: transformer.decode_step(
        p, c, t, rcfg, xa=xa))
    logits, cache2 = step(params, cache, toks)
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))
    logits3, _ = step(params, cache2, toks)
    assert np.all(np.isfinite(np.asarray(logits3, dtype=np.float32)))


def test_decode_matches_prefill_deepseek(rng):
    """Autoregressive decode reproduces teacher-forced logits (cache
    correctness oracle)."""
    rcfg = reduce_config(registry.get_config("deepseek_7b"))
    cfg = rcfg.model
    params = transformer.init_model(rng, rcfg)
    T = 8
    toks = jax.random.randint(rng, (BATCH, T), 0, cfg.vocab_size)
    full_logits, _ = jax.jit(
        lambda p, b: transformer.forward(p, b, rcfg, mode="serial"))(
        params, {"tokens": toks})
    cache = transformer.init_cache(rcfg, BATCH, T)
    step = jax.jit(lambda p, c, t: transformer.decode_step(p, c, t, rcfg))
    outs = []
    for t in range(T):
        lg, cache = step(params, cache, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full_logits, np.float32),
        rtol=2e-2, atol=2e-2)
