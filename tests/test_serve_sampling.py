"""Per-request sampling on the paged serve path.

Oracles: (a) temperature 0 is token-for-token the greedy argmax path,
(b) top-k / top-p masks provably exclude out-of-set tokens (checked both
on the mask primitives and end-to-end via degenerate settings that force
greedy), (c) the same (request, seed) reproduces the same stream in any
slot and any batch composition, (d) sampling params are per-slot: mixed
greedy/sampled batches decode lock-step.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import (MGRITConfig, ModelConfig, OptimizerConfig,
                                RunConfig, ShapeConfig)
from repro.launch.steps import (apply_top_k, apply_top_k_top_p, apply_top_p,
                                sample_tokens)
from repro.models import transformer
from repro.serve.engine import Request, ServeEngine

pytestmark = pytest.mark.serve

VOCAB = 64
MAX_LEN = 32


@pytest.fixture(scope="module")
def setup():
    rcfg = RunConfig(
        model=ModelConfig(name="smp", family="decoder", n_layers=8,
                          d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                          vocab_size=VOCAB, act="gelu", norm="layernorm",
                          dtype="float32"),
        mgrit=MGRITConfig(enabled=True, cf=2, levels=2, fwd_iters=1,
                          bwd_iters=1, n_open=1, n_close=1, pad_to=2),
        optimizer=OptimizerConfig(),
        shape=ShapeConfig("smp", "train", 16, 4))
    params = transformer.init_model(jax.random.PRNGKey(0), rcfg)
    return rcfg, params


# -- mask primitives --------------------------------------------------------


def test_top_k_mask_excludes_out_of_set():
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(4, 32)).astype(np.float32)
    k = np.array([1, 3, 8, 0], np.int32)          # 0 disables
    out = np.asarray(apply_top_k(logits, k))
    for b in range(4):
        keep = out[b] > -1e29
        if k[b] == 0:
            assert keep.all()
            continue
        assert keep.sum() == k[b]                 # distinct floats: exact
        kth = np.sort(logits[b])[-k[b]]
        assert (logits[b][keep] >= kth).all()
        assert (logits[b][~keep] < kth).all()
        np.testing.assert_array_equal(out[b][keep], logits[b][keep])


def test_top_p_mask_is_minimal_nucleus():
    rng = np.random.default_rng(1)
    logits = rng.normal(size=(3, 16)).astype(np.float32) * 3
    p = np.array([0.5, 0.9, 1.0], np.float32)
    out = np.asarray(apply_top_p(logits, p))
    for b in range(3):
        keep = out[b] > -1e29
        probs = np.exp(logits[b] - logits[b].max())
        probs /= probs.sum()
        order = np.argsort(-logits[b])
        # kept set is a prefix of the descending-probability order ...
        ranks = np.empty(16, int)
        ranks[order] = np.arange(16)
        assert ranks[keep].max() == keep.sum() - 1
        # ... that reaches mass p, and is minimal (dropping the last kept
        # token would fall below p)
        mass = probs[keep].sum()
        assert mass >= min(float(p[b]), 1.0) - 1e-6
        if keep.sum() > 1:
            assert mass - probs[order[keep.sum() - 1]] < p[b]
        assert keep[order[0]]                      # argmax always survives


def test_fused_mask_matches_sequential_reference():
    """The single-sort hot-path mask == apply_top_p(apply_top_k(x))."""
    rng = np.random.default_rng(3)
    logits = rng.normal(size=(6, 48)).astype(np.float32) * 2
    k = np.array([0, 1, 4, 16, 48, 7], np.int32)
    p = np.array([1.0, 0.3, 0.7, 0.05, 0.99, 0.5], np.float32)
    ref = np.asarray(apply_top_p(apply_top_k(logits, k), p))
    fused = np.asarray(apply_top_k_top_p(logits, k, p))
    np.testing.assert_array_equal(fused > -1e29, ref > -1e29)
    np.testing.assert_allclose(np.where(fused > -1e29, fused, 0.0),
                               np.where(ref > -1e29, ref, 0.0), rtol=1e-6)


def test_sample_tokens_respects_masks_and_greedy():
    rng = np.random.default_rng(2)
    logits = np.asarray(rng.normal(size=(2, VOCAB)), np.float32)
    greedy = logits.argmax(-1)
    temps0 = np.zeros((2,), np.float32)
    ones = np.ones((2,), np.float32)
    zeros_i = np.zeros((2,), np.int32)
    # temperature 0 -> exact argmax whatever the other params say
    tok = np.asarray(sample_tokens(logits, temps0,
                                   np.full((2,), 5, np.int32),
                                   np.full((2,), 0.3, np.float32),
                                   zeros_i, zeros_i))
    np.testing.assert_array_equal(tok, greedy)
    # top_k=1 is greedy even at high temperature
    tok = np.asarray(sample_tokens(logits, 5 * ones,
                                   np.ones((2,), np.int32), ones,
                                   zeros_i + 7, zeros_i))
    np.testing.assert_array_equal(tok, greedy)
    # sampled tokens always inside the top-k set
    k = 4
    topk_sets = np.argsort(-logits, axis=-1)[:, :k]
    for counter in range(50):
        tok = np.asarray(sample_tokens(
            logits, ones, np.full((2,), k, np.int32), ones,
            zeros_i + 3, np.full((2,), counter, np.int32)))
        for b in range(2):
            assert tok[b] in topk_sets[b]


# -- engine end-to-end ------------------------------------------------------


def test_temperature_zero_matches_greedy_engine(setup):
    """Paged decode with temperature=0 (even with top-k/top-p set) is
    token-for-token the existing greedy path."""
    rcfg, params = setup
    prompt = np.array([5, 9, 3, 7, 2, 11], np.int32)
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                      page_size=4)
    ref = eng.generate([Request(prompt=prompt, max_new_tokens=6)])[0]
    got = eng.generate([Request(prompt=prompt, max_new_tokens=6,
                                temperature=0.0, top_k=3, top_p=0.5,
                                seed=9)])[0]
    np.testing.assert_array_equal(got.output, ref.output)


def test_same_seed_same_output_in_any_slot(setup):
    """Seeded sampling depends only on (seed, tokens generated), not on
    slot placement or what else shares the batch."""
    rcfg, params = setup
    target = Request(prompt=np.array([4, 2, 9, 1], np.int32),
                     max_new_tokens=6, temperature=1.0, top_k=16,
                     top_p=0.95, seed=123)
    solo = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=3,
                       page_size=4)
    out_solo = solo.generate([Request(**vars(target))])[0]
    # same request submitted last among fillers lands in a different slot
    crowd = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=3,
                        page_size=4)
    fillers = [Request(prompt=np.array([7, 7, 3], np.int32),
                       max_new_tokens=8, temperature=0.7, seed=i)
               for i in range(2)]
    out_crowd = crowd.generate(fillers + [Request(**vars(target))])[-1]
    np.testing.assert_array_equal(out_solo.output, out_crowd.output)


def test_mixed_greedy_sampled_batch_keeps_greedy_exact(setup):
    """A sampled neighbour in the batch must not perturb a greedy slot."""
    rcfg, params = setup
    gprompt = np.array([1, 2, 3, 4, 5, 6], np.int32)
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                      page_size=4)
    ref = eng.generate([Request(prompt=gprompt, max_new_tokens=6)])[0]
    mixed = eng.generate([
        Request(prompt=gprompt, max_new_tokens=6),
        Request(prompt=np.array([9, 8, 7], np.int32), max_new_tokens=6,
                temperature=1.3, top_k=8, seed=5)])
    np.testing.assert_array_equal(mixed[0].output, ref.output)
    assert ((mixed[1].output >= 0) & (mixed[1].output < VOCAB)).all()


def test_bad_sampling_params_rejected(setup):
    rcfg, params = setup
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                      page_size=4)
    for bad in (dict(temperature=-0.1), dict(top_k=-1), dict(top_p=0.0),
                dict(top_p=1.5)):
        with pytest.raises(ValueError):
            eng.generate([Request(prompt=np.array([1, 2], np.int32),
                                  max_new_tokens=2, **bad)])
