"""Smoke tests for the paper's own architectures (reduced configs):
BERT-128L (encoder MLM), GPT2-nanoGPT (decoder + buffer layers + Dt=1/16),
ViT (encoder + patch stub), MC (tiny encoder), MT (Marian enc-dec)."""

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.reduce import reduce_config
from repro.models import transformer

PAPER = ["bert128", "gpt2_nanogpt", "vit32", "mc_tiny", "mt_marian"]
SEQ, BATCH = 16, 2


def make_batch(rcfg, key):
    cfg = rcfg.model
    ks = jax.random.split(key, 4)
    b = {"tokens": jax.random.randint(ks[0], (BATCH, SEQ), 0,
                                      cfg.vocab_size),
         "labels": jax.random.randint(ks[1], (BATCH, SEQ), 0,
                                      cfg.vocab_size)}
    if cfg.family == "encdec":
        b["src_tokens"] = jax.random.randint(ks[2], (BATCH, SEQ), 0,
                                             cfg.vocab_size)
    if cfg.frontend == "vision":
        b["mm_embeds"] = jax.random.normal(ks[3], (BATCH, 4, cfg.d_model)) \
            * 0.1
    return b


@pytest.mark.parametrize("arch", PAPER)
def test_paper_arch_forward_and_grad(arch):
    rcfg = reduce_config(registry.get_config(arch))
    key = jax.random.PRNGKey(0)
    params = transformer.init_model(key, rcfg)
    batch = make_batch(rcfg, jax.random.fold_in(key, 1))
    for mode in ("serial", "lp"):
        val, grads = jax.jit(jax.value_and_grad(
            lambda p, mode=mode: transformer.loss_fn(
                p, batch, rcfg, mode=mode)[0]))(
            params)
        assert np.isfinite(float(val)), f"{arch}/{mode}"
        assert all(np.all(np.isfinite(np.asarray(g, np.float32)))
                   for g in jax.tree.leaves(grads))


def test_gpt2_buffer_structure():
    """The paper's App. B GPT2 setup: 2+2 serial buffers, 16-layer
    ParallelNet with h = 1/16."""
    rcfg = registry.get_config("gpt2_nanogpt")
    assert rcfg.mgrit.n_open == 2 and rcfg.mgrit.n_close == 2
    assert abs(rcfg.mgrit.h - 1.0 / 16.0) < 1e-9
    plan = transformer.depth_plan(rcfg.model.n_layers, rcfg.mgrit)
    assert plan.n_mid_real == 16 and plan.n_mid_padded == 16
    # serial forward (dash in Table 3) + 1 parallel backward iteration
    assert rcfg.mgrit.fwd_iters == 0 and rcfg.mgrit.bwd_iters == 1


def test_bert128_depth():
    rcfg = registry.get_config("bert128")
    assert rcfg.model.n_layers == 128
    assert rcfg.mgrit.cf == 4 and rcfg.mgrit.levels == 2
