"""Seeded randomized fuzz over the scheduler/allocator state machine.

Tier-1 (no optional deps): random queues of prompts — mixed lengths,
shared prefixes, random eos/max_new/sampling params — drain through a
deliberately small page pool. Invariants: no dropped or duplicated rids,
output contracts hold, every page is accounted for afterwards, and the
pool returns to fully-free once the prefix cache is dropped.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import (MGRITConfig, ModelConfig, OptimizerConfig,
                                RunConfig, ShapeConfig)
from repro.models import transformer
from repro.serve.kv_pages import PageAllocator
from repro.serve.scheduler import Scheduler

pytestmark = pytest.mark.serve

VOCAB = 32
MAX_LEN = 24


@pytest.fixture(scope="module")
def setup():
    rcfg = RunConfig(
        model=ModelConfig(name="fuzz", family="decoder", n_layers=4,
                          d_model=16, n_heads=2, n_kv_heads=2, d_ff=32,
                          vocab_size=VOCAB, act="gelu", norm="layernorm",
                          dtype="float32"),
        mgrit=MGRITConfig(enabled=True, cf=2, levels=2, fwd_iters=1,
                          bwd_iters=1, n_open=1, n_close=1, pad_to=2),
        optimizer=OptimizerConfig(),
        shape=ShapeConfig("fuzz", "train", 16, 4))
    params = transformer.init_model(jax.random.PRNGKey(0), rcfg)
    return rcfg, params


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_scheduler_fuzz_drains_without_drops_or_leaks(setup, seed):
    rcfg, params = setup
    rng = np.random.default_rng(seed)
    # pool deliberately tight: fewer pages than the queue wants at once,
    # so admission stalls, waits, and prefix-cache eviction all trigger
    sched = Scheduler(rcfg, params, max_batch=3, page_size=4,
                      max_len=MAX_LEN, n_pages=1 + 18,
                      share_prefix=bool(seed % 2 == 0))
    common = rng.integers(0, VOCAB, size=8).astype(np.int32)
    rids = []
    for _ in range(12):
        if rng.random() < 0.5:     # shared-prefix population
            tail = rng.integers(0, VOCAB,
                                size=int(rng.integers(0, 5))).astype(np.int32)
            prompt = np.concatenate([common, tail])
        else:
            prompt = rng.integers(0, VOCAB, size=int(
                rng.integers(1, 14))).astype(np.int32)
        kw = {}
        if rng.random() < 0.4:
            kw = dict(temperature=float(rng.uniform(0.2, 1.5)),
                      top_k=int(rng.integers(0, 16)),
                      top_p=float(rng.uniform(0.1, 1.0)),
                      seed=int(rng.integers(0, 1000)))
        rids.append(sched.submit(
            prompt, int(rng.integers(1, 6)),
            eos_id=int(rng.integers(0, VOCAB)) if rng.random() < 0.3
            else None, **kw))
    done = sched.run()
    # completeness: every rid exactly once, nothing invented
    assert sorted(done.keys()) == sorted(rids)
    assert len(set(rids)) == len(rids)
    for rid in rids:
        req = done[rid]
        assert 1 <= len(req.out) <= req.max_new_tokens
        assert all(0 <= t < VOCAB for t in req.out)
        if req.eos_id is not None and len(req.out) < req.max_new_tokens:
            assert req.out[-1] == req.eos_id
    # resource accounting: slots empty, refcounts consistent, and the
    # pool is fully free once the prefix cache lets go of its pages
    assert sched.n_active == 0
    cached = sched.prefix.n_cached_pages if sched.prefix else 0
    assert sched.alloc.n_free + cached == sched.alloc.n_pages - 1
    sched.drop_prefix_cache()
    assert sched.alloc.n_free == sched.alloc.n_pages - 1
    assert all(r == 0 for r in sched.alloc._ref[1:])


@pytest.mark.parametrize("fam,seed", [("ssm_mamba1", 0), ("hybrid", 1),
                                      ("decoder", 2)])
def test_backend_conformance_fuzz_seeded(fam, seed):
    """Tier-1 seeded twin of the hypothesis CacheBackend conformance suite
    (test_properties.py): random mixed queues — shared prefixes, greedy +
    seeded sampling — through the paged engine must match the dense
    serial-forward oracle token-for-token on every backend."""
    from serve_oracle import dense_decode_oracle

    from repro.configs.base import SSMConfig
    from repro.serve.engine import Request, ServeEngine

    kw = dict(name=fam, family="decoder", n_layers=4, d_model=16,
              n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=VOCAB,
              act="gelu", norm="layernorm", dtype="float32")
    if fam == "ssm_mamba1":
        kw.update(family="ssm", ssm=SSMConfig(version=1, d_state=8,
                                              d_conv=3))
    elif fam == "hybrid":
        kw.update(family="hybrid", n_layers=5, hybrid_attn_every=2,
                  ssm=SSMConfig(version=2, d_state=8, d_conv=3,
                                headdim=16))
    rcfg = RunConfig(
        model=ModelConfig(**kw),
        mgrit=MGRITConfig(enabled=True, cf=2, levels=2, fwd_iters=1,
                          bwd_iters=1, n_open=1, n_close=1, pad_to=2),
        optimizer=OptimizerConfig(),
        shape=ShapeConfig(fam, "train", 16, 4))
    params = transformer.init_model(jax.random.PRNGKey(10 + seed), rcfg)
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                      page_size=4)
    step = jax.jit(lambda p, c, t: transformer.decode_step(p, c, t, rcfg))

    def oracle(req):
        return dense_decode_oracle(rcfg, params, step, req, MAX_LEN)

    rng = np.random.default_rng(seed)
    common = rng.integers(0, VOCAB, size=8).astype(np.int32)
    for _ in range(3):                     # waves reuse the prefix trie
        reqs = []
        for _ in range(int(rng.integers(1, 4))):
            tail = rng.integers(0, VOCAB, size=int(
                rng.integers(1, 6))).astype(np.int32)
            prompt = np.concatenate([common, tail]) \
                if rng.random() < 0.5 else tail
            sampled = rng.random() < 0.4
            reqs.append(Request(
                prompt=prompt, max_new_tokens=int(rng.integers(1, 5)),
                temperature=0.9 if sampled else 0.0,
                top_k=int(rng.choice([0, 8])) if sampled else 0,
                top_p=float(rng.choice([1.0, 0.9])) if sampled else 1.0,
                seed=int(rng.integers(0, 100))))
        for r in eng.generate(reqs):
            np.testing.assert_array_equal(r.output, oracle(r))
    assert eng.scheduler.n_active == 0


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_interleaved_vs_serial_differential_fuzz(setup, seed):
    """Tier-1 seeded twin of the PR-10 differential harness: random
    traffic — prompt lengths straddling page boundaries, streams
    cancelled mid-flight, preemption pressure under a small pool —
    driven step-by-step through the interleaved + token-granular engine
    and the serial whole-page control with the same deterministic
    cancel policy (cancel once ``len(out)`` reaches a per-request
    threshold; decode emits at most one token per wave, so both arms
    cancel at the identical emitted count). Every stream must match
    bitwise, the interleaved trace must show exactly one terminal event
    per rid, and the pool must drain."""
    from repro.obs.trace import lifecycle_violations

    rcfg, params = setup
    page = 4
    rng = np.random.default_rng(40 + seed)
    common = rng.integers(0, VOCAB, size=page + 2).astype(np.int32)
    specs = []                      # (prompt, max_new, kwargs, cancel_at)
    for i in range(10):
        n = int(rng.choice([page - 1, page, page + 1, 2 * page + 3,
                            int(rng.integers(1, 14))]))
        prompt = rng.integers(0, VOCAB, size=n).astype(np.int32)
        if rng.random() < 0.4:      # partial-tail fodder: shared prefix
            prompt = np.concatenate([common, prompt])[:MAX_LEN - 8]
        kw = dict(priority=int(rng.integers(0, 3)))
        if i % 3 == 0:
            kw.update(temperature=float(rng.uniform(0.3, 1.2)),
                      top_k=int(rng.choice([0, 8])),
                      top_p=float(rng.choice([1.0, 0.9])),
                      seed=int(rng.integers(0, 1000)))
        # i in {2, 7}: guaranteed mid-flight drops every seed; others random
        if i in (2, 7) or rng.random() < 0.2:
            max_new = int(rng.integers(4, 8))
            cancel_at = int(rng.integers(1, max_new - 1))
        else:
            max_new, cancel_at = int(rng.integers(2, 8)), None
        specs.append((prompt, max_new, kw, cancel_at))

    def drive(chunk_tokens, partial):
        sched = Scheduler(rcfg, params, max_batch=3, page_size=page,
                          max_len=MAX_LEN, n_pages=1 + 12,
                          partial_prefix=partial,
                          prefill_chunk_tokens=chunk_tokens)
        live = [(sched.submit_request(p, m, **kw), c)
                for p, m, kw, c in specs]
        while sched.step():
            for req, cancel_at in live:
                if cancel_at is not None and not req.done \
                        and len(req.out) >= cancel_at:
                    sched.cancel(req)
        return sched, live

    s_off, live_off = drive(chunk_tokens=0, partial=False)
    s_on, live_on = drive(chunk_tokens=5, partial=True)
    for i, ((a, ca), (b, cb)) in enumerate(
            zip(live_off, live_on, strict=True)):
        assert a.done and b.done and a.error is None and b.error is None
        np.testing.assert_array_equal(
            np.asarray(a.out, np.int32), np.asarray(b.out, np.int32),
            err_msg=f"request {i} diverged under interleaving")
        if ca is not None:          # both arms dropped at the same count,
            # mid-flight (first-emission waves carry prefill's first
            # token plus the same wave's decode token, so the threshold
            # can be crossed by one)
            assert len(a.out) == len(b.out)
            assert ca <= len(a.out) <= ca + 1 < specs[i][1]
    assert s_on.stats["prefill_chunks"] > 0
    assert lifecycle_violations(s_on.obs.trace.events()) == []
    for sched in (s_off, s_on):
        assert sched.n_active == 0
        sched.drop_prefix_cache()
        assert sched.alloc.n_free == sched.alloc.n_pages - 1
        assert all(r == 0 for r in sched.alloc._ref[1:])


def test_pool_too_small_fails_request_not_engine(setup):
    """Failure isolation (the old behavior raised RuntimeError out of
    `run()`, killing every in-flight request): a request that can never
    get enough pages finishes alone with ``error`` set, never spins, and
    the engine keeps serving feasible requests on the same pool."""
    rcfg, params = setup
    sched = Scheduler(rcfg, params, max_batch=2, page_size=4,
                      max_len=MAX_LEN, n_pages=1 + 2)   # 2 pages = 8 tokens
    big = sched.submit_request(np.arange(12, dtype=np.int32) % VOCAB,
                               max_new_tokens=4)
    assert big.failed and big.done and "pool" in big.error
    assert big.ttft is None and big.out == []
    assert sched.stats["requests_rejected"] == 1
    # a feasible request still succeeds on the same pool, same scheduler
    rid = sched.submit(np.array([1, 2, 3], np.int32), max_new_tokens=2)
    done = sched.run()
    assert len(done[rid].out) == 2
    assert done[big.rid] is big and big.failed


def test_allocator_fuzz_seeded():
    """Tier-1 allocator fuzz (the hypothesis twin lives in
    test_properties.py): random alloc/share/fork/free traffic never
    double-frees, never leaks, and refcounts stay non-negative."""
    rng = np.random.default_rng(7)
    for _ in range(20):
        n_pages = int(rng.integers(2, 20))
        a = PageAllocator(n_pages)
        live = {}                                # page -> model refcount
        for _ in range(200):
            op = rng.integers(0, 4)
            if op == 0:
                n = int(rng.integers(0, n_pages))
                free_before = a.n_free
                got = a.alloc(n)
                assert (got is None) == (n > free_before)
                if got is not None:
                    for p in got:
                        assert p not in live
                        live[p] = 1
            elif op == 1 and live:
                p = int(rng.choice(list(live)))
                a.share([p])
                live[p] += 1
            elif op == 2 and live:
                p = int(rng.choice(list(live)))
                q = a.fork(p)
                if live[p] == 1:
                    assert q == p
                elif q is not None:
                    assert q != p and q not in live
                    live[p] -= 1
                    live[q] = 1
            elif op == 3 and live:
                p = int(rng.choice(list(live)))
                a.free([p])
                live[p] -= 1
                if live[p] == 0:
                    del live[p]
            for p, r in live.items():
                assert a.refcount(p) == r and r > 0
            assert a.n_free == n_pages - 1 - len(live)
        for p, r in list(live.items()):
            a.free([p] * r)              # one free per outstanding reader
        assert a.n_free == n_pages - 1
        with pytest.raises(ValueError):
            a.free([1])                  # everything back -> double free
