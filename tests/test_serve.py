"""Serving-path tests: chunked prefill, paged KV cache, scheduler.

Correctness oracles: (a) chunked prefill == teacher-forced serial forward,
(b) the paged engine reproduces seed-style dense-cache decode
token-for-token, (c) a mixed-length request queue completes with no
dropped/duplicated outputs and batching never changes a request's tokens.
fp32 compute so greedy argmax comparisons are tie-free.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (MGRITConfig, ModelConfig, OptimizerConfig,
                                RunConfig, ShapeConfig)
from repro.models import transformer
from repro.serve.engine import Request, ServeEngine
from repro.serve.kv_pages import PageAllocator, pages_needed
from repro.serve.scheduler import Scheduler, bucket_len

pytestmark = pytest.mark.serve

VOCAB = 64
MAX_LEN = 32


def tiny_rcfg(**model_kw):
    kw = dict(name="srv", family="decoder", n_layers=8, d_model=32,
              n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=VOCAB,
              act="gelu", norm="layernorm", dtype="float32")
    kw.update(model_kw)
    return RunConfig(
        model=ModelConfig(**kw),
        mgrit=MGRITConfig(enabled=True, cf=2, levels=2, fwd_iters=1,
                          bwd_iters=1, n_open=1, n_close=1, pad_to=2),
        optimizer=OptimizerConfig(),
        shape=ShapeConfig("srv", "train", 16, 4))


@pytest.fixture(scope="module")
def setup():
    rcfg = tiny_rcfg()
    params = transformer.init_model(jax.random.PRNGKey(0), rcfg)
    return rcfg, params


def _dense_greedy(rcfg, params, prompts, max_new):
    """Seed-style reference: per-token dense-cache prefill + greedy decode."""
    cache = transformer.init_cache(rcfg, len(prompts), MAX_LEN)
    step = jax.jit(lambda p, c, t: transformer.decode_step(p, c, t, rcfg))
    toks = jnp.asarray(np.stack(prompts))
    cur = None
    for t in range(toks.shape[1]):
        lg, cache = step(params, cache, toks[:, t:t + 1])
        cur = jnp.argmax(lg[:, -1].astype(jnp.float32), -1)[:, None]
    outs = [cur]
    for _ in range(max_new - 1):
        lg, cache = step(params, cache, cur)
        cur = jnp.argmax(lg[:, -1].astype(jnp.float32), -1)[:, None]
        outs.append(cur)
    return np.concatenate([np.asarray(o) for o in outs], axis=1)


def test_chunked_prefill_matches_serial_forward(setup):
    """(a) One decode_step call over the whole prompt == serial forward."""
    rcfg, params = setup
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, VOCAB)
    full, _ = jax.jit(
        lambda p, b: transformer.forward(p, b, rcfg, mode="serial"))(
        params, {"tokens": toks})
    cache = transformer.init_cache(rcfg, 2, MAX_LEN)
    lg, cache2 = jax.jit(
        lambda p, c, t: transformer.decode_step(p, c, t, rcfg))(
        params, cache, toks)
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(full, np.float32),
                               rtol=1e-4, atol=1e-4)
    assert int(cache2["index"]) == toks.shape[1]


def test_chunked_prefill_matches_per_token_loop(setup):
    """Chunked prefill populates the cache identically to the seed's
    token-by-token loop: subsequent decode continues the same stream."""
    rcfg, params = setup
    prompts = [np.arange(1, 9, dtype=np.int32) % VOCAB,
               np.arange(11, 19, dtype=np.int32) % VOCAB]
    ref = _dense_greedy(rcfg, params, prompts, max_new=5)
    step = jax.jit(lambda p, c, t: transformer.decode_step(p, c, t, rcfg))
    cache = transformer.init_cache(rcfg, 2, MAX_LEN)
    lg, cache = step(params, cache, jnp.asarray(np.stack(prompts)))
    cur = jnp.argmax(lg[:, -1].astype(jnp.float32), -1)[:, None]
    outs = [cur]
    for _ in range(4):
        lg, cache = step(params, cache, cur)
        cur = jnp.argmax(lg[:, -1].astype(jnp.float32), -1)[:, None]
        outs.append(cur)
    got = np.concatenate([np.asarray(o) for o in outs], axis=1)
    np.testing.assert_array_equal(got, ref)


def test_paged_decode_matches_dense(setup):
    """(b) Paged-cache greedy decode == dense-cache greedy decode,
    token for token (equal-length prompts, so positions align)."""
    rcfg, params = setup
    prompts = [np.array([5, 9, 3, 7, 2, 11], np.int32),
               np.array([1, 2, 3, 4, 5, 6], np.int32)]
    ref = _dense_greedy(rcfg, params, prompts, max_new=6)
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                      page_size=4)
    assert eng.paged
    out = eng.generate([Request(prompt=p, max_new_tokens=6)
                        for p in prompts])
    got = np.stack([r.output for r in out])
    np.testing.assert_array_equal(got, ref)


def test_scheduler_mixed_queue_no_drops(setup):
    """(c) More mixed-length requests than slots: every request finishes
    with exactly max_new tokens, and continuous batching never changes a
    request's output vs running it alone (slot/page isolation)."""
    rcfg, params = setup
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, VOCAB, size=int(rng.integers(
                3, 14))).astype(np.int32),
                    max_new_tokens=int(rng.integers(2, 7)))
            for _ in range(7)]
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=3,
                      page_size=4)
    out = eng.generate(reqs)
    assert len(out) == 7
    for r in out:
        assert len(r.output) == r.max_new_tokens
        assert ((r.output >= 0) & (r.output < VOCAB)).all()
        assert r.ttft_s is not None and r.ttft_s >= 0
    # all pages returned to the pool, all slots free
    sched = eng.scheduler
    assert sched.n_active == 0
    assert sched.alloc.n_free == sched.alloc.n_pages - 1
    # isolation: re-running one request on a fresh engine is identical
    solo = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=3,
                       page_size=4)
    r = out[3]
    s = solo.generate([Request(prompt=r.prompt,
                               max_new_tokens=r.max_new_tokens)])[0]
    np.testing.assert_array_equal(s.output, r.output)


def test_scheduler_single_token_requests_drain(setup):
    """Requests that finish during their own prefill (max_new_tokens=1)
    with more requests than slots must drain, not deadlock/raise: the
    admit pass sees n_active==0 with a non-empty queue and retries."""
    rcfg, params = setup
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                      page_size=4)
    reqs = [Request(prompt=np.arange(1 + i, 5 + i, dtype=np.int32) % VOCAB,
                    max_new_tokens=1) for i in range(5)]
    out = eng.generate(reqs)
    assert all(len(r.output) == 1 for r in out)
    assert eng.scheduler.alloc.n_free == eng.scheduler.alloc.n_pages - 1


def test_scheduler_eos_frees_slot_early(setup):
    """EOS mid-decode evicts the sequence and its pages immediately."""
    rcfg, params = setup
    sched = Scheduler(rcfg, params, max_batch=1, page_size=4,
                      max_len=MAX_LEN)
    # run once without eos to learn the second generated token
    rid = sched.submit(np.array([3, 1, 4], np.int32), max_new_tokens=6)
    probe = sched.run()[rid]
    assert len(probe.out) == 6
    eos = probe.out[1]
    sched2 = Scheduler(rcfg, params, max_batch=1, page_size=4,
                       max_len=MAX_LEN)
    rid2 = sched2.submit(np.array([3, 1, 4], np.int32), max_new_tokens=6,
                         eos_id=eos)
    fin = sched2.run()[rid2]
    assert fin.out[:2] == probe.out[:2]
    assert len(fin.out) == 2
    assert sched2.alloc.n_free == sched2.alloc.n_pages - 1


def test_page_allocator_freelist():
    a = PageAllocator(8)           # pages 1..7 allocatable
    assert a.n_free == 7
    got = a.alloc(7)
    assert sorted(got) == list(range(1, 8))
    assert a.alloc(1) is None      # exhausted -> caller waits
    a.free(got[:3])
    assert a.n_free == 3
    with pytest.raises(ValueError):
        a.free([got[0]])           # double free
    with pytest.raises(ValueError):
        a.free([0])                # scratch page is never allocatable
    assert pages_needed(0, 4) == 0
    assert pages_needed(1, 4) == 1
    assert pages_needed(9, 4) == 3
    assert bucket_len(3) == 8 and bucket_len(9) == 16 and bucket_len(16) == 16


def test_paged_moe_decoder_smoke():
    """The paged path also covers attn_moe decoders."""
    from repro.configs.base import MoEConfig
    rcfg = tiny_rcfg(moe=MoEConfig(num_experts=4, top_k=2, d_ff=64))
    params = transformer.init_model(jax.random.PRNGKey(2), rcfg)
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                      page_size=4)
    assert eng.paged
    out = eng.generate([Request(prompt=np.array([1, 2, 3], np.int32),
                                max_new_tokens=4)])
    assert out[0].output.shape == (4,)
    assert ((out[0].output >= 0) & (out[0].output < VOCAB)).all()
