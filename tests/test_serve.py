"""Serving-path tests: chunked prefill, paged KV cache, scheduler.

Correctness oracles: (a) chunked prefill == teacher-forced serial forward,
(b) the paged engine reproduces seed-style dense-cache decode
token-for-token, (c) a mixed-length request queue completes with no
dropped/duplicated outputs and batching never changes a request's tokens.
fp32 compute so greedy argmax comparisons are tie-free.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (MGRITConfig, ModelConfig, OptimizerConfig,
                                RunConfig, ShapeConfig)
from repro.models import transformer
from repro.serve.engine import Request, ServeEngine
from repro.serve.kv_pages import PageAllocator, pages_needed
from repro.serve.scheduler import Scheduler, bucket_len

pytestmark = pytest.mark.serve

VOCAB = 64
MAX_LEN = 32


def tiny_rcfg(**model_kw):
    kw = dict(name="srv", family="decoder", n_layers=8, d_model=32,
              n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=VOCAB,
              act="gelu", norm="layernorm", dtype="float32")
    kw.update(model_kw)
    return RunConfig(
        model=ModelConfig(**kw),
        mgrit=MGRITConfig(enabled=True, cf=2, levels=2, fwd_iters=1,
                          bwd_iters=1, n_open=1, n_close=1, pad_to=2),
        optimizer=OptimizerConfig(),
        shape=ShapeConfig("srv", "train", 16, 4))


@pytest.fixture(scope="module")
def setup():
    rcfg = tiny_rcfg()
    params = transformer.init_model(jax.random.PRNGKey(0), rcfg)
    return rcfg, params


def _dense_greedy(rcfg, params, prompts, max_new):
    """Seed-style reference: per-token dense-cache prefill + greedy decode."""
    cache = transformer.init_cache(rcfg, len(prompts), MAX_LEN)
    step = jax.jit(lambda p, c, t: transformer.decode_step(p, c, t, rcfg))
    toks = jnp.asarray(np.stack(prompts))
    cur = None
    for t in range(toks.shape[1]):
        lg, cache = step(params, cache, toks[:, t:t + 1])
        cur = jnp.argmax(lg[:, -1].astype(jnp.float32), -1)[:, None]
    outs = [cur]
    for _ in range(max_new - 1):
        lg, cache = step(params, cache, cur)
        cur = jnp.argmax(lg[:, -1].astype(jnp.float32), -1)[:, None]
        outs.append(cur)
    return np.concatenate([np.asarray(o) for o in outs], axis=1)


def test_chunked_prefill_matches_serial_forward(setup):
    """(a) One decode_step call over the whole prompt == serial forward."""
    rcfg, params = setup
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, VOCAB)
    full, _ = jax.jit(
        lambda p, b: transformer.forward(p, b, rcfg, mode="serial"))(
        params, {"tokens": toks})
    cache = transformer.init_cache(rcfg, 2, MAX_LEN)
    lg, cache2 = jax.jit(
        lambda p, c, t: transformer.decode_step(p, c, t, rcfg))(
        params, cache, toks)
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(full, np.float32),
                               rtol=1e-4, atol=1e-4)
    assert int(cache2["index"]) == toks.shape[1]


def test_chunked_prefill_matches_per_token_loop(setup):
    """Chunked prefill populates the cache identically to the seed's
    token-by-token loop: subsequent decode continues the same stream."""
    rcfg, params = setup
    prompts = [np.arange(1, 9, dtype=np.int32) % VOCAB,
               np.arange(11, 19, dtype=np.int32) % VOCAB]
    ref = _dense_greedy(rcfg, params, prompts, max_new=5)
    step = jax.jit(lambda p, c, t: transformer.decode_step(p, c, t, rcfg))
    cache = transformer.init_cache(rcfg, 2, MAX_LEN)
    lg, cache = step(params, cache, jnp.asarray(np.stack(prompts)))
    cur = jnp.argmax(lg[:, -1].astype(jnp.float32), -1)[:, None]
    outs = [cur]
    for _ in range(4):
        lg, cache = step(params, cache, cur)
        cur = jnp.argmax(lg[:, -1].astype(jnp.float32), -1)[:, None]
        outs.append(cur)
    got = np.concatenate([np.asarray(o) for o in outs], axis=1)
    np.testing.assert_array_equal(got, ref)


def test_paged_decode_matches_dense(setup):
    """(b) Paged-cache greedy decode == dense-cache greedy decode,
    token for token (equal-length prompts, so positions align)."""
    rcfg, params = setup
    prompts = [np.array([5, 9, 3, 7, 2, 11], np.int32),
               np.array([1, 2, 3, 4, 5, 6], np.int32)]
    ref = _dense_greedy(rcfg, params, prompts, max_new=6)
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                      page_size=4)
    out = eng.generate([Request(prompt=p, max_new_tokens=6)
                        for p in prompts])
    got = np.stack([r.output for r in out])
    np.testing.assert_array_equal(got, ref)


def test_scheduler_mixed_queue_no_drops(setup):
    """(c) More mixed-length requests than slots: every request finishes
    with exactly max_new tokens, and continuous batching never changes a
    request's output vs running it alone (slot/page isolation)."""
    rcfg, params = setup
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, VOCAB, size=int(rng.integers(
                3, 14))).astype(np.int32),
                    max_new_tokens=int(rng.integers(2, 7)))
            for _ in range(7)]
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=3,
                      page_size=4)
    out = eng.generate(reqs)
    assert len(out) == 7
    for r in out:
        assert len(r.output) == r.max_new_tokens
        assert ((r.output >= 0) & (r.output < VOCAB)).all()
        assert r.ttft_s is not None and r.ttft_s >= 0
    # all slots free; every page is either back in the pool or pinned by
    # the prefix cache — and the pool drains fully once that is dropped
    sched = eng.scheduler
    assert sched.n_active == 0
    assert sched.alloc.n_free + sched.prefix.n_cached_pages \
        == sched.alloc.n_pages - 1
    sched.drop_prefix_cache()
    assert sched.alloc.n_free == sched.alloc.n_pages - 1
    # isolation: re-running one request on a fresh engine is identical
    solo = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=3,
                       page_size=4)
    r = out[3]
    s = solo.generate([Request(prompt=r.prompt,
                               max_new_tokens=r.max_new_tokens)])[0]
    np.testing.assert_array_equal(s.output, r.output)


def test_scheduler_single_token_requests_drain(setup):
    """Requests that finish during their own prefill (max_new_tokens=1)
    with more requests than slots must drain, not deadlock/raise: the
    admit pass sees n_active==0 with a non-empty queue and retries."""
    rcfg, params = setup
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                      page_size=4)
    reqs = [Request(prompt=np.arange(1 + i, 5 + i, dtype=np.int32) % VOCAB,
                    max_new_tokens=1) for i in range(5)]
    out = eng.generate(reqs)
    assert all(len(r.output) == 1 for r in out)
    eng.scheduler.drop_prefix_cache()
    assert eng.scheduler.alloc.n_free == eng.scheduler.alloc.n_pages - 1


def test_scheduler_eos_frees_slot_early(setup):
    """EOS mid-decode evicts the sequence and its pages immediately."""
    rcfg, params = setup
    sched = Scheduler(rcfg, params, max_batch=1, page_size=4,
                      max_len=MAX_LEN)
    # run once without eos to learn the second generated token
    rid = sched.submit(np.array([3, 1, 4], np.int32), max_new_tokens=6)
    probe = sched.run()[rid]
    assert len(probe.out) == 6
    eos = probe.out[1]
    sched2 = Scheduler(rcfg, params, max_batch=1, page_size=4,
                       max_len=MAX_LEN)
    rid2 = sched2.submit(np.array([3, 1, 4], np.int32), max_new_tokens=6,
                         eos_id=eos)
    fin = sched2.run()[rid2]
    assert fin.out[:2] == probe.out[:2]
    assert len(fin.out) == 2
    # the 3-token prompt's partial tail page stays pinned by the prefix
    # trie (token-granular publish at reap); everything else is freed,
    # and dropping the cache drains the pool fully
    assert sched2.alloc.n_free + sched2.prefix.n_cached_pages \
        == sched2.alloc.n_pages - 1
    sched2.drop_prefix_cache()
    assert sched2.alloc.n_free == sched2.alloc.n_pages - 1


def test_page_allocator_freelist():
    a = PageAllocator(8)           # pages 1..7 allocatable
    assert a.n_free == 7
    got = a.alloc(7)
    assert sorted(got) == list(range(1, 8))
    assert a.alloc(1) is None      # exhausted -> caller waits
    a.free(got[:3])
    assert a.n_free == 3
    with pytest.raises(ValueError):
        a.free([got[0]])           # double free
    with pytest.raises(ValueError):
        a.free([0])                # scratch page is never allocatable
    assert pages_needed(0, 4) == 0
    assert pages_needed(1, 4) == 1
    assert pages_needed(9, 4) == 3
    assert bucket_len(3) == 8 and bucket_len(9) == 16 and bucket_len(16) == 16


def test_page_allocator_refcounts():
    """Shared pages only return to the pool when the last reader frees
    them; fork detaches a private copy (or is a no-op on private pages)."""
    a = PageAllocator(6)                       # pages 1..5
    (p,) = a.alloc(1)
    a.share([p])
    assert a.refcount(p) == 2
    a.free([p])
    assert a.refcount(p) == 1 and not a.is_free(p)
    # fork of a private page: no new allocation
    assert a.fork(p) == p and a.n_free == 4
    # fork of a shared page: fresh private copy, source keeps one reader
    a.share([p])
    q = a.fork(p)
    assert q != p and a.refcount(q) == 1 and a.refcount(p) == 1
    with pytest.raises(ValueError):
        a.share([5])                           # page 5 was never allocated
    a.free([p, q])
    assert a.n_free == 5
    with pytest.raises(ValueError):
        a.fork(p)                              # fork of a freed page


def test_prefix_sharing_skips_prefill_and_matches_no_sharing(setup):
    """Requests with a common prompt prefix map the same physical pages:
    fewer prefill tokens computed, fewer pages allocated, and outputs
    token-for-token identical to a no-sharing engine."""
    rcfg, params = setup
    common = np.arange(1, 9, dtype=np.int32) % VOCAB       # 2 pages of 4

    def reqs():
        return [Request(prompt=np.concatenate(
                    [common, np.array([20 + i], np.int32)]),
                        max_new_tokens=4) for i in range(4)]

    base = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                       page_size=4, share_prefix=False)
    out_base = base.generate(reqs())
    shared = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                         page_size=4, share_prefix=True)
    out_shared = shared.generate(reqs())
    for a, b in zip(out_base, out_shared, strict=True):
        np.testing.assert_array_equal(a.output, b.output)
    sb, ss = base.scheduler.stats, shared.scheduler.stats
    assert ss["prefill_tokens"] < sb["prefill_tokens"]
    assert ss["pages_allocated"] < sb["pages_allocated"]
    assert ss["shared_tokens"] > 0


def test_prefix_sharing_cow_fork_on_full_prompt_hit(setup):
    """A page-aligned full-prompt cache hit recomputes only the final
    token, writing it into a COW fork of the last shared page — the
    original stays intact for other readers."""
    rcfg, params = setup
    prompt = np.arange(1, 9, dtype=np.int32) % VOCAB       # exactly 2 pages
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=1,
                      page_size=4)
    a = eng.generate([Request(prompt=prompt, max_new_tokens=5)])[0]
    pt0 = eng.scheduler.stats["prefill_tokens"]
    b = eng.generate([Request(prompt=prompt, max_new_tokens=5)])[0]
    np.testing.assert_array_equal(a.output, b.output)
    # second pass recomputed exactly one token (the logits seed)
    assert eng.scheduler.stats["prefill_tokens"] == pt0 + 1
    solo = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=1,
                       page_size=4, share_prefix=False)
    c = solo.generate([Request(prompt=prompt, max_new_tokens=5)])[0]
    np.testing.assert_array_equal(a.output, c.output)
    eng.scheduler.drop_prefix_cache()
    assert eng.scheduler.alloc.n_free == eng.scheduler.alloc.n_pages - 1


def test_cow_fork_evicts_prefix_cache_under_pressure(setup):
    """When the pool is empty at fork time, the scheduler must evict an
    unrelated trie leaf instead of refusing a servable request."""
    rcfg, params = setup
    sched = Scheduler(rcfg, params, max_batch=1, page_size=4,
                      max_len=16, n_pages=1 + 5)
    p_prompt = np.arange(1, 9, dtype=np.int32)       # 2 full pages
    q_prompt = np.array([30, 31, 32, 33], np.int32)  # 1 unrelated page
    sched.submit(p_prompt, 2)
    sched.submit(q_prompt, 2)
    sched.run()
    assert sched.prefix.n_cached_pages == 3
    # full-prompt hit on p needs 2 fresh pages (draining the pool) + a
    # fork page -> only q's cached page can supply it
    rid = sched.submit(p_prompt, 8)
    out = sched.run()[rid]
    assert len(out.out) == 8
    assert sched.prefix.stats["evicted"] >= 1
    sched.drop_prefix_cache()
    assert sched.alloc.n_free == sched.alloc.n_pages - 1


def test_batched_prefill_single_call_per_wave(setup):
    """One admission wave = one jitted prefill call, whatever the queue
    depth; outputs still match the sequential-admission reference."""
    rcfg, params = setup
    prompts = [np.array([5, 9, 3, 7, 2, 11], np.int32),
               np.array([1, 2, 3, 4, 5, 6], np.int32)]
    ref = _dense_greedy(rcfg, params, prompts, max_new=6)
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                      page_size=4)
    out = eng.generate([Request(prompt=p, max_new_tokens=6)
                        for p in prompts])
    np.testing.assert_array_equal(np.stack([r.output for r in out]), ref)
    assert eng.scheduler.stats["prefill_calls"] == 1


def test_ssm_engine_paged_and_probes(setup):
    """SSM families serve through the same paged engine (state-snapshot
    pages): mixed-length queues, sampling accepted, eos truncation; the
    probe APIs work on every backend."""
    from repro.configs.base import SSMConfig
    from repro.serve.cache import SSMStateBackend
    rcfg = tiny_rcfg(family="ssm", n_layers=4, act="silu", norm="rmsnorm",
                     ssm=SSMConfig(version=1, d_state=8, d_conv=2))
    params = transformer.init_model(jax.random.PRNGKey(1), rcfg)
    eng = ServeEngine(rcfg, params, max_len=24, max_batch=2, page_size=4)
    assert isinstance(eng.backend, SSMStateBackend)
    out = eng.generate([
        Request(prompt=np.array([1, 2, 3], np.int32), max_new_tokens=4),
        Request(prompt=np.array([4, 5], np.int32), max_new_tokens=4),
        Request(prompt=np.array([1], np.int32), max_new_tokens=2,
                temperature=0.5, seed=3)])
    for r in out:
        assert len(r.output) == r.max_new_tokens
        assert ((r.output >= 0) & (r.output < VOCAB)).all()
    assert eng.throughput_probe(2, steps=2) > 0
    assert eng.throughput_probe(2, steps=2, paged=False) > 0
    assert eng.prefill_probe(8, batch=1, iters=1) > 0
    # attention-backend probes (greedy sampling args path)
    prcfg, pparams = setup
    peng = ServeEngine(prcfg, pparams, max_len=MAX_LEN, max_batch=2,
                       page_size=4)
    assert peng.throughput_probe(2, steps=2) > 0
    assert peng.throughput_probe(2, steps=2, paged=False) > 0
    assert peng.prefill_probe(8, batch=1, iters=1) > 0


def test_unservable_families_raise():
    """Families with no CacheBackend (encoder: no decode; encdec: needs
    per-request encoder state) are rejected at engine construction."""
    rcfg = tiny_rcfg(family="encoder")
    params = transformer.init_model(jax.random.PRNGKey(4), rcfg)
    with pytest.raises(NotImplementedError, match="CacheBackend"):
        ServeEngine(rcfg, params, max_len=MAX_LEN)


def test_paged_moe_decoder_smoke():
    """The paged path also covers attn_moe decoders."""
    from repro.configs.base import MoEConfig
    rcfg = tiny_rcfg(moe=MoEConfig(num_experts=4, top_k=2, d_ff=64))
    params = transformer.init_model(jax.random.PRNGKey(2), rcfg)
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                      page_size=4)
    out = eng.generate([Request(prompt=np.array([1, 2, 3], np.int32),
                                max_new_tokens=4)])
    assert out[0].output.shape == (4,)
    assert ((out[0].output >= 0) & (out[0].output < VOCAB)).all()
