"""Runtime twin of staticcheck's RC001: the serve hot path must not
recompile.

Wraps the backend's jitted prefill/step/verify callables in a
trace-counter (the pre-jit Python body runs exactly once per trace, so
re-wrapping the cached factory output counts compilations directly)
and drives a full admit→evict→refill→preempt→resume cycle, asserting
each callable is compiled at most once per (bucket, batch) argument
shape.  A duplicate signature in the counter means jax retraced an
already-seen shape — the recompile-per-wave failure mode PR 1's
occupancy-mask design exists to prevent, which no output-correctness
test can catch (the tokens stay right; the engine just gets slow)."""
import jax
import numpy as np
import pytest

from repro.configs.base import (MGRITConfig, ModelConfig, OptimizerConfig,
                                RunConfig, ShapeConfig)
from repro.launch import steps as steps_mod
from repro.models import transformer
from repro.serve.scheduler import Scheduler
from repro.serve.spec import SpecConfig

pytestmark = pytest.mark.serve

VOCAB = 32
MAX_LEN = 24


def make_setup(seed: int = 0):
    rcfg = RunConfig(
        model=ModelConfig(name="trace_decoder", family="decoder",
                          n_layers=4, d_model=16, n_heads=2, n_kv_heads=2,
                          d_ff=32, vocab_size=VOCAB, act="gelu",
                          norm="layernorm", dtype="float32"),
        mgrit=MGRITConfig(enabled=False, cf=2, fwd_iters=1,
                          bwd_iters=1, n_open=1, n_close=1, pad_to=2),
        optimizer=OptimizerConfig(),
        shape=ShapeConfig("trace_decoder", "train", 16, 4))
    params = transformer.init_model(jax.random.PRNGKey(seed), rcfg)
    return rcfg, params


def _sig(args):
    leaves = jax.tree_util.tree_leaves(args)
    return tuple((tuple(x.shape), str(x.dtype)) for x in leaves)


def _count_step_traces(backend):
    """Replace the backend's jitted prefill/step callable with a
    counting twin of the same factory output; returns the signature
    log (one entry per trace)."""
    inner = steps_mod.make_paged_serve_fn(
        backend.rcfg, backend.mesh, backend._decode_fn(),
        fused=backend.fused)
    sigs = []

    def counting(*args):
        sigs.append(_sig(args))
        return inner(*args)

    backend._step_fn = jax.jit(counting, donate_argnums=(1,))
    return sigs


def _count_verify_traces(backend):
    """Pre-build the (normally lazy) jitted verify callable with a
    trace counter installed."""
    vf, cf = backend._verify_fns()
    inner = steps_mod.make_paged_verify_fn(backend.rcfg, backend.mesh,
                                           vf, cf)
    sigs = []

    def counting(*args):
        sigs.append(_sig(args))
        return inner(*args)

    backend._verify_fn = jax.jit(counting, donate_argnums=(1,))
    return sigs


def _assert_trace_once(sigs, label):
    dupes = [s for s in set(sigs) if sigs.count(s) > 1]
    assert not dupes, (
        f"{label} retraced {len(dupes)} already-seen shape signature(s) "
        f"across {len(sigs)} traces — the hot path recompiled")


def test_preempt_resume_cycle_compiles_each_shape_once():
    """admit → decode → preempt(spill) → evict → refill → resume, plus
    a trailing fresh request: every step/prefill trace has a distinct
    (bucket, batch) shape."""
    rcfg, params = make_setup()
    sched = Scheduler(rcfg, params, max_batch=1, page_size=4,
                      max_len=MAX_LEN, share_prefix=False,
                      preempt_policy="spill")
    sigs = _count_step_traces(sched.backend)

    a = sched.submit_request(np.arange(2, 9, dtype=np.int32), 8,
                             priority=5)
    for _ in range(3):
        sched.step()                  # admit + decode waves
    b = sched.submit_request(np.array([5, 4, 3, 2, 1], np.int32), 4,
                             priority=0)
    sched.step()                      # slot exhaustion -> preempt a
    assert a.preemptions == 1
    done = sched.run()                # b evicts, a restores + finishes
    assert not done[a.rid].failed and not done[b.rid].failed
    assert sched.stats["preemptions"] == 1

    c = sched.submit_request(np.arange(1, 6, dtype=np.int32), 4)
    done = sched.run()                # refill into the drained engine
    assert not done[c.rid].failed
    assert len(sigs) > 0
    _assert_trace_once(sigs, "paged serve step")


def test_batched_churn_compiles_each_shape_once():
    """Continuous-batching churn at max_batch=2 — staggered admits,
    evictions, and refills across mixed prompt lengths reuse the same
    compiled step for every repeated (bucket, batch) shape."""
    rcfg, params = make_setup()
    sched = Scheduler(rcfg, params, max_batch=2, page_size=4,
                      max_len=MAX_LEN, share_prefix=False)
    sigs = _count_step_traces(sched.backend)
    prompts = [np.arange(1, 8, dtype=np.int32),
               np.array([3, 1, 2], np.int32),
               np.arange(4, 10, dtype=np.int32) % VOCAB,
               np.array([7, 7, 1, 2], np.int32),
               np.arange(2, 5, dtype=np.int32)]
    reqs = []
    for i, p in enumerate(prompts):
        reqs.append(sched.submit_request(p, 3 + (i % 3)))
        sched.step()                  # interleave admit with decode
    done = sched.run()
    assert all(not done[r.rid].failed for r in reqs)
    assert len(sigs) > 0
    _assert_trace_once(sigs, "paged serve step")


def test_chunked_interleaving_reuses_serial_bucket_shapes():
    """Chunked-prefill ingest rounds through ``bucket_len``'s existing
    shape universe: every (bucket, batch) signature the chunked +
    token-granular engine traces is traced exactly once AND already
    exists in the serial whole-prompt engine's compiled set — chunking
    adds no new jit shapes, so ``engine.compiles_per_callable`` stays
    stable when the feature is switched on."""
    rcfg, params = make_setup()
    prompts = [np.arange(1, 8, dtype=np.int32),      # straddles a page
               np.array([3, 1, 2], np.int32),
               np.arange(4, 15, dtype=np.int32) % VOCAB,
               np.arange(1, 8, dtype=np.int32),      # trie re-hit
               np.array([7, 7, 1, 2, 5], np.int32)]

    def drive(chunk):
        sched = Scheduler(rcfg, params, max_batch=2, page_size=4,
                          max_len=MAX_LEN, prefill_chunk_tokens=chunk)
        sigs = _count_step_traces(sched.backend)
        reqs = []
        for i, p in enumerate(prompts):
            reqs.append(sched.submit_request(p.copy(), 3 + (i % 3)))
            sched.step()              # interleave admit/ingest with decode
        done = sched.run()
        assert all(not done[r.rid].failed for r in reqs)
        if chunk:
            assert sched.stats["prefill_chunks"] > 0
        assert len(sigs) > 0
        _assert_trace_once(sigs, f"paged serve step (chunk={chunk})")
        return set(sigs)

    serial = drive(0)
    chunked = drive(5)
    new = chunked - serial
    assert not new, (
        f"chunked ingest introduced {len(new)} jit shape(s) the serial "
        f"engine never compiles")


def test_spec_verify_compiles_each_shape_once():
    """The speculative verify wave is shape-stable too: one compile per
    (bucket, batch) signature across a mixed-length spec run."""
    rcfg, params = make_setup()
    sched = Scheduler(rcfg, params, max_batch=2, page_size=4,
                      max_len=MAX_LEN, share_prefix=False,
                      spec=SpecConfig(cf=2, k=3))
    step_sigs = _count_step_traces(sched.backend)
    verify_sigs = _count_verify_traces(sched.backend)
    reqs = [sched.submit_request(np.arange(1, 8, dtype=np.int32), 6),
            sched.submit_request(np.array([3, 1, 2], np.int32), 5)]
    done = sched.run()
    assert all(not done[r.rid].failed for r in reqs)
    assert sched.stats["verify_calls"] > 0
    assert len(verify_sigs) > 0
    _assert_trace_once(step_sigs, "paged serve step")
    _assert_trace_once(verify_sigs, "paged verify step")
