"""Mesh-sharded serving conformance (ISSUE 5 acceptance).

On a 2-device host mesh (``--xla_force_host_platform_device_count=2``,
forced in a subprocess because the parent's jax is already initialized
single-device), temp-0 paged decode must be **token-for-token identical**
to the single-device dense serial oracle for every backend family, under
both a data-parallel split (pages/batch over 'data') and a
tensor-parallel split (weights/heads over 'model') — and sampled
requests must reproduce the oracle stream too (the sampler keys off
(seed, n_emitted) only, so placement can't change it). One spec-decode
run asserts greedy spec == plain paged decode under tp.

The engines under test are real ``ServeEngine``s built with
``mesh=jax.make_mesh((dp, tp), ("data", "model"))`` — the same
scheduler/allocator/trie paths as single-device serving; only the jitted
calls go SPMD (serve/cache.CacheBackend, docs/sharding.md).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = [pytest.mark.slow, pytest.mark.serve]

_MESH_SCRIPT = textwrap.dedent("""
    import os
    import sys
    sys.path.insert(0, "src")
    sys.path.insert(0, "tests")
    from repro.launch.hostdev import force_host_device_count
    force_host_device_count(2)        # before jax's backend comes up
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import json
    import jax
    import numpy as np
    from repro.models import transformer
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.spec import SpecConfig
    from serve_oracle import dense_decode_oracle
    from test_serve_backends import FAMILY_MODELS, MAX_LEN, family_rcfg

    FAMILIES = ("decoder", "ssm_mamba1", "hybrid")
    out = {"devices": jax.device_count(), "mismatch": []}

    def reqs():
        return [Request(prompt=np.array([5, 9, 3, 7, 2], np.int32),
                        max_new_tokens=5),
                Request(prompt=np.array([4, 2, 9], np.int32),
                        max_new_tokens=5, temperature=1.1, top_k=16,
                        top_p=0.9, seed=7)]

    for name in FAMILIES:
        rcfg = family_rcfg(name)
        params = transformer.init_model(
            jax.random.PRNGKey(sum(map(ord, name)) % 1000), rcfg)
        step = jax.jit(lambda p, c, t, _r=rcfg: transformer.decode_step(
            p, c, t, _r))
        refs = [dense_decode_oracle(rcfg, params, step, r, MAX_LEN)
                for r in reqs()]
        for dp, tp in ((2, 1), (1, 2)):
            mesh = jax.make_mesh((dp, tp), ("data", "model"))
            eng = ServeEngine(rcfg, params, mesh=mesh, max_len=MAX_LEN,
                              max_batch=2, page_size=4)
            got = eng.generate(reqs())
            for i, (g, ref) in enumerate(zip(got, refs)):
                if not np.array_equal(g.output, ref):
                    out["mismatch"].append(
                        [name, f"dp{dp}xtp{tp}", i,
                         list(map(int, g.output)), list(map(int, ref))])
            st = eng.stats
            out[f"{name}_dp{dp}tp{tp}"] = [st["mesh_dp"], st["mesh_tp"]]
            if dp > 1:
                # the pool page axis (axis 1) must actually shard over
                # 'data' — pool_pages rounds the default size to make
                # the mapping divisible rather than silently replicate
                specs = [getattr(leaf.sharding, "spec", ())
                         for leaf in jax.tree.leaves(eng.scheduler.state)]
                out[f"{name}_pool_dp_sharded"] = any(
                    len(s) > 1 and s[1] == "data" for s in specs)

    # fused paged-decode path under the 2-device mesh (ISSUE 6): the
    # engines above already run fused by default and match the oracle;
    # here an explicit fused/gathered pair under both splits pins the
    # flag itself, so a silent fused=False regression can't hide behind
    # oracle equality
    out["fused_flag"] = []
    out["fused_mesh_mismatch"] = []
    for dp, tp in ((2, 1), (1, 2)):
        mesh = jax.make_mesh((dp, tp), ("data", "model"))
        for name in ("decoder", "ssm_mamba1"):
            rcfg = family_rcfg(name)
            params = transformer.init_model(
                jax.random.PRNGKey(sum(map(ord, name)) % 1000), rcfg)
            kw = dict(max_len=MAX_LEN, max_batch=2, page_size=4)
            ef = ServeEngine(rcfg, params, mesh=mesh, **kw)
            eg = ServeEngine(rcfg, params, mesh=mesh, fused=False, **kw)
            out["fused_flag"].append(
                [bool(ef.scheduler.backend.fused),
                 bool(eg.scheduler.backend.fused)])
            for i, (a, b) in enumerate(zip(ef.generate(reqs()),
                                           eg.generate(reqs()))):
                if not np.array_equal(a.output, b.output):
                    out["fused_mesh_mismatch"].append(
                        [name, f"dp{dp}xtp{tp}", i,
                         list(map(int, a.output)), list(map(int, b.output))])

    # spec decode under tp: greedy spec == greedy plain, bitwise — ssm
    # covers the stacked snapshot-pool commit constraints
    # (ssm_paged_commit_step) inside the SPMD verify call, hybrid the
    # composite in-line-KV + deferred-snapshot commit path
    out["spec_drafted"] = 0
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    for name in ("decoder", "ssm_mamba1", "hybrid"):
        rcfg = family_rcfg(name)
        params = transformer.init_model(
            jax.random.PRNGKey(sum(map(ord, name)) % 1000), rcfg)
        greedy = [Request(prompt=np.array([5, 9, 3, 7, 2], np.int32),
                          max_new_tokens=6),
                  Request(prompt=np.array([4, 2, 9], np.int32),
                          max_new_tokens=6)]
        kw = dict(max_len=MAX_LEN, max_batch=2, page_size=4)
        plain = ServeEngine(rcfg, params, mesh=mesh, **kw).generate(
            [Request(prompt=r.prompt.copy(),
                     max_new_tokens=r.max_new_tokens) for r in greedy])
        spec_eng = ServeEngine(rcfg, params, mesh=mesh,
                               spec=SpecConfig(cf=2, k=3), **kw)
        spec = spec_eng.generate(greedy)
        for i, (a, b) in enumerate(zip(plain, spec)):
            if not np.array_equal(a.output, b.output):
                out["mismatch"].append(
                    [f"spec_tp2_{name}", i, list(map(int, b.output)),
                     list(map(int, a.output))])
        out["spec_drafted"] += int(spec_eng.stats["tokens_drafted"])
    print("RESULT " + json.dumps(out))
""")


def _run_mesh_subprocess():
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)        # the script pins its own device count
    r = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                       capture_output=True, text=True, cwd=os.getcwd(),
                       env=env, timeout=1200)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_mesh_sharded_decode_matches_dense_oracle():
    """All three backend families, dp and tp 2-device splits, greedy AND
    sampled requests, token-for-token vs the single-device dense oracle;
    plus greedy spec decode == plain decode under tp."""
    out = _run_mesh_subprocess()
    assert out["devices"] == 2
    assert out["mismatch"] == [], out["mismatch"]
    assert out["fused_mesh_mismatch"] == [], out["fused_mesh_mismatch"]
    assert all(f == [True, False] for f in out["fused_flag"])
    assert out["spec_drafted"] > 0          # spec decode actually drafted
    for name in ("decoder", "ssm_mamba1", "hybrid"):
        assert out[f"{name}_dp2tp1"] == [2, 1]
        assert out[f"{name}_dp1tp2"] == [1, 2]
        assert out[f"{name}_pool_dp_sharded"], \
            f"{name}: page pools replicated under dp2 (pool_pages?)"
