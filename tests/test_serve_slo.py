"""Overload-path coverage for the SLO-aware scheduler (ISSUE 7).

Tier-1: failure isolation (an unservable request fails alone — at
submit time or on an idle engine — while everything else keeps
serving), bounded skip-ahead admission with the aging starvation guard,
preempt/spill/restore reproducing undisturbed greedy output bitwise on
all three backend families, the recompute resume path, cancel-while-
queued metric sanity (no negative TTFT), the debug-gated COW invariant
check, and the bucket_len clamp.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import (MGRITConfig, ModelConfig, OptimizerConfig,
                                RunConfig, ShapeConfig, SSMConfig)
from repro.models import transformer
from repro.serve.scheduler import COWViolationError, Scheduler, bucket_len

pytestmark = pytest.mark.serve

VOCAB = 32
MAX_LEN = 24


def make_setup(fam: str, seed: int = 0):
    kw = dict(name=f"slo_{fam}", family="decoder", n_layers=4, d_model=16,
              n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=VOCAB,
              act="gelu", norm="layernorm", dtype="float32")
    if fam == "ssm_mamba1":
        kw.update(family="ssm", ssm=SSMConfig(version=1, d_state=8,
                                              d_conv=3))
    elif fam == "hybrid":
        kw.update(family="hybrid", n_layers=5, hybrid_attn_every=2,
                  ssm=SSMConfig(version=2, d_state=8, d_conv=3,
                                headdim=16))
    rcfg = RunConfig(
        model=ModelConfig(**kw),
        mgrit=MGRITConfig(enabled=True, cf=2, levels=2, fwd_iters=1,
                          bwd_iters=1, n_open=1, n_close=1, pad_to=2),
        optimizer=OptimizerConfig(),
        shape=ShapeConfig(fam, "train", 16, 4))
    params = transformer.init_model(jax.random.PRNGKey(seed), rcfg)
    return rcfg, params


@pytest.fixture(scope="module")
def setup():
    return make_setup("decoder")


# -- failure isolation -------------------------------------------------------

def test_oversized_rejection_leaves_inflight_untouched(setup):
    """An unservable request must fail at submit WITHOUT perturbing a
    request already decoding — its output stays bitwise what an
    undisturbed engine produces."""
    rcfg, params = setup
    prompt = np.arange(1, 8, dtype=np.int32)
    ref = Scheduler(rcfg, params, max_batch=2, page_size=4,
                    max_len=MAX_LEN, n_pages=1 + 4)
    rid = ref.submit(prompt, 6)
    want = ref.run()[rid].out

    sched = Scheduler(rcfg, params, max_batch=2, page_size=4,
                      max_len=MAX_LEN, n_pages=1 + 4)
    live = sched.submit_request(prompt, 6)
    sched.step()                      # admit + first decode: in flight
    assert sched.n_active == 1
    big = sched.submit_request(np.arange(20, dtype=np.int32) % VOCAB,
                               max_new_tokens=4)   # needs 6 pages > 4
    assert big.failed and big.done and big.out == []
    assert big.ttft is None and big.tpot is None and big.latency >= 0.0
    assert not big.slo_met
    assert sched.n_active == 1        # in-flight slot untouched
    done = sched.run()
    assert done[live.rid].out == want
    assert sched.stats["requests_rejected"] == 1
    assert sched.stats["requests_failed"] == 1


def test_idle_engine_admission_failure_fails_request_alone(setup):
    """Runtime safety net: a request that passes the submit-time check
    but cannot get pages even on an otherwise idle engine (pages pinned
    outside the scheduler) fails alone; later requests still serve."""
    rcfg, params = setup
    sched = Scheduler(rcfg, params, max_batch=2, page_size=4,
                      max_len=MAX_LEN, n_pages=1 + 6, share_prefix=False)
    pinned = sched.alloc.alloc(4)     # external pin: only 2 pages free
    stuck = sched.submit_request(np.arange(8, dtype=np.int32),
                                 max_new_tokens=4)   # needs 3 pages
    ok = sched.submit_request(np.array([1, 2, 3], np.int32),
                              max_new_tokens=2)      # needs 2 pages: fits
    done = sched.run()
    assert stuck.failed and "idle engine" in stuck.error
    assert done[ok.rid].out is not None and len(done[ok.rid].out) == 2
    assert not done[ok.rid].failed
    sched.alloc.free(pinned)
    assert sched.alloc.n_free == sched.alloc.n_pages - 1


# -- admission order ---------------------------------------------------------

def test_skip_ahead_admits_small_request_past_blocked_head(setup):
    """A small request behind an unservable head must admit (bounded
    skip-ahead) instead of head-of-line blocking; the head admits once
    the pool drains."""
    rcfg, params = setup
    sched = Scheduler(rcfg, params, max_batch=2, page_size=4,
                      max_len=MAX_LEN, n_pages=1 + 7, share_prefix=False,
                      preempt_policy="off")
    hog = sched.submit_request(np.arange(8, dtype=np.int32),
                               max_new_tokens=8)     # 4 pages
    sched.step()                                     # hog in flight
    big = sched.submit_request(np.arange(12, dtype=np.int32) % VOCAB,
                               max_new_tokens=4)     # 4 pages > 3 free
    small = sched.submit_request(np.array([9, 8, 7], np.int32),
                                 max_new_tokens=2)   # 2 pages: fits now
    sched.step()
    assert small.t_first > 0.0        # admitted past the blocked head
    assert big.t_first == 0.0 and big.skips > 0
    done = sched.run()
    assert all(not done[r.rid].failed for r in (hog, big, small))
    assert small.t_done < big.t_done


def test_starvation_limit_blocks_skip_ahead(setup):
    """Once the head has been skipped past starvation_limit waves, the
    queue stops skipping ahead: later small requests wait behind it
    until it admits (aging -> drain-for-the-head)."""
    rcfg, params = setup
    sched = Scheduler(rcfg, params, max_batch=2, page_size=4,
                      max_len=MAX_LEN, n_pages=1 + 7, share_prefix=False,
                      preempt_policy="off", starvation_limit=0)
    hog = sched.submit_request(np.arange(8, dtype=np.int32),
                               max_new_tokens=6)
    sched.step()
    big = sched.submit_request(np.arange(12, dtype=np.int32) % VOCAB,
                               max_new_tokens=4)
    small = sched.submit_request(np.array([9, 8, 7], np.int32),
                                 max_new_tokens=2)
    sched.step()                      # head blocked, limit 0: no skip
    assert small.t_first == 0.0 and big.t_first == 0.0
    done = sched.run()
    assert all(not done[r.rid].failed for r in (hog, big, small))
    assert big.t_first <= small.t_first   # queue order held


# -- preemption: spill/restore and recompute resumes -------------------------

@pytest.mark.parametrize("fam,policy", [("decoder", "spill"),
                                        ("ssm_mamba1", "spill"),
                                        ("hybrid", "spill"),
                                        ("decoder", "recompute")])
def test_preempt_resume_bitwise_identical(fam, policy):
    """A greedy request preempted mid-decode by a more urgent one and
    later resumed (restore from spilled pages, or recompute) must emit
    exactly the tokens it would have undisturbed — on every backend
    family."""
    rcfg, params = make_setup(fam)
    kw = dict(max_batch=1, page_size=4, max_len=MAX_LEN,
              share_prefix=False)
    p_a = np.arange(2, 9, dtype=np.int32)            # 7 tokens
    p_b = np.array([5, 4, 3, 2, 1], np.int32)

    ref = Scheduler(rcfg, params, **kw)
    ref_a = ref.submit_request(p_a, 8, priority=5)
    ref.run()
    ref_b = ref.submit_request(p_b, 4, priority=0)
    ref.run()

    sched = Scheduler(rcfg, params, preempt_policy=policy, **kw)
    a = sched.submit_request(p_a, 8, priority=5)
    for _ in range(3):                # prefill+decode, then 2 decodes
        sched.step()
    assert len(a.out) == 4 and sched.n_active == 1
    b = sched.submit_request(p_b, 4, priority=0)
    sched.step()                      # slot exhaustion -> preempt a
    assert a.preemptions == 1 and b.t_first > 0.0
    if policy == "spill":
        assert sched.stats["pages_spilled"] > 0
    else:
        assert sched.stats["preempt_recomputes"] == 1
    done = sched.run()
    assert done[b.rid].out == ref_b.out
    assert done[a.rid].out == ref_a.out      # bitwise, across preemption
    if policy == "spill":
        assert sched.stats["pages_restored"] > 0
    assert sched.stats["preemptions"] == 1
    assert sched.alloc.n_free == sched.alloc.n_pages - 1


def test_preemption_requires_strictly_less_urgent_victim(setup):
    """Equal-priority requests never preempt each other: the later one
    waits for a slot instead (no thrash)."""
    rcfg, params = setup
    sched = Scheduler(rcfg, params, max_batch=1, page_size=4,
                      max_len=MAX_LEN, share_prefix=False)
    a = sched.submit_request(np.arange(6, dtype=np.int32), 6, priority=1)
    sched.step()
    b = sched.submit_request(np.array([3, 2, 1], np.int32), 3, priority=1)
    sched.step()
    assert a.preemptions == 0 and b.t_first == 0.0
    done = sched.run()
    assert sched.stats["preemptions"] == 0
    assert not done[a.rid].failed and not done[b.rid].failed


# -- satellite fixes ---------------------------------------------------------

def test_cancel_while_queued_reports_sane_metrics(setup):
    """Cancelling a request that never reached prefill used to report a
    negative TTFT (t_done set, t_first never); now ttft/tpot are None
    and latency is non-negative."""
    rcfg, params = setup
    sched = Scheduler(rcfg, params, max_batch=1, page_size=4,
                      max_len=MAX_LEN)
    running = sched.submit_request(np.arange(5, dtype=np.int32), 4)
    sched.step()
    queued = sched.submit_request(np.array([7, 6], np.int32), 4)
    sched.cancel(queued)
    assert queued.done and not queued.failed
    assert queued.ttft is None and queued.tpot is None
    assert queued.latency is not None and queued.latency >= 0.0
    done = sched.run()
    assert len(done[running.rid].out) == 4    # unaffected by the cancel


def test_cow_violation_raises_diagnostic(setup):
    """The COW invariant is an explicit debug-gated check (not a bare
    assert stripped under python -O): a shared page in a slot's write
    range raises COWViolationError naming slot, page, and refcount."""
    rcfg, params = setup
    sched = Scheduler(rcfg, params, max_batch=1, page_size=4,
                      max_len=MAX_LEN, debug_checks=True)
    sched.submit_request(np.arange(5, dtype=np.int32), 6)
    sched.step()
    page = int(sched.page_table[0, int(sched.lengths[0]) // 4])
    sched.alloc.share([page])         # simulate a bookkeeping bug
    with pytest.raises(COWViolationError, match=f"page {page} with "
                                                f"refcount 2"):
        sched.step()
    sched.alloc.free([page])


def test_bucket_len_clamped_to_hi():
    """bucket_len must not trace a wider-than-max_len prefill for
    prompts just under the cap."""
    assert bucket_len(5) == 8
    assert bucket_len(100) == 128
    assert bucket_len(100, hi=192) == 128
    assert bucket_len(130, hi=192) == 192      # clamped, not 256
    assert bucket_len(191, hi=192) == 192
    assert bucket_len(192, hi=192) == 192
    assert bucket_len(24, hi=24) == 24         # MAX_LEN-sized resume


# -- end-to-end acceptance ---------------------------------------------------

def test_mixed_priority_overload_drains_to_completion(setup):
    """ISSUE 7 acceptance: a concurrent mixed-priority workload with an
    unservable request in it drains to completion — the unservable one
    fails alone (visible via Request.error), everything else finishes,
    and the pool is fully free afterwards."""
    from repro.serve.engine import Request, ServeEngine

    rcfg, params = setup
    eng = ServeEngine(rcfg, params, max_batch=2, page_size=4,
                      max_len=MAX_LEN, n_pages=1 + 5)
    rng = np.random.default_rng(3)
    reqs = [Request(prompt=rng.integers(0, VOCAB, size=int(
                rng.integers(3, 10))).astype(np.int32),
                    max_new_tokens=int(rng.integers(2, 6)),
                    priority=i % 3, ttft_target_s=30.0,
                    tpot_target_s=30.0)
            for i in range(8)]
    # 20 prompt + 4 new tokens = 6 pages: can never fit the 5-page pool
    reqs[3] = Request(prompt=rng.integers(0, VOCAB, size=20).astype(
        np.int32), max_new_tokens=8, priority=0)
    out = eng.generate(reqs)
    assert out[3].error is not None and len(out[3].output) == 0
    for i, r in enumerate(out):
        if i == 3:
            continue
        assert r.error is None
        assert 1 <= len(r.output) <= r.max_new_tokens
        assert r.ttft_s is not None and r.ttft_s >= 0.0
        assert r.slo_met
    st = eng.stats
    assert st["requests_failed"] == 1 and st["requests_rejected"] == 1
    sched = eng.scheduler
    sched.drop_prefix_cache()
    assert sched.n_active == 0
    assert sched.alloc.n_free == sched.alloc.n_pages - 1
