"""Coarse-propagator speculative decoding: conformance + satellites.

The hard guarantee: with temperature 0, spec decode is token-for-token
identical to plain paged decode on every backend family — acceptance is
exact argmax match, so wrong drafts cost waves, never correctness. The
sampled path preserves the target distribution via rejection sampling;
the top_k=1 case collapses it back to greedy and is asserted bitwise.

Also covered here (PR satellites): engine stats counters for spec decode
and the prefix trie, streaming early termination releasing pages, and
prefix-cache persistence across an engine restart.
"""
import os

import jax
import numpy as np
import pytest

from repro.configs.base import (MGRITConfig, ModelConfig, OptimizerConfig,
                                RunConfig, SSMConfig, ShapeConfig)
from repro.core import mgrit
from repro.models import transformer
from repro.serve.engine import Request, ServeEngine
from repro.serve.spec import SpecConfig
from serve_oracle import engine_outputs

pytestmark = pytest.mark.serve

VOCAB = 64
MAX_LEN = 32

FAMILY_MODELS = {
    "decoder": dict(family="decoder"),
    "ssm": dict(family="ssm", n_layers=4, act="silu", norm="rmsnorm",
                ssm=SSMConfig(version=2, d_state=8, d_conv=3, headdim=16)),
    "hybrid": dict(family="hybrid", n_layers=5, hybrid_attn_every=2,
                   act="silu", norm="rmsnorm",
                   ssm=SSMConfig(version=2, d_state=8, d_conv=3,
                                 headdim=16)),
}


def family_rcfg(name: str) -> RunConfig:
    kw = dict(name=name, family="decoder", n_layers=8, d_model=32,
              n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=VOCAB,
              act="gelu", norm="layernorm", dtype="float32")
    kw.update(FAMILY_MODELS[name])
    return RunConfig(
        model=ModelConfig(**kw),
        mgrit=MGRITConfig(enabled=True, cf=2, levels=2, fwd_iters=1,
                          bwd_iters=1, n_open=1, n_close=1, pad_to=2),
        optimizer=OptimizerConfig(),
        shape=ShapeConfig(name, "train", 16, 4))


_PARAMS = {}


def family_setup(name: str):
    if name not in _PARAMS:
        rcfg = family_rcfg(name)
        params = transformer.init_model(
            jax.random.PRNGKey(sum(map(ord, name)) % 997), rcfg)
        _PARAMS[name] = (rcfg, params)
    return _PARAMS[name]


MIXED_REQS = [(np.array([5, 9, 3, 7, 2, 11], np.int32), 9),
              (np.array([1, 2, 3], np.int32), 7),
              (np.array([4], np.int32), 5)]


# ---------------------------------------------------------------------------
# Conformance: greedy spec decode == plain paged decode, all families
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(FAMILY_MODELS))
def test_spec_greedy_bitwise_equals_plain(name):
    """Acceptance criterion: temp-0 spec decode is token-for-token the
    plain paged engine on attention, SSM, and hybrid backends — mixed
    prompt lengths, continuous batching, uneven per-slot acceptance."""
    rcfg, params = family_setup(name)
    kw = dict(max_len=MAX_LEN, max_batch=2, page_size=4)
    _, ref = engine_outputs(rcfg, params, MIXED_REQS, **kw)
    eng, got = engine_outputs(rcfg, params, MIXED_REQS,
                              spec=SpecConfig(cf=2, k=3), **kw)
    for a, b in zip(ref, got, strict=True):
        np.testing.assert_array_equal(a, b)
    st = eng.stats
    assert st["verify_calls"] > 0 and st["tokens_drafted"] > 0
    # spec must finish in fewer decode waves than plain emits tokens
    assert st["decode_steps"] < sum(len(o) for o in got)


@pytest.mark.parametrize("cf,k", [(1, 1), (1, 4), (3, 2), (4, 5)])
def test_spec_cf_k_grid_stays_bitwise(cf, k):
    """cf=1 (draft == fine, everything accepted) and ragged cf/k combos
    all stay bitwise-greedy; cf=1 acceptance is exactly 1."""
    rcfg, params = family_setup("decoder")
    kw = dict(max_len=MAX_LEN, max_batch=2, page_size=4)
    _, ref = engine_outputs(rcfg, params, MIXED_REQS, **kw)
    eng, got = engine_outputs(rcfg, params, MIXED_REQS,
                              spec=SpecConfig(cf=cf, k=k), **kw)
    for a, b in zip(ref, got, strict=True):
        np.testing.assert_array_equal(a, b)
    if cf == 1:
        assert eng.stats["accept_rate"] == 1.0


def test_spec_eos_truncates_like_plain():
    """EOS inside an accepted burst truncates the output exactly where
    plain decode would have stopped."""
    rcfg, params = family_setup("decoder")
    kw = dict(max_len=MAX_LEN, max_batch=1, page_size=4)
    prompt = np.array([3, 1, 4], np.int32)
    _, (probe,) = engine_outputs(rcfg, params, [(prompt, 8)], **kw)
    eos = int(probe[2])                      # third emitted token
    reqs = [(prompt, 8, dict(eos_id=eos))]
    _, (ref,) = engine_outputs(rcfg, params, reqs, **kw)
    _, (got,) = engine_outputs(rcfg, params, reqs,
                               spec=SpecConfig(cf=2, k=4), **kw)
    np.testing.assert_array_equal(ref, got)
    assert len(got) == 3 and got[-1] == eos


def test_spec_topk1_sampling_collapses_to_greedy():
    """Distribution-preservation edge: top_k=1 at any temperature makes
    the target one-hot, so spec sampling must reproduce plain greedy
    bitwise (rejection sampling + leftover redraw included)."""
    rcfg, params = family_setup("ssm")
    kw = dict(max_len=MAX_LEN, max_batch=2, page_size=4)
    greedy_reqs = [(p, n) for p, n, *_ in MIXED_REQS]
    hot_reqs = [(p, n, dict(temperature=0.9, top_k=1, seed=11 + i))
                for i, (p, n) in enumerate(greedy_reqs)]
    _, ref = engine_outputs(rcfg, params, greedy_reqs, **kw)
    _, got = engine_outputs(rcfg, params, hot_reqs,
                            spec=SpecConfig(cf=2, k=3), **kw)
    for a, b in zip(ref, got, strict=True):
        np.testing.assert_array_equal(a, b)


def test_spec_sampled_is_deterministic_and_placement_free():
    """Sampled spec decode is a function of (prompt, params, seed) only:
    two runs agree, and so does a run with the batch order shuffled
    (slot placement must not leak into the streams)."""
    rcfg, params = family_setup("decoder")
    kw = dict(max_len=MAX_LEN, max_batch=2, page_size=4)
    reqs = [(np.array([7, 7, 2], np.int32), 6,
             dict(temperature=1.2, top_k=8, seed=5)),
            (np.array([9, 1], np.int32), 6,
             dict(temperature=0.7, top_p=0.9, seed=6))]
    _, a = engine_outputs(rcfg, params, reqs,
                          spec=SpecConfig(cf=2, k=3), **kw)
    _, b = engine_outputs(rcfg, params, reqs,
                          spec=SpecConfig(cf=2, k=3), **kw)
    _, c = engine_outputs(rcfg, params, reqs[::-1],
                          spec=SpecConfig(cf=2, k=3), **kw)
    for x, y in zip(a, b, strict=True):
        np.testing.assert_array_equal(x, y)
    for x, y in zip(a, c[::-1], strict=True):
        np.testing.assert_array_equal(x, y)


def test_spec_sampled_matches_target_distribution():
    """Rejection sampling preserves the target distribution: over many
    seeds, the first sampled token's empirical distribution under spec
    decode matches plain decode (both deterministic given seeds, so this
    comparison never flakes)."""
    rcfg, params = family_setup("decoder")
    kw = dict(max_len=MAX_LEN, max_batch=4, page_size=4)
    prompt = np.array([5, 9, 3], np.int32)

    def first_tokens(spec):
        toks = []
        for lo in range(0, 48, 8):
            reqs = [(prompt, 3, dict(temperature=1.5, seed=s))
                    for s in range(lo, lo + 8)]
            _, outs = engine_outputs(rcfg, params, reqs, spec=spec, **kw)
            toks += [o[2] for o in outs]     # third token: past prefill,
        return np.asarray(toks)              # shaped by accept/reject

    plain = first_tokens(None)
    spec = first_tokens(SpecConfig(cf=2, k=3))
    # same target law, independent draws: compare histograms loosely
    hp = np.bincount(plain, minlength=VOCAB) / len(plain)
    hs = np.bincount(spec, minlength=VOCAB) / len(spec)
    assert 0.5 * np.abs(hp - hs).sum() < 0.45   # total-variation bound


def test_spec_counters_in_engine_stats():
    rcfg, params = family_setup("decoder")
    eng, _ = engine_outputs(rcfg, params, MIXED_REQS, max_len=MAX_LEN,
                            max_batch=2, page_size=4,
                            spec=SpecConfig(cf=2, k=3))
    st = eng.stats
    for key in ("draft_calls", "verify_calls", "tokens_drafted",
                "tokens_accepted", "accept_rate", "trie_hit_pages",
                "trie_miss_prompts", "trie_evictions"):
        assert key in st, key
    assert st["draft_calls"] > st["verify_calls"]   # + draft prefills
    assert 0.0 <= st["accept_rate"] <= 1.0


def test_coarse_restrict_is_every_cf_th_layer():
    """The serve draft reuses the solver's level restriction: every
    cf-th slice, ragged tails allowed."""
    stacked = {"w": np.arange(7 * 3).reshape(7, 3)}
    got = mgrit.coarse_restrict(stacked, 3)
    np.testing.assert_array_equal(got["w"], stacked["w"][[0, 3, 6]])
    rcfg, params = family_setup("decoder")
    draft, _, n_coarse = transformer.coarse_draft_params(params, rcfg, 3)
    n_fine = rcfg.mgrit.n_open + rcfg.mgrit.n_close \
        + transformer.depth_plan(rcfg.model.n_layers, rcfg.mgrit).n_mid_padded
    assert n_coarse == -(-n_fine // 3)
    # the coarse gates sum the fine gates: total ODE time span preserved
    assert float(draft["mid"]["gate"].sum()) == float(
        rcfg.mgrit.n_open + rcfg.mgrit.n_close
        + transformer.depth_plan(rcfg.model.n_layers,
                                 rcfg.mgrit).n_mid_real)


# ---------------------------------------------------------------------------
# Satellites: streaming early termination + prefix persistence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", [None, SpecConfig(cf=2, k=3)],
                         ids=["plain", "spec"])
def test_streaming_early_termination_releases_pages(spec):
    """Dropping a stream=True iterator mid-generation must cancel the
    request and hand its pages back to the allocator."""
    rcfg, params = family_setup("decoder")
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                      page_size=4, spec=spec)
    sched = eng.scheduler
    free0 = sched.alloc.n_free
    req = Request(prompt=np.array([2, 4, 6, 8, 1], np.int32),
                  max_new_tokens=12)
    stream = eng.submit(req, stream=True)
    got = [next(stream) for _ in range(2)]      # mid-generation...
    assert len(got) == 2
    stream.close()                              # ...and dropped
    assert sched.n_active == 0
    sched.drop_prefix_cache()
    assert sched.alloc.n_free == free0
    assert req.output is not None and len(req.output) >= 2
    # the engine keeps serving normally afterwards
    out = eng.generate([Request(prompt=np.array([1, 2], np.int32),
                                max_new_tokens=3)])
    assert len(out[0].output) == 3


def test_cancel_queued_request_never_admits_it():
    """Scheduler.cancel on a still-queued request removes it from the
    queue; the rest of the queue drains normally."""
    rcfg, params = family_setup("decoder")
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=1,
                      page_size=4)
    rid1 = eng.submit(Request(prompt=np.array([1, 2, 3], np.int32),
                              max_new_tokens=4))
    sreq2 = eng.scheduler.submit_request(np.array([4, 5, 6], np.int32), 4)
    eng.scheduler.cancel(sreq2)
    done = eng.scheduler.run()
    assert len(done[rid1].out) == 4
    assert sreq2.done and len(sreq2.out) == 0


@pytest.mark.parametrize("name", ["decoder", "ssm"])
def test_prefix_cache_persists_across_engine_restart(name, tmp_path):
    """PrefixCache.save/load round-trips the trie + pinned page contents:
    a restarted engine serves a cached prompt without re-prefilling the
    shared prefix, with identical outputs."""
    rcfg, params = family_setup(name)
    path = os.path.join(tmp_path, "prefix.npz")
    common = np.arange(1, 9, dtype=np.int32) % VOCAB       # 2 pages of 4
    reqs = [(np.concatenate([common, np.array([20 + i], np.int32)]), 4)
            for i in range(2)]
    eng1, ref = engine_outputs(rcfg, params, reqs, max_len=MAX_LEN,
                               max_batch=2, page_size=4)
    n_saved = eng1.save_prefix_cache(path)
    assert n_saved == eng1.scheduler.prefix.n_cached_pages > 0

    eng2 = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                       page_size=4, prefix_cache_path=path)
    assert eng2.scheduler.prefix.n_cached_pages == n_saved
    out = eng2.generate([Request(prompt=p, max_new_tokens=n)
                         for p, n in reqs])
    for a, b in zip(ref, out, strict=True):
        np.testing.assert_array_equal(a, b.output)
    st = eng2.scheduler.stats
    assert st["shared_tokens"] >= len(common)   # restored pages reused
    eng2.scheduler.drop_prefix_cache()
    assert eng2.scheduler.alloc.n_free == eng2.scheduler.alloc.n_pages - 1


def test_prefix_cache_load_rejects_page_size_mismatch(tmp_path):
    rcfg, params = family_setup("decoder")
    path = os.path.join(tmp_path, "prefix.npz")
    eng1, _ = engine_outputs(
        rcfg, params, [(np.arange(1, 9, dtype=np.int32), 2)],
        max_len=MAX_LEN, max_batch=1, page_size=4)
    eng1.save_prefix_cache(path)
    eng2 = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=1,
                       page_size=8)
    with pytest.raises(ValueError, match="page_size"):
        eng2.load_prefix_cache(path)


# ---------------------------------------------------------------------------
# Property check (optional hypothesis dependency, like test_properties)
# ---------------------------------------------------------------------------


def test_spec_conformance_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    rcfg, params = family_setup("decoder")

    @settings(max_examples=8, deadline=None)
    @given(data=st.data())
    def run(data):
        rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
        n_req = data.draw(st.integers(1, 3))
        reqs = [(rng.integers(0, VOCAB, size=int(rng.integers(1, 12)))
                 .astype(np.int32), int(rng.integers(1, 8)))
                for _ in range(n_req)]
        k = data.draw(st.integers(1, 5))
        cf = data.draw(st.integers(1, 5))
        kw = dict(max_len=MAX_LEN, max_batch=2, page_size=4)
        _, ref = engine_outputs(rcfg, params, reqs, **kw)
        _, got = engine_outputs(rcfg, params, reqs,
                                spec=SpecConfig(cf=cf, k=k), **kw)
        for a, b in zip(ref, got, strict=True):
            np.testing.assert_array_equal(a, b)

    run()
