"""Doc-drift guards (CI doc-lint step — no jax import, runs anywhere).

1. Every intra-repo markdown link in README.md / ROADMAP.md / docs/*
   resolves to a real file, and a ``file.md#fragment`` link names a
   heading that actually exists in the target.
2. Every CacheBackend method named in docs/cache-backends.md (the
   protocol tables and ``CacheBackend.x`` references) exists on the
   class in src/repro/serve/cache.py — the protocol doc cannot silently
   drift from the code. Checked with ``ast`` so the lint job needs no
   model dependencies.
"""
import ast
import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_FILES = ["README.md", "ROADMAP.md"] + sorted(
    os.path.join("docs", f) for f in os.listdir(os.path.join(REPO, "docs"))
    if f.endswith(".md"))

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _headings(path):
    """GitHub-style anchor slugs for every heading in a markdown file
    (lines inside ``` fences are code, not headings)."""
    slugs = set()
    fenced = False
    for line in open(path, encoding="utf-8"):
        if line.lstrip().startswith("```"):
            fenced = not fenced
            continue
        m = None if fenced else re.match(r"#+\s+(.*)", line)
        if m:
            text = re.sub(r"[`*]", "", m.group(1).strip()).lower()
            slugs.add(re.sub(r"[^\w\- ]", "", text).replace(" ", "-"))
    return slugs


@pytest.mark.parametrize("doc", DOC_FILES)
def test_intra_repo_markdown_links_resolve(doc):
    path = os.path.join(REPO, doc)
    body = open(path, encoding="utf-8").read()
    bad = []
    for target in _LINK.findall(body):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        file_part, _, frag = target.partition("#")
        dest = os.path.normpath(os.path.join(
            os.path.dirname(path), file_part)) if file_part else path
        if not dest.startswith(REPO + os.sep):
            continue      # GitHub-site-relative (e.g. the CI badge)
        if not os.path.exists(dest):
            bad.append(f"{target}: {file_part} does not exist")
        elif frag and dest.endswith(".md") \
                and frag not in _headings(dest):
            bad.append(f"{target}: no heading #{frag} in {file_part}")
    assert not bad, f"{doc}: broken links: {bad}"


def _cache_backend_names():
    """Method names of CacheBackend + module-level callables in
    serve/cache.py, via ast (no repro import needed)."""
    src = os.path.join(REPO, "src", "repro", "serve", "cache.py")
    tree = ast.parse(open(src, encoding="utf-8").read())
    methods, module_fns = set(), set()
    for node in tree.body:
        if isinstance(node, ast.FunctionDef):
            module_fns.add(node.name)
        if isinstance(node, ast.ClassDef) and node.name == "CacheBackend":
            methods = {n.name for n in node.body
                       if isinstance(n, ast.FunctionDef)}
    assert methods, "CacheBackend class not found in serve/cache.py"
    return methods, module_fns


def test_cache_backends_doc_methods_exist():
    """The protocol tables (| `name(...)` | rows) and dotted
    ``CacheBackend.name`` references in docs/cache-backends.md must all
    name real CacheBackend methods."""
    methods, module_fns = _cache_backend_names()
    body = open(os.path.join(REPO, "docs", "cache-backends.md"),
                encoding="utf-8").read()
    named = set()
    for line in body.splitlines():
        m = re.match(r"\|\s*`([A-Za-z_]\w*)\s*\(", line)
        if m:
            named.add(m.group(1))
    named |= set(re.findall(r"CacheBackend\.([A-Za-z_]\w*)", body))
    assert named >= {"init", "prefill", "step", "verify", "fork"}, \
        f"protocol tables look truncated: only found {sorted(named)}"
    missing = sorted(n for n in named
                     if n not in methods and n not in module_fns)
    assert not missing, (
        f"docs/cache-backends.md names CacheBackend methods that do not "
        f"exist: {missing}")


def _registry_rule_ids():
    """Rule ids from the staticcheck registry — the package is
    stdlib-only, so importing it keeps this job jax-free."""
    import sys
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.analysis.staticcheck import RULES
    return set(RULES)


def test_static_analysis_doc_matches_registry():
    """Every rule id named in docs/static-analysis.md exists in the
    staticcheck registry, and every registered rule is documented in
    the catalog table — the doc and the gate cannot drift apart."""
    body = open(os.path.join(REPO, "docs", "static-analysis.md"),
                encoding="utf-8").read()
    named = set(re.findall(r"\b([A-Z]{2}\d{3})\b", body))
    rules = _registry_rule_ids()
    assert named, "no rule ids found in docs/static-analysis.md"
    ghosts = sorted(named - rules)
    assert not ghosts, (
        f"docs/static-analysis.md names rules not in the registry: "
        f"{ghosts}")
    undocumented = sorted(rules - named)
    assert not undocumented, (
        f"registered rules missing from docs/static-analysis.md: "
        f"{undocumented}")


def test_observability_doc_matches_catalog():
    """Every metric in docs/observability.md's tables exists in
    METRIC_CATALOG and every catalogued metric is documented — the
    catalog and the doc cannot drift apart (metrics.py is stdlib-only,
    so importing it keeps this job jax-free)."""
    import sys
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.obs.metrics import METRIC_CATALOG
    body = open(os.path.join(REPO, "docs", "observability.md"),
                encoding="utf-8").read()
    named = set()
    for line in body.splitlines():
        m = re.match(r"\|\s*`([a-z_]+\.[a-z_]+)`\s*\|", line)
        if m:
            named.add(m.group(1))
    assert named, "no metric table rows found in docs/observability.md"
    ghosts = sorted(named - set(METRIC_CATALOG))
    assert not ghosts, (
        f"docs/observability.md documents metrics not in METRIC_CATALOG: "
        f"{ghosts}")
    undocumented = sorted(set(METRIC_CATALOG) - named)
    assert not undocumented, (
        f"catalogued metrics missing from docs/observability.md: "
        f"{undocumented}")
