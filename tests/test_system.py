"""End-to-end system tests: training with adaptive switching, serving,
elastic checkpoint restore, and sharding-rule coherence (subprocess with a
forced multi-device host platform)."""
import json
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import numpy as np
import pytest

from repro.configs.base import (MGRITConfig, ModelConfig, OptimizerConfig,
                                RunConfig, ShapeConfig)
from repro.serve.engine import Request, ServeEngine
from repro.models import transformer
from repro.train.trainer import Trainer


def tiny_rcfg(lp=True, **mg_kw):
    model = ModelConfig(name="sys", family="decoder", n_layers=8, d_model=32,
                        n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                        act="gelu", norm="layernorm")
    mg = dict(enabled=lp, cf=2, levels=2, fwd_iters=1, bwd_iters=1,
              pad_to=8, check_every=5)
    mg.update(mg_kw)
    return RunConfig(model=model, mgrit=MGRITConfig(**mg),
                     optimizer=OptimizerConfig(name="sgd", lr=0.05,
                                               warmup_steps=2,
                                               total_steps=50),
                     shape=ShapeConfig("sys", "train", 16, 4))


def test_adaptive_switch_forced_by_threshold():
    """With threshold 0 the first probe must switch LP -> serial and the
    run must continue to train (the paper's Fig. 4 green-curve mechanism)."""
    rcfg = tiny_rcfg(switch_threshold=0.0)
    tr = Trainer(rcfg, seed=0)
    rep = tr.train(12, log_every=0, probe=True)
    assert rep.switched_at is not None
    assert rep.mode_trace[-1] == "serial"
    assert rep.mode_trace[0] == "lp"
    assert np.isfinite(rep.losses).all()


def test_serve_engine_generates():
    rcfg = tiny_rcfg()
    params = transformer.init_model(jax.random.PRNGKey(0), rcfg)
    eng = ServeEngine(rcfg, params, max_len=32)
    reqs = [Request(prompt=np.array([1, 2, 3], np.int32), max_new_tokens=4),
            Request(prompt=np.array([5, 6], np.int32), max_new_tokens=4)]
    out = eng.generate(reqs)
    for r in out:
        assert r.output.shape == (4,)
        assert ((r.output >= 0) & (r.output < 64)).all()


def test_elastic_restore_roundtrip():
    """A checkpoint written under one run restores into a fresh trainer
    (the elastic path stores logical arrays; mesh-specific placement is
    re-derived)."""
    from repro.train import checkpoint as ckpt
    rcfg = tiny_rcfg()
    tr = Trainer(rcfg, seed=0)
    tr.train(3, log_every=0, probe=False)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 3, tr.params, tr.opt_state)
        restored = ckpt.restore(d, tr.params, tr.opt_state)
        assert restored is not None
        p2, o2, step, _ = restored
        assert step == 3
        np.testing.assert_allclose(
            np.asarray(jax.tree.leaves(tr.params)[0]),
            np.asarray(jax.tree.leaves(p2)[0]))


_SHARDING_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, json
    import numpy as np
    from repro.configs import registry
    from repro.launch import specs as specs_mod
    from repro.parallel.params import param_specs, batch_specs

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    out = {}
    for arch in ("deepseek_7b", "qwen3_moe_235b", "falcon_mamba_7b"):
        rcfg = registry.get_config(arch, "train_4k")
        params = specs_mod.params_specs(rcfg)
        ps = param_specs(params, rcfg, mesh)
        flat, _ = jax.tree_util.tree_flatten_with_path(ps)
        layer_sharded = 0
        for path, s in flat:
            spec = s.spec
            if len(spec) and spec[0] == "model":
                layer_sharded += 1
        out[arch] = layer_sharded
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_sharding_rules_subprocess():
    """param_specs shards the stacked trunk over 'model' for LP archs
    (verified on a real 8-device host mesh in a subprocess)."""
    r = subprocess.run([sys.executable, "-c", _SHARDING_SCRIPT],
                       capture_output=True, text=True, cwd=os.getcwd(),
                       timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    # every LP arch must shard a substantial number of trunk leaves on the
    # layers->model axis
    for arch, n in out.items():
        assert n >= 5, f"{arch}: only {n} layer-sharded leaves"


def test_train_cli_reduced():
    from repro.launch import train as train_cli
    rc = train_cli.main(["--arch", "qwen3_1p7b", "--reduced",
                         "--steps", "2"])
    assert rc == 0
