"""Gradient correctness of the layer-parallel custom VJP.

Oracle: direct jax.grad through the exact serial scan. The serial-mode
lp_forward (fwd_iters=bwd_iters=0, i.e. the discrete adjoint) must match it
to numerical precision; MGRIT-mode gradients must converge to it as the
iteration counts grow (the paper's controllable inexactness)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.reduce import reduce_config
from repro.core import lp, mgrit
from repro.core.lp import LPStatic, lp_forward
from repro.models import transformer


def tiny_rcfg(fwd_iters, bwd_iters):
    rcfg = reduce_config(registry.get_config("deepseek_7b"))
    mg = dataclasses.replace(rcfg.mgrit, fwd_iters=fwd_iters,
                             bwd_iters=bwd_iters)
    return dataclasses.replace(rcfg, mgrit=mg)


def setup(key, rcfg):
    params = transformer.init_model(key, rcfg)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (2, 8), 0,
                              rcfg.model.vocab_size)
    labels = jax.random.randint(jax.random.fold_in(key, 2), (2, 8), 0,
                                rcfg.model.vocab_size)
    return params, {"tokens": toks, "labels": labels}


def _flat(tree):
    return jnp.concatenate([jnp.ravel(x).astype(jnp.float32)
                            for x in jax.tree.leaves(tree)])


def test_serial_adjoint_matches_direct_ad():
    """Exact adjoint (iters=0) == autodiff through the serial scan."""
    rcfg = tiny_rcfg(0, 0)
    key = jax.random.PRNGKey(0)
    params, batch = setup(key, rcfg)

    def loss_adjoint(p):
        return transformer.loss_fn(p, batch, rcfg, mode="serial")[0]

    # direct-AD oracle: same forward, but differentiate *through* the scan
    def loss_direct(p):
        static = LPStatic(cfg=rcfg.model, mgrit=rcfg.mgrit, kind="attn_mlp",
                          causal=True)
        from repro.models.layers import rope_freqs
        from repro.models.transformer import (_embed_inputs, _serial_buffer,
                                              lm_loss)
        from repro.models.layers import norm_apply, unembed
        cfg = rcfg.model
        z = _embed_inputs(p, batch, cfg)
        rope = rope_freqs(cfg.resolved_head_dim, cfg.rope_theta,
                          jnp.arange(8, dtype=jnp.int32))
        z = _serial_buffer(p.get("open"), z, cfg, kind="attn_mlp",
                           causal=True, rope=rope)
        step = lp.make_fwd_step(static, {"rope": rope})
        _, zT = mgrit.serial_solve(step, p["mid"], z, rcfg.mgrit.h)
        zT = _serial_buffer(p.get("close"), zT, cfg, kind="attn_mlp",
                            causal=True, rope=rope)
        zT = norm_apply(p["final_norm"], zT, cfg)
        return lm_loss(unembed(p["embed"], zT, cfg), batch["labels"])

    la, ga = jax.value_and_grad(loss_adjoint)(params)
    ld, gd = jax.value_and_grad(loss_direct)(params)
    np.testing.assert_allclose(float(la), float(ld), rtol=1e-5)
    # the gates are structural constants: the adjoint returns zero for them
    # by design, so zero them in the direct-AD oracle as well
    gd["mid"]["gate"] = jnp.zeros_like(gd["mid"]["gate"])
    ga["mid"]["gate"] = jnp.zeros_like(ga["mid"]["gate"])
    fa, fd = np.asarray(_flat(ga)), np.asarray(_flat(gd))
    # the adjoint reassociates reductions; in bf16 compute that leaves
    # ~1e-2 relative noise — check direction + magnitude agreement
    cos = float(np.dot(fa, fd)
                / (np.linalg.norm(fa) * np.linalg.norm(fd) + 1e-30))
    assert cos > 0.9999, f"cosine {cos}"
    np.testing.assert_allclose(np.linalg.norm(fa), np.linalg.norm(fd),
                               rtol=1e-2)
    np.testing.assert_allclose(fa, fd, rtol=5e-2, atol=5e-3)


@pytest.mark.parametrize("iters,min_cos", [(1, 0.90), (4, 0.999)])
def test_mgrit_grads_converge_to_exact(iters, min_cos):
    key = jax.random.PRNGKey(1)
    rcfg_exact = tiny_rcfg(0, 0)
    params, batch = setup(key, rcfg_exact)
    g_exact = jax.grad(
        lambda p: transformer.loss_fn(p, batch, rcfg_exact, mode="serial")[0]
    )(params)

    rcfg_lp = tiny_rcfg(iters, iters)
    g_lp = jax.grad(
        lambda p: transformer.loss_fn(p, batch, rcfg_lp, mode="lp")[0]
    )(params)

    fe, fl = _flat(g_exact), _flat(g_lp)
    cos = float(jnp.dot(fe, fl)
                / (jnp.linalg.norm(fe) * jnp.linalg.norm(fl) + 1e-30))
    assert cos > min_cos, f"cosine {cos} too low at iters={iters}"


def test_padded_layers_receive_zero_grads():
    rcfg = tiny_rcfg(1, 1)
    # force real padding: 8 mid layers padded to 12
    rcfg = dataclasses.replace(
        rcfg, mgrit=dataclasses.replace(rcfg.mgrit, pad_to=12, cf=2))
    key = jax.random.PRNGKey(2)
    params, batch = setup(key, rcfg)
    grads = jax.grad(
        lambda p: transformer.loss_fn(p, batch, rcfg, mode="lp")[0])(params)
    gate = np.asarray(params["mid"]["gate"])
    pad_idx = np.where(gate == 0.0)[0]
    assert pad_idx.size > 0
    for leaf in jax.tree.leaves(grads["mid"]["params"]):
        arr = np.asarray(leaf, np.float32)
        assert np.allclose(arr[pad_idx], 0.0), "padded layer got gradient"


def test_fwd_residual_norms_exposed():
    rcfg = tiny_rcfg(3, 1)
    key = jax.random.PRNGKey(3)
    params, batch = setup(key, rcfg)
    _, diag = transformer.loss_fn(params, batch, rcfg, mode="lp")
    norms = np.asarray(diag["fwd_norms"])
    assert norms.shape == (3,)
    assert np.all(np.isfinite(norms))
