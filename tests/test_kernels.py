"""Pallas kernels vs pure-jnp oracles (interpret mode), sweeping
shapes/dtypes per the assignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.rmsnorm import rmsnorm_2d
from repro.kernels.ssm_scan import ssm_scan


def rand(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.5).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hkv,Sq,Sk,hd,causal", [
    (1, 4, 4, 128, 128, 64, True),
    (2, 4, 2, 128, 128, 32, True),     # GQA
    (1, 8, 1, 256, 256, 64, True),     # MQA
    (1, 2, 2, 128, 256, 64, False),    # cross-ish, non-causal
    (2, 2, 2, 384, 384, 128, True),    # 3 q blocks, hd=128
])
def test_flash_attention_matches_ref(dtype, B, H, Hkv, Sq, Sk, hd, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (B, H, Sq, hd), dtype)
    k = rand(ks[1], (B, Hkv, Sk, hd), dtype)
    v = rand(ks[2], (B, Hkv, Sk, hd), dtype)
    out = flash_attention_bhsd(q, k, v, causal=causal, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("R,D,rb", [(256, 512, 128), (512, 128, 256),
                                    (64, 2048, 64)])
def test_rmsnorm_matches_ref(dtype, R, D, rb):
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    x = rand(ks[0], (R, D), dtype)
    w = jnp.ones((D,), jnp.float32) + rand(ks[1], (D,), jnp.float32) * 0.1
    out = rmsnorm_2d(x, w, row_block=rb, interpret=True)
    expect = ref.rmsnorm_ref(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("Bb,S,di,ds,chunk", [
    (2, 128, 64, 16, 32),
    (1, 64, 128, 8, 64),
    (2, 256, 32, 16, 128),
])
def test_ssm_scan_matches_ref(dtype, Bb, S, di, ds, chunk):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    dt = jax.nn.softplus(rand(ks[0], (Bb, S, di), jnp.float32)) * 0.2
    x = rand(ks[1], (Bb, S, di), dtype)
    A = -jnp.exp(rand(ks[2], (di, ds), jnp.float32))
    B = rand(ks[3], (Bb, S, ds), dtype)
    C = rand(ks[4], (Bb, S, ds), dtype)
    D = jnp.ones((di,), jnp.float32)
    out = ssm_scan(dt.astype(dtype), x, A, B, C, D, chunk=chunk,
                   interpret=True)
    expect = ref.ssm_scan_ref(dt.astype(dtype), x, A, B, C, D)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_model_layout():
    """ops.flash_attention consumes the model's (B,S,H,hd) layout and matches
    the model's dense reference path."""
    from repro.kernels import ops
    from repro.models.attention import dot_attention
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = rand(ks[0], (2, 128, 4, 32), jnp.float32)
    k = rand(ks[1], (2, 128, 2, 32), jnp.float32)
    v = rand(ks[2], (2, 128, 2, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    expect = dot_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)
