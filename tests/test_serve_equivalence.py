"""Differential serve-equivalence: chunked interleaving + token-granular
prefix sharing must be invisible in every token stream.

The headline suite for the PR-10 harness (``serve_oracle.
serve_equivalence``): any workload run with chunked-prefill/decode
interleaving AND partial-page prefix sharing ON emits per-request
**bitwise** the token streams of the serial whole-page engine — at
temperature 0 and under seeded sampling, on attention, SSM, and hybrid
backends — while the trace proves no decode wave waited for more than
one chunk budget of prefill (``chunk_wave_invariant``).
"""
import jax
import numpy as np
import pytest
from serve_oracle import chunk_wave_invariant, serve_equivalence

from repro.configs.base import (MGRITConfig, ModelConfig, OptimizerConfig,
                                RunConfig, SSMConfig, ShapeConfig)
from repro.models import transformer
from repro.obs.trace import SPAN
from repro.serve.engine import Request, ServeEngine

pytestmark = pytest.mark.serve

VOCAB = 32
MAX_LEN = 48


def _setup(fam: str, seed: int = 0):
    kw = dict(name=fam, family="decoder", n_layers=4, d_model=16,
              n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=VOCAB,
              act="gelu", norm="layernorm", dtype="float32")
    if fam == "ssm_mamba1":
        kw.update(family="ssm", ssm=SSMConfig(version=1, d_state=8,
                                              d_conv=3))
    elif fam == "hybrid":
        kw.update(family="hybrid", n_layers=5, hybrid_attn_every=2,
                  ssm=SSMConfig(version=2, d_state=8, d_conv=3,
                                headdim=16))
    rcfg = RunConfig(
        model=ModelConfig(**kw),
        mgrit=MGRITConfig(enabled=True, cf=2, levels=2, fwd_iters=1,
                          bwd_iters=1, n_open=1, n_close=1, pad_to=2),
        optimizer=OptimizerConfig(),
        shape=ShapeConfig(fam, "train", 16, 4))
    params = transformer.init_model(jax.random.PRNGKey(seed), rcfg)
    return rcfg, params


def _workload(rng, n_reqs: int, page_size: int):
    """Mixed specs: prompt lengths straddle page boundaries (one short
    of, exactly on, and past a boundary), greedy and seeded-sampled
    requests interleaved — the shapes that break chunk-resume math."""
    common = rng.integers(0, VOCAB, size=page_size + 3).astype(np.int32)
    reqs = []
    for i in range(n_reqs):
        n = int(rng.choice([page_size - 1, page_size, page_size + 1,
                            2 * page_size + 3, 3 * page_size - 2,
                            int(rng.integers(2, 3 * page_size))]))
        prompt = rng.integers(0, VOCAB, size=n).astype(np.int32)
        if rng.random() < 0.4:          # shared-prefix population
            prompt = np.concatenate([common, prompt])[:MAX_LEN - 8]
        kw = {}
        if i % 2:
            kw = dict(temperature=float(rng.uniform(0.3, 1.2)),
                      top_k=int(rng.choice([0, 8])),
                      top_p=float(rng.choice([1.0, 0.9])),
                      seed=int(rng.integers(0, 1000)))
        reqs.append((prompt, int(rng.integers(2, 7)), kw))
    return reqs


@pytest.mark.parametrize("fam,seed", [("decoder", 0), ("ssm_mamba1", 1),
                                      ("hybrid", 2)])
def test_interleaved_partial_bitwise_equal_all_families(fam, seed):
    """The acceptance headline: every family, temp 0 AND seeded
    sampling in one workload, chunk budget smaller than most prompts so
    multi-wave ingest actually happens."""
    rcfg, params = _setup(fam, seed)
    rng = np.random.default_rng(seed)
    reqs = _workload(rng, 8, page_size=8)
    serve_equivalence(rcfg, params, reqs, chunk_tokens=10,
                      max_len=MAX_LEN, max_batch=3, page_size=8)


def test_partial_sharing_reuses_37_of_64_token_page():
    """ISSUE 10's literal scenario: a prompt sharing only the first 37
    tokens of a finished prompt's 64-token page reuses exactly those 37
    tokens via fork_partial — bitwise equal to recomputing them."""
    rcfg, params = _setup("decoder")
    rng = np.random.default_rng(3)
    base = rng.integers(0, VOCAB, size=64 + 37).astype(np.int32)
    follow = np.concatenate(
        [base[:64 + 37], rng.integers(0, VOCAB, size=9).astype(np.int32)])

    def run(partial):
        eng = ServeEngine(rcfg, params, max_len=256, max_batch=1,
                          page_size=64, partial_prefix=partial)
        outs = []
        for p in (base, follow):        # sequential: tail publishes at reap
            outs.append(eng.generate(
                [Request(prompt=p.copy(), max_new_tokens=6)])[0].output)
        return eng, outs

    e_off, off = run(False)
    e_on, on = run(True)
    for a, b in zip(off, on, strict=True):
        np.testing.assert_array_equal(a, b)
    assert e_on.stats["prefix_partial_hits"] == 1
    assert e_on.stats["prefix_partial_tokens_shared"] == 37
    # exactly the 37 reused tokens disappear from recomputation
    assert e_off.stats["prefill_tokens"] - e_on.stats["prefill_tokens"] == 37


def test_chunked_ingest_interleaves_with_live_decode():
    """A long prompt admitted while another request decodes must not
    stall it: some scheduler wave carries BOTH a prefill_chunk span and
    a decode span, and the wave invariant holds throughout."""
    rcfg, params = _setup("decoder")
    rng = np.random.default_rng(4)
    budget = 8
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                      page_size=8, prefill_chunk_tokens=budget)
    short = Request(prompt=rng.integers(0, VOCAB, 4).astype(np.int32),
                    max_new_tokens=12)
    eng.submit(short)
    eng.scheduler.step()                 # short is admitted and decoding
    long = Request(
        prompt=rng.integers(0, VOCAB, MAX_LEN - 10).astype(np.int32),
        max_new_tokens=4)
    eng.submit(long)
    eng.scheduler.run()
    assert short.error is None and long.error is None
    events = eng.obs.trace.events()
    assert chunk_wave_invariant(events, budget) == []
    chunk_waves = {w for ph, _t, _d, k, rid, _s, w, _a in events
                   if ph == SPAN and k == "prefill_chunk" and rid < 0}
    decode_waves = {w for ph, _t, _d, k, rid, _s, w, _a in events
                    if ph == SPAN and k == "decode" and rid < 0}
    assert len(chunk_waves) >= 3         # multi-wave ingest happened
    assert chunk_waves & decode_waves, \
        "no wave ran decode alongside a prefill chunk — the long " \
        "prompt stalled the running request"


def test_spec_decode_composes_with_chunking():
    """Speculative waves skip mid-ingest slots and stay bitwise equal
    to the serial spec engine."""
    from repro.serve.spec import SpecConfig
    rcfg, params = _setup("decoder", seed=5)
    rng = np.random.default_rng(5)
    reqs = _workload(rng, 5, page_size=8)
    serve_equivalence(rcfg, params, reqs, chunk_tokens=9,
                      max_len=MAX_LEN, max_batch=3, page_size=8,
                      spec=SpecConfig(cf=2, k=3))


def test_equivalence_under_preemption_pressure():
    """Small pool + mixed priorities: preemption (spill and the forced
    mid-ingest recompute path) composes with interleaving bitwise."""
    rcfg, params = _setup("decoder", seed=6)
    rng = np.random.default_rng(6)
    reqs = []
    for i in range(8):
        prompt = rng.integers(0, VOCAB, size=int(
            rng.integers(4, 20))).astype(np.int32)
        reqs.append((prompt, int(rng.integers(2, 6)),
                     {"priority": int(rng.integers(0, 3))}))
    serve_equivalence(rcfg, params, reqs, chunk_tokens=6,
                      max_len=MAX_LEN, max_batch=2, page_size=4,
                      n_pages=1 + 14)   # tight: forces preempt/skip-ahead
