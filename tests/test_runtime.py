"""Runtime substrate tests: checkpoint/restore, trainer switching,
data determinism, optimizer, compression."""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (MGRITConfig, ModelConfig, OptimizerConfig,
                                RunConfig, ShapeConfig)
from repro.core.adaptive import AdaptiveController, convergence_factor
from repro.data.pipeline import SyntheticLM
from repro.optim import optimizers
from repro.parallel import compression
from repro.train import checkpoint as ckpt
from repro.train.trainer import Trainer


def tiny_rcfg(lp=True, steps=30):
    model = ModelConfig(name="t", family="encoder", n_layers=8, d_model=32,
                        n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                        act="gelu", norm="layernorm")
    return RunConfig(
        model=model,
        mgrit=MGRITConfig(enabled=lp, cf=2, levels=2, fwd_iters=1,
                          bwd_iters=1, pad_to=8, check_every=10),
        optimizer=OptimizerConfig(name="sgd", lr=0.05, warmup_steps=2,
                                  total_steps=steps),
        shape=ShapeConfig("t", "train", 16, 4))


def test_data_pipeline_deterministic():
    rcfg = tiny_rcfg()
    p1 = SyntheticLM(rcfg, seed=3)
    p2 = SyntheticLM(rcfg, seed=3)
    b1, b2 = p1.batch_at(17), p2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch_at(17)["tokens"],
                              p1.batch_at(18)["tokens"])


def test_trainer_loss_decreases():
    tr = Trainer(tiny_rcfg(), seed=0)
    rep = tr.train(30, log_every=0, probe=False)
    assert np.mean(rep.losses[-5:]) < np.mean(rep.losses[:5])


def test_checkpoint_roundtrip_and_resume():
    with tempfile.TemporaryDirectory() as d:
        rcfg = tiny_rcfg()
        tr = Trainer(rcfg, ckpt_dir=d, seed=0)
        tr.train(6, ckpt_every=3, log_every=0, probe=False)
        p_before = jax.tree.leaves(tr.params)[0]

        tr2 = Trainer(rcfg, ckpt_dir=d, seed=0)
        assert tr2.step == 6
        p_after = jax.tree.leaves(tr2.params)[0]
        np.testing.assert_allclose(np.asarray(p_before),
                                   np.asarray(p_after), rtol=1e-6)
        # determinism: continued run equals uninterrupted run
        tr2.train(4, log_every=0, probe=False)
        tr3 = Trainer(rcfg, seed=0)
        tr3.train(10, log_every=0, probe=False)
        np.testing.assert_allclose(
            np.asarray(jax.tree.leaves(tr2.params)[0]),
            np.asarray(jax.tree.leaves(tr3.params)[0]), atol=1e-5)


def test_checkpoint_rotation_and_latest():
    with tempfile.TemporaryDirectory() as d:
        params = {"w": jnp.ones((4,))}
        opt = {"step": jnp.zeros((), jnp.int32)}
        for s in (1, 2, 3, 4, 5):
            ckpt.save(d, s, params, opt, keep=2)
        dirs = [x for x in os.listdir(d) if x.startswith("step_")]
        assert len(dirs) == 2
        assert ckpt.latest_step(d) == 5


def test_adaptive_controller_switches():
    c = AdaptiveController(MGRITConfig(check_every=10, switch_threshold=1.0))
    assert c.should_probe(10)
    assert not c.should_probe(5)
    assert c.observe(10, np.array([1.0, 0.5]), np.array([1.0, 0.4])) == "ok"
    assert c.state.mode == "lp"
    assert c.observe(20, np.array([1.0, 1.5]), np.array([1.0, 0.4])) \
        == "switched"
    assert c.state.mode == "serial"
    assert c.state.step_of_switch == 20


def test_convergence_factor_floor():
    assert convergence_factor(np.array([1e-32, 1e-33])) == 0.0
    assert convergence_factor(np.array([1.0, 0.25])) == pytest.approx(0.25)


def test_optimizer_adamw_descends_quadratic():
    cfg = OptimizerConfig(name="adamw", lr=0.1, warmup_steps=0,
                          total_steps=100, schedule="constant",
                          weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = optimizers.init_opt_state(cfg, params)
    for _ in range(100):
        grads = jax.tree.map(lambda w: 2 * w, params)
        params, state, _ = optimizers.apply_updates(cfg, params, grads,
                                                    state)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_int8_compression_error_feedback():
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (1000,)) * 0.01}
    err = compression.init_error_state(g)
    total_q = jnp.zeros_like(g["w"])
    total_g = jnp.zeros_like(g["w"])
    for i in range(20):
        gi = {"w": jax.random.normal(jax.random.fold_in(key, i),
                                     (1000,)) * 0.01}
        gq, err = compression.compress_tree(gi, err)
        total_q += gq["w"]
        total_g += gi["w"]
    # error feedback keeps the *accumulated* compressed signal unbiased
    rel = float(jnp.linalg.norm(total_q - total_g)
                / jnp.linalg.norm(total_g))
    assert rel < 0.05


def test_trainer_lp_and_serial_equivalent_when_converged():
    """fwd_iters large enough for exactness -> LP step == serial step."""
    rcfg = tiny_rcfg(lp=True)
    rcfg = dataclasses.replace(
        rcfg, mgrit=dataclasses.replace(rcfg.mgrit, fwd_iters=4,
                                        bwd_iters=4))
    t_lp = Trainer(rcfg, seed=0)
    r_lp = t_lp.train(5, log_every=0, probe=False)
    t_s = Trainer(dataclasses.replace(
        rcfg, mgrit=dataclasses.replace(rcfg.mgrit, enabled=False)), seed=0)
    r_s = t_s.train(5, log_every=0, probe=False)
    np.testing.assert_allclose(r_lp.losses, r_s.losses, rtol=2e-2, atol=2e-2)
