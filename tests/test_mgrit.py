"""Core MGRIT solver tests: exactness, convergence, adjoint gradients."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mgrit

jax.config.update("jax_enable_x64", False)


def toy_step(slot, z, h):
    """Nonlinear toy Phi: z + h*gate*tanh(z @ W + b)."""
    f = jnp.tanh(z @ slot["params"]["w"] + slot["params"]["b"])
    return z + jnp.asarray(h, z.dtype) * slot["gate"].astype(z.dtype) * f


def make_toy(key, N=16, B=4, D=8, h=0.25):
    kw, kb, kz = jax.random.split(key, 3)
    stacked = {
        "params": {
            "w": jax.random.normal(kw, (N, D, D)) * 0.3,
            "b": jax.random.normal(kb, (N, D)) * 0.1,
        },
        "gate": jnp.ones((N,)),
    }
    z0 = jax.random.normal(kz, (B, D))
    return stacked, z0, h


@pytest.mark.parametrize("cf,levels", [(2, 2), (4, 2), (2, 3)])
def test_mgrit_exactness_after_J_iterations(cf, levels):
    """MGRIT reproduces the serial solve after J = N/cf V-cycles."""
    stacked, z0, h = make_toy(jax.random.PRNGKey(0), N=16)
    _, zT_serial = mgrit.serial_solve(toy_step, stacked, z0, h)
    spec = mgrit.MGRITSpec(cf=cf, levels=levels, iters=16 // cf, h=h,
                           shard=False, znames=(None, None))
    states, zT, norms = mgrit.mgrit_solve(toy_step, stacked, z0, spec)
    np.testing.assert_allclose(np.asarray(zT), np.asarray(zT_serial),
                               rtol=1e-5, atol=1e-5)
    # all fine states must match the serial trajectory too
    serial_states, _ = mgrit.serial_solve(toy_step, stacked, z0, h)
    np.testing.assert_allclose(np.asarray(states), np.asarray(serial_states),
                               rtol=1e-5, atol=1e-5)


def test_mgrit_residual_contracts():
    """Residual norms decrease monotonically on a dissipative problem."""
    stacked, z0, h = make_toy(jax.random.PRNGKey(1), N=32, h=0.2)
    spec = mgrit.MGRITSpec(cf=4, levels=2, iters=6, h=h, shard=False,
                           znames=(None, None))
    _, _, norms = mgrit.mgrit_solve(toy_step, stacked, z0, spec)
    norms = np.asarray(norms)
    assert norms[-1] < norms[0]
    # strong overall contraction for this mild problem (later iterates can
    # sit at the fp32 floor, so compare against the first residual)
    assert norms[-1] < 1e-3 * norms[0]


def test_mgrit_more_iters_reduce_error():
    stacked, z0, h = make_toy(jax.random.PRNGKey(2), N=32, h=0.25)
    _, zT_serial = mgrit.serial_solve(toy_step, stacked, z0, h)
    errs = []
    for iters in (1, 2, 4):
        spec = mgrit.MGRITSpec(cf=4, levels=2, iters=iters, h=h, shard=False,
                               znames=(None, None))
        _, zT, _ = mgrit.mgrit_solve(toy_step, stacked, z0, spec)
        errs.append(float(jnp.linalg.norm(zT - zT_serial)))
    assert errs[2] < errs[1] < errs[0] or errs[2] < 1e-6


def test_serial_solve_matches_manual_loop():
    stacked, z0, h = make_toy(jax.random.PRNGKey(3), N=8, B=2, D=4)
    states, zT = mgrit.serial_solve(toy_step, stacked, z0, h)
    z = z0
    for n in range(8):
        assert np.allclose(states[n], z, atol=1e-6)
        z = toy_step({"params": jax.tree.map(lambda a, n=n: a[n], stacked["params"]),
                      "gate": stacked["gate"][n]}, z, h)
    np.testing.assert_allclose(np.asarray(zT), np.asarray(z), rtol=1e-6)


def test_gates_make_identity_layers():
    stacked, z0, h = make_toy(jax.random.PRNGKey(4), N=8, B=2, D=4)
    stacked["gate"] = stacked["gate"].at[4:].set(0.0)
    _, zT = mgrit.serial_solve(toy_step, stacked, z0, h)
    short = {"params": jax.tree.map(lambda a: a[:4], stacked["params"]),
             "gate": jnp.ones((4,))}
    _, zT4 = mgrit.serial_solve(toy_step, short, z0, h)
    np.testing.assert_allclose(np.asarray(zT), np.asarray(zT4), rtol=1e-6)
