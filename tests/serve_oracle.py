"""Shared conformance + differential oracles for the serve test suites.

One implementation of "what the paged engine must reproduce": serial
dense-cache decode (token by token, the seed design) combined with the
same vectorized sampler the jitted paged step uses, run on the host with
the request's own (seed, tokens_emitted) counter keying. Used by
tests/test_serve_backends.py, tests/test_serve_fuzz.py (seeded tier-1
twin), and tests/test_properties.py (hypothesis suite) so the three
suites cannot silently drift apart.

:func:`serve_equivalence` is the **differential serve-equivalence
harness** (ISSUE 10): any workload runs twice through fresh engines —
the control arm with chunked-prefill interleaving and token-granular
partial sharing OFF (serial whole-prompt admission, whole-page trie
matching: the pre-PR-10 engine) and the treatment arm with both ON —
and every request's token stream must match **bitwise**, at temperature
0 and under seeded sampling alike. :func:`chunk_wave_invariant` checks
the wave-level latency contract on the treatment trace: at most one
prefill-chunk ingest call per scheduler wave, never exceeding the chunk
budget — i.e. no decode wave is delayed by more than one budget's worth
of prefill.
"""
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import sample_tokens
from repro.models import transformer
from repro.obs.trace import SPAN, lifecycle_violations
from repro.serve.engine import Request, ServeEngine


def engine_outputs(rcfg, params, reqs, **engine_kw):
    """Run a list of (prompt, max_new_tokens[, kwargs]) specs through a
    fresh ServeEngine and return the output arrays. One harness for the
    plain-vs-spec conformance suites: ``engine_kw`` selects the engine
    under test (e.g. ``spec=SpecConfig(cf, k)``), the request list stays
    byte-identical across engines."""
    engine = ServeEngine(rcfg, params, **engine_kw)
    out = engine.generate(
        [Request(prompt=np.asarray(p, np.int32), max_new_tokens=n,
                 **(kw[0] if kw else {}))
         for p, n, *kw in reqs])
    return engine, [r.output for r in out]


def dense_decode_oracle(rcfg, params, step, req, max_len: int) -> np.ndarray:
    """Greedy-or-sampled reference stream for one request.

    ``step`` is a jitted ``transformer.decode_step`` closure (pass the
    same one across calls to reuse its compile cache); ``req`` is any
    object with prompt / max_new_tokens / temperature / top_k / top_p /
    seed / eos_id attributes (serve.engine.Request or
    serve.scheduler.ScheduledRequest).
    """
    cache = transformer.init_cache(rcfg, 1, max_len)
    toks = jnp.asarray(np.asarray(req.prompt, np.int32))[None]
    lg = None
    for t in range(toks.shape[1]):
        lg, cache = step(params, cache, toks[:, t:t + 1])
    out = []
    for n in range(req.max_new_tokens):
        nxt = sample_tokens(np.asarray(lg[:, -1], np.float32),
                            np.array([req.temperature], np.float32),
                            np.array([req.top_k], np.int32),
                            np.array([req.top_p], np.float32),
                            np.array([req.seed], np.int32),
                            np.array([n], np.int32))
        tok = int(np.asarray(nxt)[0])
        out.append(tok)
        if req.eos_id is not None and tok == req.eos_id:
            break
        if n < req.max_new_tokens - 1:
            lg, cache = step(params, cache, jnp.asarray([[tok]], jnp.int32))
    return np.asarray(out, np.int32)


def chunk_wave_invariant(events, budget: int):
    """Wave-level latency contract for chunked-prefill interleaving:
    fold a trace's scheduler-track ``prefill_chunk`` spans by wave and
    return violation messages (empty list = contract holds):

    - at most ONE ingest call per scheduler wave (decode runs in the
      same wave, so one call bounds how long decode waits), and
    - no call ingests more than ``budget`` tokens.

    Together these say: between any two consecutive decode waves the
    engine spends at most one chunk budget on prefill.
    """
    msgs = []
    per_wave = {}
    for ph, _ts, _dur, kind, rid, _slot, wave, args in events:
        if ph == SPAN and kind == "prefill_chunk" and rid < 0:
            per_wave.setdefault(wave, []).append(
                int((args or {}).get("tokens", 0)))
    for wave, calls in sorted(per_wave.items()):
        if len(calls) > 1:
            msgs.append(f"wave {wave}: {len(calls)} prefill_chunk calls "
                        f"(want at most 1)")
        for tokens in calls:
            if tokens > budget:
                msgs.append(f"wave {wave}: prefill_chunk ingested "
                            f"{tokens} tokens > budget {budget}")
    return msgs


def serve_equivalence(rcfg, params, reqs, *, chunk_tokens: int,
                      check_sharing: bool = False, **engine_kw):
    """Differential serve-equivalence harness (see module docstring).

    Runs ``reqs`` (``engine_outputs``-style specs) twice: the control
    arm serial + whole-page (``prefill_chunk_tokens=0,
    partial_prefix=False``), the treatment arm interleaved + token-
    granular (``prefill_chunk_tokens=chunk_tokens, partial_prefix=True``
    — on snapshot backends the scheduler itself falls back to whole-page
    matching). Asserts per-request **bitwise** token-stream equality,
    a clean request lifecycle on the treatment trace, at least one
    budget-bounded ingest wave, and the :func:`chunk_wave_invariant`.
    ``check_sharing=True`` additionally requires the treatment arm to
    have reused tokens via ``fork_partial``. Returns
    (engine_off, engine_on, outputs) for further stats assertions."""
    e_off, out_off = engine_outputs(
        rcfg, params, reqs, prefill_chunk_tokens=0, partial_prefix=False,
        **engine_kw)
    e_on, out_on = engine_outputs(
        rcfg, params, reqs, prefill_chunk_tokens=chunk_tokens,
        partial_prefix=True, **engine_kw)
    for i, (a, b) in enumerate(zip(out_off, out_on, strict=True)):
        np.testing.assert_array_equal(
            a, b, err_msg=f"request {i}: interleaved+partial-sharing "
            f"stream diverged from the serial/whole-page engine")
    events = e_on.obs.trace.events()
    assert lifecycle_violations(events) == []
    assert e_on.stats["prefill_chunks"] > 0, \
        "treatment arm never took the chunked-ingest path"
    assert chunk_wave_invariant(events, chunk_tokens) == []
    if check_sharing:
        assert e_on.stats["prefix_partial_tokens_shared"] > 0, \
            "workload was built to partial-hit but fork_partial never ran"
    return e_off, e_on, out_on
