"""Shared conformance oracle for the serve-backend test suites.

One implementation of "what the paged engine must reproduce": serial
dense-cache decode (token by token, the seed design) combined with the
same vectorized sampler the jitted paged step uses, run on the host with
the request's own (seed, tokens_emitted) counter keying. Used by
tests/test_serve_backends.py, tests/test_serve_fuzz.py (seeded tier-1
twin), and tests/test_properties.py (hypothesis suite) so the three
suites cannot silently drift apart.
"""
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import sample_tokens
from repro.models import transformer
from repro.serve.engine import Request, ServeEngine


def engine_outputs(rcfg, params, reqs, **engine_kw):
    """Run a list of (prompt, max_new_tokens[, kwargs]) specs through a
    fresh ServeEngine and return the output arrays. One harness for the
    plain-vs-spec conformance suites: ``engine_kw`` selects the engine
    under test (e.g. ``spec=SpecConfig(cf, k)``), the request list stays
    byte-identical across engines."""
    engine = ServeEngine(rcfg, params, **engine_kw)
    out = engine.generate(
        [Request(prompt=np.asarray(p, np.int32), max_new_tokens=n,
                 **(kw[0] if kw else {}))
         for p, n, *kw in reqs])
    return engine, [r.output for r in out]


def dense_decode_oracle(rcfg, params, step, req, max_len: int) -> np.ndarray:
    """Greedy-or-sampled reference stream for one request.

    ``step`` is a jitted ``transformer.decode_step`` closure (pass the
    same one across calls to reuse its compile cache); ``req`` is any
    object with prompt / max_new_tokens / temperature / top_k / top_p /
    seed / eos_id attributes (serve.engine.Request or
    serve.scheduler.ScheduledRequest).
    """
    cache = transformer.init_cache(rcfg, 1, max_len)
    toks = jnp.asarray(np.asarray(req.prompt, np.int32))[None]
    lg = None
    for t in range(toks.shape[1]):
        lg, cache = step(params, cache, toks[:, t:t + 1])
    out = []
    for n in range(req.max_new_tokens):
        nxt = sample_tokens(np.asarray(lg[:, -1], np.float32),
                            np.array([req.temperature], np.float32),
                            np.array([req.top_k], np.int32),
                            np.array([req.top_p], np.float32),
                            np.array([req.seed], np.int32),
                            np.array([n], np.int32))
        tok = int(np.asarray(nxt)[0])
        out.append(tok)
        if req.eos_id is not None and tok == req.eos_id:
            break
        if n < req.max_new_tokens - 1:
            lg, cache = step(params, cache, jnp.asarray([[tok]], jnp.int32))
    return np.asarray(out, np.int32)
