"""Hypothesis property-based tests on the system's invariants."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency; installed in CI via "
                         "requirements-dev.txt")
import hypothesis.strategies as st  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.analysis.hlo import collective_bytes
from repro.core import mgrit
from repro.core.lp import make_gates, pad_depth
from repro.models.attention import chunked_attention, dot_attention
from repro.parallel import compression

SET = settings(max_examples=15, deadline=None,
               suppress_health_check=[hypothesis.HealthCheck.too_slow])


def toy_step(slot, z, h):
    f = jnp.tanh(z @ slot["params"]["w"] + slot["params"]["b"])
    return z + jnp.asarray(h, z.dtype) * slot["gate"].astype(z.dtype) * f


def make_toy(seed, N, D=4, B=2):
    k = jax.random.PRNGKey(seed)
    kw, kb, kz = jax.random.split(k, 3)
    stacked = {"params": {"w": jax.random.normal(kw, (N, D, D)) * 0.3,
                          "b": jax.random.normal(kb, (N, D)) * 0.1},
               "gate": jnp.ones((N,))}
    return stacked, jax.random.normal(kz, (B, D))


@SET
@given(seed=st.integers(0, 50), cf=st.sampled_from([2, 4]),
       j=st.integers(2, 4))
def test_mgrit_exactness_property(seed, cf, j):
    """MGRIT is exact after J = N/cf V-cycles for ANY toy problem."""
    N = cf * j
    stacked, z0 = make_toy(seed, N)
    _, zT = mgrit.serial_solve(toy_step, stacked, z0, 0.3)
    spec = mgrit.MGRITSpec(cf=cf, levels=2, iters=j, h=0.3, shard=False,
                           znames=(None, None))
    _, zT_mg, _ = mgrit.mgrit_solve(toy_step, stacked, z0, spec)
    np.testing.assert_allclose(np.asarray(zT_mg), np.asarray(zT),
                               rtol=1e-4, atol=1e-4)


@SET
@given(seed=st.integers(0, 50), n_pad=st.integers(0, 3))
def test_gate_padding_is_identity(seed, n_pad):
    """Padded (gate=0) trailing layers never change the solution."""
    stacked, z0 = make_toy(seed, 8 + n_pad)
    stacked["gate"] = stacked["gate"].at[8:].set(0.0)
    ref = {"params": jax.tree.map(lambda a: a[:8], stacked["params"]),
           "gate": jnp.ones((8,))}
    _, zT_pad = mgrit.serial_solve(toy_step, stacked, z0, 0.5)
    _, zT_ref = mgrit.serial_solve(toy_step, ref, z0, 0.5)
    np.testing.assert_allclose(np.asarray(zT_pad), np.asarray(zT_ref),
                               rtol=1e-6)


@SET
@given(n=st.integers(1, 100), p=st.sampled_from([4, 8, 16]))
def test_pad_depth_invariants(n, p):
    m = pad_depth(n, p)
    assert m >= n and m % p == 0 and m - n < p
    g = np.asarray(make_gates(n, m))
    assert g.sum() == n and (g[:n] == 1).all() and (g[n:] == 0).all()


@SET
@given(seed=st.integers(0, 30),
       sq=st.sampled_from([64, 128]),
       h=st.sampled_from([(2, 2), (4, 2), (4, 1)]),
       causal=st.booleans())
def test_chunked_attention_matches_dense(seed, sq, h, causal):
    H, Hkv = h
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (2, sq, H, 16)) * 0.5
    k = jax.random.normal(ks[1], (2, sq, Hkv, 16)) * 0.5
    v = jax.random.normal(ks[2], (2, sq, Hkv, 16)) * 0.5
    out = chunked_attention(q, k, v, causal=causal, q_block=32, k_block=32)
    ref = dot_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@SET
@given(seed=st.integers(0, 100), scale=st.floats(1e-4, 10.0))
def test_int8_quantization_bounded_error(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4096,)) * scale
    q, s = compression.quantize_int8(x)
    x2 = compression.dequantize_int8(q, s, x.shape)
    err = float(jnp.max(jnp.abs(x - x2)))
    assert err <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


@given(kind=st.sampled_from(["all-reduce", "all-gather", "reduce-scatter",
                             "collective-permute", "all-to-all"]),
       dims=st.lists(st.integers(1, 64), min_size=1, max_size=3),
       dtype=st.sampled_from([("f32", 4), ("bf16", 2), ("s8", 1)]))
@settings(max_examples=30, deadline=None)
def test_hlo_parser_counts_synthetic_collectives(kind, dims, dtype):
    dt, dbytes = dtype
    shape = ",".join(map(str, dims))
    n = 1
    for d in dims:
        n *= d
    text = (f"  %op0 = {dt}[{shape}]{{0}} parameter(0)\n"
            f"  %c1 = {dt}[{shape}]{{0}} {kind}(%op0), channel_id=1\n")
    out = collective_bytes(text)
    assert out.get(kind, 0) == n * dbytes


# -- CacheBackend conformance (serve path) ----------------------------------
#
# Every backend (attention KV pages, SSM state-snapshot pages, hybrid
# composition) must reproduce the dense serial-forward oracle
# token-for-token — greedy and seeded-sampled — through the full engine
# (batched prefill, prefix sharing/COW, continuous batching).

_CONF_VOCAB = 32
_CONF_MAX_LEN = 24
_CONF_FAMILIES = ("decoder", "ssm_mamba1", "ssm_mamba2", "hybrid")
_CONF_CACHE = {}


def _conf_setup(fam):
    if fam in _CONF_CACHE:
        return _CONF_CACHE[fam]
    from repro.configs.base import (MGRITConfig, ModelConfig,
                                    OptimizerConfig, RunConfig, SSMConfig,
                                    ShapeConfig)
    from repro.models import transformer
    from repro.serve.engine import ServeEngine
    kw = dict(name=fam, family="decoder", n_layers=4, d_model=16,
              n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=_CONF_VOCAB,
              act="gelu", norm="layernorm", dtype="float32")
    if fam == "ssm_mamba1":
        kw.update(family="ssm", ssm=SSMConfig(version=1, d_state=8,
                                              d_conv=3))
    elif fam == "ssm_mamba2":
        kw.update(family="ssm", ssm=SSMConfig(version=2, d_state=8,
                                              d_conv=3, headdim=16))
    elif fam == "hybrid":
        kw.update(family="hybrid", n_layers=5, hybrid_attn_every=2,
                  ssm=SSMConfig(version=2, d_state=8, d_conv=3,
                                headdim=16))
    rcfg = RunConfig(
        model=ModelConfig(**kw),
        mgrit=MGRITConfig(enabled=True, cf=2, levels=2, fwd_iters=1,
                          bwd_iters=1, n_open=1, n_close=1, pad_to=2),
        optimizer=OptimizerConfig(),
        shape=ShapeConfig(fam, "train", 16, 4))
    params = transformer.init_model(
        jax.random.PRNGKey(sum(map(ord, fam)) % 997), rcfg)
    # one long-lived engine per family: examples share jit caches AND
    # exercise the prefix trie / eviction paths across examples
    eng = ServeEngine(rcfg, params, max_len=_CONF_MAX_LEN, max_batch=2,
                      page_size=4)
    step = jax.jit(lambda p, c, t: transformer.decode_step(p, c, t, rcfg))
    _CONF_CACHE[fam] = (rcfg, params, eng, step)
    return _CONF_CACHE[fam]


def _conf_oracle(rcfg, params, step, req):
    from serve_oracle import dense_decode_oracle
    return dense_decode_oracle(rcfg, params, step, req, _CONF_MAX_LEN)


@settings(max_examples=8, deadline=None,
          suppress_health_check=[hypothesis.HealthCheck.too_slow,
                                 hypothesis.HealthCheck.data_too_large])
@given(fam=st.sampled_from(_CONF_FAMILIES), data=st.data())
def test_cache_backend_conformance_vs_dense_oracle(fam, data):
    """Continuous-batched paged decode == dense serial decode, token for
    token, for every CacheBackend, under greedy AND seeded sampling, for
    arbitrary prompt mixes (including shared prefixes page-aligned and
    not)."""
    from repro.serve.engine import Request
    rcfg, params, eng, step = _conf_setup(fam)
    common = np.arange(1, 1 + data.draw(
        st.integers(0, 8), label="common_len"), dtype=np.int32)
    reqs = []
    for i in range(data.draw(st.integers(1, 3), label="n_req")):
        tail_len = data.draw(st.integers(1, 6), label=f"tail{i}")
        tail = np.asarray(data.draw(st.lists(
            st.integers(0, _CONF_VOCAB - 1), min_size=tail_len,
            max_size=tail_len), label=f"toks{i}"), np.int32)
        temp = data.draw(st.sampled_from([0.0, 0.0, 0.9]),
                         label=f"temp{i}")
        reqs.append(Request(
            prompt=np.concatenate([common, tail]),
            max_new_tokens=data.draw(st.integers(1, 4), label=f"new{i}"),
            temperature=temp,
            top_k=data.draw(st.sampled_from([0, 8]), label=f"topk{i}"),
            top_p=data.draw(st.sampled_from([1.0, 0.9]),
                            label=f"topp{i}"),
            seed=data.draw(st.integers(0, 99), label=f"seed{i}")))
    out = eng.generate(reqs)
    for r in out:
        np.testing.assert_array_equal(
            r.output, _conf_oracle(rcfg, params, step, r))
    assert eng.scheduler.n_active == 0


# -- SSMStateBackend page-op model check ------------------------------------

_PAGEOP_SEQ = st.lists(
    st.tuples(st.integers(0, 3), st.integers(0, 10**6)),
    min_size=0, max_size=40)


@settings(max_examples=25, deadline=None)
@given(n_pages=st.integers(2, 10), ops=_PAGEOP_SEQ)
def test_ssm_backend_page_ops_model_checked(n_pages, ops):
    """Random alloc_view/share/fork/release traffic on an SSMStateBackend,
    checked against a pure-dict refcount model — and fork must deep-copy
    the device state snapshot (COW semantics on recurrent state)."""
    rcfg, params, _, _ = _conf_setup("ssm_mamba1")
    from repro.serve.cache import make_backend
    backend = make_backend(rcfg, params, page_size=4)
    state = backend.init(2, n_pages)
    live = {}                                     # page -> refcount model
    fill = {}                                     # page -> h fill value
    for op, arg in ops:
        if op == 0:                               # alloc_view
            n = arg % n_pages
            free_before = backend.alloc.n_free
            got = backend.alloc_view(n)
            assert (got is None) == (n > free_before)
            for p in got or []:
                live[p] = 1
                fill[p] = float(p + 100 * len(fill))
                state["h"] = state["h"].at[:, p].set(fill[p])
        elif op == 1 and live:                    # share
            p = sorted(live)[arg % len(live)]
            backend.share([p])
            live[p] += 1
        elif op == 2 and live:                    # fork (copy-on-write)
            p = sorted(live)[arg % len(live)]
            state, q = backend.fork(state, p)
            if live[p] == 1:
                assert q == p
            elif q is not None:
                assert q != p and q not in live
                live[p] -= 1
                live[q] = 1
                fill[q] = fill[p]
                np.testing.assert_array_equal(
                    np.asarray(state["h"][:, q]),
                    np.asarray(state["h"][:, p]))
                np.testing.assert_array_equal(
                    np.asarray(state["conv"][:, q]),
                    np.asarray(state["conv"][:, p]))
        elif op == 3 and live:                    # release one reference
            p = sorted(live)[arg % len(live)]
            backend.release([p])
            live[p] -= 1
            if live[p] == 0:
                del live[p]
                del fill[p]
        for p, r in live.items():
            assert backend.alloc.refcount(p) == r and r > 0
            np.testing.assert_array_equal(
                np.asarray(state["h"][:, p]),
                np.full_like(np.asarray(state["h"][:, p]), fill[p]))
        assert backend.alloc.n_free == n_pages - 1 - len(live)
    for p, r in list(live.items()):
        backend.release([p] * r)
    assert backend.alloc.n_free == n_pages - 1    # no leak


# -- refcounted page allocator (serve path) ---------------------------------

_ALLOC_OPS = st.lists(
    st.tuples(st.integers(0, 4), st.integers(0, 10**6)),
    min_size=0, max_size=120)


@settings(max_examples=60, deadline=None)
@given(n_pages=st.integers(2, 24), ops=_ALLOC_OPS)
def test_page_allocator_refcount_property(n_pages, ops):
    """Random alloc/share/fork/free traffic never double-frees, never
    leaks (after all frees n_free == pool size), and refcounts stay
    non-negative — checked against a pure-dict model allocator."""
    from repro.serve.kv_pages import PageAllocator

    a = PageAllocator(n_pages)
    live = {}                                     # page -> refcount model
    for op, arg in ops:
        if op == 0:                               # alloc
            n = arg % n_pages
            free_before = a.n_free
            got = a.alloc(n)
            assert (got is None) == (n > free_before)
            for p in got or []:
                assert p not in live and not a.is_free(p)
                live[p] = 1
        elif op == 1 and live:                    # share
            p = sorted(live)[arg % len(live)]
            a.share([p])
            live[p] += 1
        elif op == 2 and live:                    # fork (copy-on-write)
            p = sorted(live)[arg % len(live)]
            q = a.fork(p)
            if live[p] == 1:
                assert q == p
            elif q is not None:
                assert q != p and q not in live
                live[p] -= 1
                live[q] = 1
        elif op == 3 and live:                    # free one reference
            p = sorted(live)[arg % len(live)]
            a.free([p])
            live[p] -= 1
            if live[p] == 0:
                del live[p]
                assert a.is_free(p)
        elif op == 4 and live:                    # fork_partial (copy, not
            p = sorted(live)[arg % len(live)]     # detach)
            free_before = a.n_free
            q = a.fork_partial(p)
            assert (q is None) == (free_before == 0)
            if q is not None:
                # fresh private page; the SOURCE keeps every reference
                # (unlike fork, which detaches one)
                assert q != p and q not in live
                assert a.refcount(p) == live[p]
                live[q] = 1
        assert all(a.refcount(p) == r and r > 0 for p, r in live.items())
        assert a.n_free == n_pages - 1 - len(live)
    for p, r in list(live.items()):
        a.free([p] * r)
    assert a.n_free == n_pages - 1                # no leak
    if n_pages > 1:
        with pytest.raises(ValueError):
            a.free([1])                           # and no double free
        with pytest.raises(ValueError):
            a.fork_partial(1)                     # fork of a freed page


# -- partial-page COW (fork_partial) device model check ---------------------

_FORKP_OPS = st.lists(
    st.tuples(st.integers(0, 4), st.integers(0, 10**6)),
    min_size=1, max_size=30)


@settings(max_examples=25, deadline=None)
@given(n_pages=st.integers(2, 8), ops=_FORKP_OPS)
def test_kv_backend_fork_partial_model_checked(n_pages, ops):
    """Random alloc_view/share/fork_partial/release traffic on a
    PagedKVBackend, checked against a pure-dict refcount model — and
    fork_partial must deep-copy the device page while leaving the
    source's refcounts untouched (mirrors the PR-3 SSM page-op check
    for the detaching fork)."""
    rcfg, params, _, _ = _conf_setup("decoder")
    from repro.serve.cache import make_backend
    backend = make_backend(rcfg, params, page_size=4)
    state = backend.init(2, n_pages)
    live = {}                                     # page -> refcount model
    fill = {}                                     # page -> fill value
    leaves0 = jax.tree.leaves(state)

    def set_page(p, val):
        nonlocal state
        leaves, treedef = jax.tree.flatten(state)
        state = jax.tree.unflatten(
            treedef, [leaf.at[:, p].set(val) for leaf in leaves])

    for op, arg in ops:
        if op == 0:                               # alloc_view
            n = arg % n_pages
            free_before = backend.alloc.n_free
            got = backend.alloc_view(n)
            assert (got is None) == (n > free_before)
            for p in got or []:
                live[p] = 1
                fill[p] = float(p + 100 * len(fill))
                set_page(p, fill[p])
        elif op == 1 and live:                    # share
            p = sorted(live)[arg % len(live)]
            backend.share([p])
            live[p] += 1
        elif op == 2 and live:                    # fork_partial
            p = sorted(live)[arg % len(live)]
            n_valid = 1 + arg % (backend.page_size - 1)
            free_before = backend.alloc.n_free
            state, q = backend.fork_partial(state, p, n_valid)
            assert (q is None) == (free_before == 0)
            if q is not None:
                assert q != p and q not in live
                assert backend.alloc.refcount(p) == live[p]
                live[q] = 1
                fill[q] = fill[p]                 # whole page copied
                for leaf in jax.tree.leaves(state):
                    np.testing.assert_array_equal(
                        np.asarray(leaf[:, q]), np.asarray(leaf[:, p]))
        elif op == 3 and live:                    # release one reference
            p = sorted(live)[arg % len(live)]
            backend.release([p])
            live[p] -= 1
            if live[p] == 0:
                del live[p]
                del fill[p]
        elif op == 4:                             # n_valid bounds raise
            if live:
                p = sorted(live)[arg % len(live)]
                for bad in (0, backend.page_size):
                    with pytest.raises(ValueError):
                        backend.fork_partial(state, p, bad)
        for p, r in live.items():
            assert backend.alloc.refcount(p) == r and r > 0
            for leaf in jax.tree.leaves(state):
                np.testing.assert_array_equal(
                    np.asarray(leaf[:, p]),
                    np.full_like(np.asarray(leaf[:, p]), fill[p]))
        assert backend.alloc.n_free == n_pages - 1 - len(live)
    for p, r in list(live.items()):
        backend.release([p] * r)
    assert backend.alloc.n_free == n_pages - 1    # no leak
    assert len(leaves0) == len(jax.tree.leaves(state))


def test_fork_partial_rejected_on_snapshot_backends():
    """A state snapshot has no token-granular prefix: fork_partial on
    SSM/hybrid backends is a contract error, not a silent wrong answer
    (the scheduler's partial_prefix flag falls back to whole-page
    matching instead — docs/cache-backends.md)."""
    from repro.serve.cache import make_backend

    rcfg, params, _, _ = _conf_setup("ssm_mamba1")
    backend = make_backend(rcfg, params, page_size=4)
    state = backend.init(2, 4)
    (page,) = backend.alloc_view(1)
    with pytest.raises(ValueError, match="snapshot"):
        backend.fork_partial(state, page, 2)
    backend.release([page])
