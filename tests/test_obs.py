"""Serve-layer observability: metrics registry, lifecycle trace,
Perfetto export (PR 9).

Covers the three contracts the obs subsystem makes:

1. **Registry exactness** — histogram quantiles match numpy.percentile
   bitwise (linear interpolation on raw samples, not bucket midpoints),
   the snapshot exposes exactly the catalogued metrics, and the legacy
   ``engine.stats`` / ``scheduler.stats`` dicts stay backwards
   compatible (same keys, same reset idiom) with the registry as their
   single owner.
2. **Lifecycle invariant** — every submitted rid emits exactly one
   terminal event (finish/fail/cancel) across seeded fuzz traffic with
   a tight pool (rejections), deterministic preempt→resume, and a
   dropped stream (cancel).
3. **Perfetto schema** — the exported JSON is structurally valid
   trace-event format: named tracks, non-negative span durations,
   paired async begin/end per request.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import (MGRITConfig, ModelConfig, OptimizerConfig,
                                RunConfig, ShapeConfig)
from repro.models import transformer
from repro.obs import (METRIC_CATALOG, Histogram, MetricsRegistry,
                       TraceBuffer, lifecycle_violations, request_outcomes)
from repro.obs.trace import INSTANT
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import Scheduler

pytestmark = pytest.mark.serve

VOCAB = 32
MAX_LEN = 24


def make_setup(seed: int = 0):
    rcfg = RunConfig(
        model=ModelConfig(name="obs_decoder", family="decoder", n_layers=4,
                          d_model=16, n_heads=2, n_kv_heads=2, d_ff=32,
                          vocab_size=VOCAB, act="gelu", norm="layernorm",
                          dtype="float32"),
        mgrit=MGRITConfig(enabled=True, cf=2, levels=2, fwd_iters=1,
                          bwd_iters=1, n_open=1, n_close=1, pad_to=2),
        optimizer=OptimizerConfig(),
        shape=ShapeConfig("obs", "train", 16, 4))
    params = transformer.init_model(jax.random.PRNGKey(seed), rcfg)
    return rcfg, params


@pytest.fixture(scope="module")
def setup():
    return make_setup()


# -- metrics registry ---------------------------------------------------------

@pytest.mark.parametrize("n", [1, 2, 7, 100, 1000])
def test_histogram_quantiles_match_numpy(n):
    """quantile() is numpy.percentile's linear interpolation on the raw
    samples — exact, not a bucket approximation."""
    rng = np.random.default_rng(n)
    xs = rng.lognormal(mean=-3.0, sigma=2.0, size=n)
    h = Histogram("request.ttft_s", "test")
    for x in xs:
        h.observe(float(x))
    for q in (0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0):
        np.testing.assert_allclose(
            h.quantile(q), np.percentile(xs, 100 * q), rtol=0, atol=1e-12)
    p = h.percentiles()
    np.testing.assert_allclose(p["p50"], np.percentile(xs, 50), atol=1e-12)
    assert h.count == n
    np.testing.assert_allclose(h.sum, xs.sum(), rtol=1e-12)


def test_histogram_prometheus_buckets_cumulative():
    h = Histogram("request.ttft_s", "test")
    for x in (0.001, 0.01, 0.1, 1.0, 1e6):   # 1e6 overflows every bound
        h.observe(x)
    counts = h.bucket_counts
    assert sum(counts) == h.count == 5
    assert counts[-1] == 1                   # the +Inf overflow bucket
    # cumulative form never decreases and ends at count
    cum = np.cumsum(counts)
    assert list(cum) == sorted(cum) and cum[-1] == h.count


def test_registry_snapshot_is_exactly_the_catalog(setup):
    """A live engine's snapshot has one entry per catalogued metric —
    nothing uncatalogued leaks in, nothing catalogued goes dark."""
    rcfg, params = setup
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                      page_size=4)
    eng.generate([Request(prompt=np.arange(1, 6, dtype=np.int32),
                          max_new_tokens=4)])
    snap = eng.metrics_snapshot()
    assert set(snap) == set(METRIC_CATALOG)
    assert snap["scheduler.decode_tokens"] > 0
    assert snap["request.ttft_s"]["count"] == 1
    prom = eng.metrics_prometheus()
    assert "# TYPE repro_scheduler_decode_tokens counter" in prom
    assert "repro_scheduler_decode_tokens_total " in prom
    assert 'repro_request_ttft_s_bucket{le="+Inf"} 1' in prom
    assert "# TYPE repro_pool_free_pages gauge" in prom


def test_uncatalogued_metrics_are_rejected():
    m = MetricsRegistry()
    with pytest.raises(KeyError, match="not a catalogued counter"):
        m.stats_dict("scheduler", {"made_up_counter": 0})
    with pytest.raises(KeyError, match="not a catalogued gauge"):
        m.gauge("scheduler.decode_tokens", lambda: 0.0)  # it's a counter


def test_engine_stats_backwards_compatible(setup):
    """The registry owns scheduler.stats now, but every legacy key and
    the in-place reset idiom (`stats[k] = 0`) keep working — both arms
    of the observability flag."""
    rcfg, params = setup
    legacy = ("prefill_tokens", "decode_tokens", "decode_s", "shared_tokens",
              "pages_allocated", "preemptions", "requests_rejected")
    for obs_on in (True, False):
        eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                          page_size=4, observability=obs_on)
        eng.generate([Request(prompt=np.arange(1, 6, dtype=np.int32),
                              max_new_tokens=4)])
        s = eng.stats
        assert set(legacy) <= set(s)
        assert s["decode_tokens"] > 0
        assert "compiles_per_callable" in s
        sched = eng.scheduler
        for k in sched.stats:
            sched.stats[k] = type(sched.stats[k])(0)
        assert eng.stats["decode_tokens"] == 0
        if not obs_on:
            assert eng.metrics_snapshot() == {}
            assert eng.obs.trace is None
            with pytest.raises(ValueError, match="no trace buffer"):
                eng.save_trace("/dev/null")


# -- lifecycle invariant ------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fuzz_exactly_one_terminal_per_request(setup, seed):
    """Random traffic through a deliberately tight pool (admission
    stalls, rejections, mixed priorities): every submitted rid gets
    exactly one terminal event and the trace drops nothing."""
    rcfg, params = setup
    rng = np.random.default_rng(seed)
    sched = Scheduler(rcfg, params, max_batch=3, page_size=4,
                      max_len=MAX_LEN, n_pages=1 + 18,
                      share_prefix=bool(seed % 2 == 0))
    rids = []
    for _ in range(12):
        prompt = rng.integers(0, VOCAB, size=int(
            rng.integers(1, 14))).astype(np.int32)
        rids.append(sched.submit(
            prompt, int(rng.integers(1, 8)),
            priority=int(rng.integers(0, 3))))
    done = sched.run()
    assert set(done) >= set(rids)
    tr = sched.trace
    assert tr.dropped == 0
    assert lifecycle_violations(tr.events(), rids=set(rids)) == []
    outs = request_outcomes(tr.events())
    for rid in rids:
        assert outs[rid].terminal == ("fail" if done[rid].failed
                                      else "finish")
        assert outs[rid].n_out == len(done[rid].out)


def test_preempt_resume_lifecycle_events(setup):
    """A preempted-then-resumed request shows preempt + resume events
    and still exactly one terminal; outcomes count the preemption."""
    rcfg, params = setup
    sched = Scheduler(rcfg, params, max_batch=1, page_size=4,
                      max_len=MAX_LEN, share_prefix=False)
    a = sched.submit_request(np.arange(2, 9, dtype=np.int32), 8, priority=5)
    for _ in range(3):
        sched.step()
    b = sched.submit_request(np.array([5, 4, 3, 2, 1], np.int32), 4,
                             priority=0)
    sched.step()                      # urgent b preempts a
    assert a.preemptions == 1
    sched.run()
    evs = sched.trace.events()
    kinds_a = [e[3] for e in evs if e[0] == INSTANT and e[4] == a.rid]
    assert "preempt" in kinds_a and "resume" in kinds_a
    assert kinds_a.count("finish") == 1
    assert lifecycle_violations(evs) == []
    outs = request_outcomes(evs)
    assert outs[a.rid].preemptions == 1
    assert outs[b.rid].preemptions == 0


def test_dropped_stream_emits_one_cancel(setup):
    """Closing a streaming iterator mid-generation cancels the request:
    exactly one 'cancel' terminal, pages back in the pool."""
    rcfg, params = setup
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                      page_size=4)
    it = eng.submit(Request(prompt=np.arange(1, 7, dtype=np.int32),
                            max_new_tokens=12), stream=True)
    next(it)
    it.close()
    evs = eng.obs.trace.events()
    assert lifecycle_violations(evs) == []
    (outcome,) = request_outcomes(evs).values()
    assert outcome.terminal == "cancel" and outcome.n_out >= 1
    eng.scheduler.drop_prefix_cache()    # trie legitimately caches pages
    assert eng.scheduler.alloc.n_free == eng.scheduler.alloc.n_pages - 1


def test_rejected_request_is_a_fail_terminal(setup):
    """An unservable request fails at submit: one 'fail' terminal with
    rejected=True in the fold."""
    rcfg, params = setup
    sched = Scheduler(rcfg, params, max_batch=2, page_size=4,
                      max_len=MAX_LEN, n_pages=1 + 2)
    req = sched.submit_request(np.arange(1, 12, dtype=np.int32), 12)
    assert req.failed
    outs = request_outcomes(sched.trace.events())
    assert outs[req.rid].terminal == "fail" and outs[req.rid].rejected


# -- trace buffer + Perfetto export -------------------------------------------

def test_ring_buffer_bounded():
    tr = TraceBuffer(capacity=8)
    for i in range(20):
        tr.instant("submit", rid=i)
    assert len(tr) == 8 and tr.dropped == 12
    # survivors are the newest 8
    assert [e[4] for e in tr.events()] == list(range(12, 20))


def test_perfetto_export_schema(setup, tmp_path):
    """Structural validation of the Chrome trace-event JSON: every
    event carries ph/pid/ts, spans have dur >= 0, async b/e pair up
    per rid, and scheduler/allocator/slot tracks are named."""
    rcfg, params = setup
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                      page_size=4)
    eng.generate([Request(prompt=np.arange(1, 6 + i, dtype=np.int32),
                          max_new_tokens=4) for i in range(3)])
    path = tmp_path / "trace.json"
    n = eng.save_trace(str(path))
    import json
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    assert len(evs) == n > 0
    track_names = {e["args"]["name"] for e in evs if e["ph"] == "M"
                   and e["name"] == "thread_name"}
    assert {"scheduler", "slot 0", "slot 1"} <= track_names
    begins, ends = set(), set()
    for e in evs:
        assert e["ph"] in ("M", "i", "X", "C", "b", "e")
        if e["ph"] == "M":
            continue
        assert e["pid"] == 1 and "ts" in e
        if e["ph"] == "X":
            assert e["dur"] >= 0
        elif e["ph"] == "i":
            assert e["s"] == "t"
        elif e["ph"] == "b":
            begins.add(e["id"])
        elif e["ph"] == "e":
            ends.add(e["id"])
    assert begins == ends and len(begins) == 3   # one async span per rid
    span_kinds = {e["name"] for e in evs if e["ph"] == "X"}
    assert {"prefill", "decode", "admit_wave"} <= span_kinds


def test_trace_accounting_matches_scheduler_counters(setup):
    """The bench_traffic cross-check in miniature: goodput, preemption
    and rejection counts recomputed from the trace equal the
    scheduler's own counters."""
    rcfg, params = setup
    sched = Scheduler(rcfg, params, max_batch=2, page_size=4,
                      max_len=MAX_LEN, n_pages=1 + 6)
    rng = np.random.default_rng(7)
    rids = [sched.submit(rng.integers(0, VOCAB, size=int(
                rng.integers(2, 10))).astype(np.int32),
            int(rng.integers(1, 6)), priority=int(rng.integers(0, 2)))
            for _ in range(8)]
    done = sched.run()
    outs = request_outcomes(sched.trace.events())
    assert sum(o.preemptions for o in outs.values()) \
        == sched.stats["preemptions"]
    assert sum(o.rejected for o in outs.values()) \
        == sched.stats["requests_rejected"]
    assert sum(o.terminal == "finish" for o in outs.values()) \
        == sum(not done[r].failed for r in rids)


# -- compile-event counters ---------------------------------------------------

def test_compile_counts_stable_across_repeat_traffic(setup):
    """compiles_per_callable counts XLA traces; repeating identical
    traffic must not grow it (the RC001 no-recompile contract as a
    production metric)."""
    rcfg, params = setup
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                      page_size=4)
    reqs = [Request(prompt=np.arange(1, 6, dtype=np.int32),
                    max_new_tokens=4) for _ in range(2)]
    eng.generate([Request(**{**r.__dict__}) for r in reqs])
    counts_after_first = dict(eng.backend.compile_counts)
    assert counts_after_first["PagedKVBackend.serve_step"] >= 1
    eng.generate([Request(**{**r.__dict__}) for r in reqs])
    assert dict(eng.backend.compile_counts) == counts_after_first
    assert eng.stats["compiles_per_callable"] > 0
    assert eng.metrics_snapshot()["engine.compiles_per_callable"] > 0
