"""Fixture suite for repro.analysis.staticcheck.

Every rule must (a) fire on its known-bad snippet, (b) stay silent on
the known-good twin, and (c) stay silent on a real clean excerpt of
the tree (serve/cache.py — the file whose conventions the rules were
tuned against).  Also covers the jit-region resolver, the baseline
round-trip, and the CLI exit codes the CI lint step relies on.

Stdlib-only on purpose (no jax import): this is the same constraint
the CI lint job runs under.
"""
import pathlib
import sys
import textwrap

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

from repro.analysis.staticcheck import (RULES, Project,   # noqa: E402
                                        run_rules)
from repro.analysis.staticcheck.cli import main as cli_main  # noqa: E402


def _scan(tmp_path, name, source, known_axes=None, select=None):
    path = tmp_path / name
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(source))
    project = Project([str(path)], known_axes=known_axes)
    return run_rules(project, select={select} if select else None)


def _rules_of(findings):
    return {f.rule for f in findings}


# -- registry ----------------------------------------------------------------

def test_registry_has_at_least_six_rules():
    assert len(RULES) >= 6
    for rid, rule in RULES.items():
        assert rid == rule.rule_id and rule.summary


# -- RC001: recompile hazards ------------------------------------------------

BAD_RC001_BRANCH = """
    import jax
    import jax.numpy as jnp

    @jax.jit
    def step(x):
        if jnp.any(x > 0):
            return x + 1
        return x - 1
"""

GOOD_RC001_BRANCH = """
    import jax
    import jax.numpy as jnp

    @jax.jit
    def step(x):
        return jnp.where(jnp.any(x > 0), x + 1, x - 1)
"""

BAD_RC001_CONTAINER = """
    import jax
    import jax.numpy as jnp

    @jax.jit
    def step(x, y):
        return jnp.asarray([x, y])
"""

BAD_RC001_STATIC = """
    import functools
    import jax

    @functools.partial(jax.jit, static_argnames=("dims",))
    def reduce(x, dims):
        return x.sum(dims)

    def caller(x):
        return reduce(x, dims=[0, 1])
"""

GOOD_RC001_STATIC = """
    import functools
    import jax

    @functools.partial(jax.jit, static_argnames=("dims",))
    def reduce(x, dims):
        return x.sum(dims)

    def caller(x):
        return reduce(x, dims=(0, 1))
"""


def test_rc001_catches_tracer_branch(tmp_path):
    findings = _scan(tmp_path, "mod.py", BAD_RC001_BRANCH, select="RC001")
    assert _rules_of(findings) == {"RC001"}


def test_rc001_silent_on_lax_select(tmp_path):
    assert _scan(tmp_path, "mod.py", GOOD_RC001_BRANCH,
                 select="RC001") == []


def test_rc001_catches_container_asarray(tmp_path):
    findings = _scan(tmp_path, "mod.py", BAD_RC001_CONTAINER,
                     select="RC001")
    assert _rules_of(findings) == {"RC001"}


def test_rc001_catches_unhashable_static_arg(tmp_path):
    findings = _scan(tmp_path, "mod.py", BAD_RC001_STATIC, select="RC001")
    assert _rules_of(findings) == {"RC001"}
    assert _scan(tmp_path, "good.py", GOOD_RC001_STATIC,
                 select="RC001") == []


def test_rc001_ignores_host_side_branching(tmp_path):
    host = """
        import jax.numpy as jnp

        def host_loop(x):
            if jnp.any(x > 0):          # not a jit region: fine
                return 1
            return 0
    """
    assert _scan(tmp_path, "mod.py", host, select="RC001") == []


# -- RC002: host sync --------------------------------------------------------

BAD_RC002 = """
    import jax
    import jax.numpy as jnp
    import numpy as np

    @jax.jit
    def step(x):
        total = float(jnp.sum(x))
        host = np.asarray(x)
        return total, host, x.max().item()
"""

GOOD_RC002 = """
    import jax
    import jax.numpy as jnp
    import numpy as np

    @jax.jit
    def step(x):
        return jnp.sum(x)

    def host_caller(x):
        val = float(np.asarray(step(x)))   # host side: fine
        return val
"""


def test_rc002_catches_host_sync(tmp_path):
    findings = _scan(tmp_path, "mod.py", BAD_RC002, select="RC002")
    assert _rules_of(findings) == {"RC002"}
    assert len(findings) >= 3        # float(), np.asarray, .item()


def test_rc002_silent_on_host_side_pulls(tmp_path):
    assert _scan(tmp_path, "mod.py", GOOD_RC002, select="RC002") == []


# -- DN001: donation-after-use -----------------------------------------------

BAD_DN001 = """
    import jax

    class Backend:
        def setup(self, fn):
            self._step_fn = jax.jit(fn, donate_argnums=(1,))

        def apply(self, params, state):
            nxt = self._step_fn(params, state)
            return state["cache"], nxt      # read after donation
"""

GOOD_DN001 = """
    import jax

    class Backend:
        def setup(self, fn):
            self._step_fn = jax.jit(fn, donate_argnums=(1,))

        def apply(self, params, state):
            nxt, state = self._step_fn(params, state)   # rebind idiom
            return state["cache"], nxt
"""

BAD_DN001_PALLAS = """
    import jax.experimental.pallas as pl

    def run(kernel, spec, x):
        out = pl.pallas_call(kernel, out_shape=spec,
                             input_output_aliases={0: 0})(x)
        return x + out                      # x's buffer was aliased away
"""


def test_dn001_catches_read_after_donation(tmp_path):
    findings = _scan(tmp_path, "mod.py", BAD_DN001, select="DN001")
    assert _rules_of(findings) == {"DN001"}


def test_dn001_allows_rebind_in_same_statement(tmp_path):
    assert _scan(tmp_path, "mod.py", GOOD_DN001, select="DN001") == []


def test_dn001_catches_pallas_aliased_operand(tmp_path):
    findings = _scan(tmp_path, "mod.py", BAD_DN001_PALLAS, select="DN001")
    assert _rules_of(findings) == {"DN001"}


# -- PG001: allocator lifecycle ----------------------------------------------

BAD_PG001 = """
    class Scheduler:
        def admit(self, n):
            pages = self.backend.alloc_view(n)
            if pages is None:
                return None                 # alloc failed: fine
            if self.occupied():
                return None                 # LEAK: pages never released
            return pages
"""

GOOD_PG001 = """
    class Scheduler:
        def admit(self, n):
            pages = self.backend.alloc_view(n)
            if pages is None:
                return None
            if self.occupied():
                self.backend.release(pages)
                return None
            return pages
"""


def test_pg001_catches_leaked_pages(tmp_path):
    findings = _scan(tmp_path, "scheduler.py", BAD_PG001, select="PG001")
    assert _rules_of(findings) == {"PG001"}


def test_pg001_silent_when_released_or_returned(tmp_path):
    assert _scan(tmp_path, "scheduler.py", GOOD_PG001,
                 select="PG001") == []


BAD_PG001_FORK_PARTIAL = """
    class Scheduler:
        def admit_partial(self, src, n_tok):
            self.state, dst = self.backend.fork_partial(self.state, src, n_tok)
            if dst is None:
                return None                 # fork failed: fine
            if self.occupied():
                return None                 # LEAK: dst never released
            return dst
"""

GOOD_PG001_FORK_PARTIAL = """
    class Scheduler:
        def admit_partial(self, shared, src, n_tok):
            self.state, dst = self.backend.fork_partial(self.state, src, n_tok)
            if dst is None:
                return None
            if self.occupied():
                self.backend.release([dst])
                return None
            shared.append(dst)              # handoff: caller's list owns it
            return shared
"""


def test_pg001_fork_partial_tuple_binding_catches_leak(tmp_path):
    findings = _scan(tmp_path, "scheduler.py", BAD_PG001_FORK_PARTIAL,
                     select="PG001")
    assert _rules_of(findings) == {"PG001"}
    assert any("`dst`" in f.message for f in findings)


def test_pg001_fork_partial_silent_on_release_or_handoff(tmp_path):
    assert _scan(tmp_path, "scheduler.py", GOOD_PG001_FORK_PARTIAL,
                 select="PG001") == []


def test_pg001_scope_is_scheduler_and_engine_only(tmp_path):
    # same leak in an out-of-scope file: the allocator's own internals
    # (kv_pages.py) and tests juggle refcounts legitimately
    assert _scan(tmp_path, "kv_pages.py", BAD_PG001, select="PG001") == []


# -- PL001: Pallas index-map purity ------------------------------------------

BAD_PL001_CLOSURE = """
    import jax.experimental.pallas as pl

    def build(table):
        return pl.BlockSpec((1, 128), lambda i, j: (table[i], 0))
"""

BAD_PL001_JNP = """
    import jax.numpy as jnp
    import jax.experimental.pallas as pl

    def build():
        return pl.BlockSpec((1, 128), lambda i, j: (jnp.mod(i, 4), 0))
"""

GOOD_PL001 = """
    import jax.experimental.pallas as pl

    def build(n_heads, n_kv_heads):
        g = n_heads // n_kv_heads        # captured static scalar: fine
        prefetch = pl.BlockSpec((1, 128),
                                lambda b, p, pt: (pt[b, p], 0))
        gqa = pl.BlockSpec((1, 128), lambda b, h: (b, h // g))
        return prefetch, gqa
"""


def test_pl001_catches_closure_subscript(tmp_path):
    findings = _scan(tmp_path, "mod.py", BAD_PL001_CLOSURE,
                     select="PL001")
    assert _rules_of(findings) == {"PL001"}


def test_pl001_catches_materialized_op(tmp_path):
    findings = _scan(tmp_path, "mod.py", BAD_PL001_JNP, select="PL001")
    assert _rules_of(findings) == {"PL001"}


def test_pl001_allows_prefetch_refs_and_static_scalars(tmp_path):
    assert _scan(tmp_path, "mod.py", GOOD_PL001, select="PL001") == []


# -- SH001: sharding-axis drift ----------------------------------------------

AXES = {"batch", "heads", "mlp", "kv_seq", "pages", "seq"}

BAD_SH001 = """
    from repro.parallel.sharding import logical_constraint

    def forward(x):
        return logical_constraint(x, ("batch", "sqe", None))
"""

GOOD_SH001 = """
    from repro.parallel.sharding import logical_constraint

    def forward(x, pre):
        x = logical_constraint(x, ("batch", "seq", None))
        return logical_constraint(x, pre + ("pages", None, "mlp"))
"""


def test_sh001_catches_axis_typo(tmp_path):
    findings = _scan(tmp_path, "mod.py", BAD_SH001, known_axes=AXES,
                     select="SH001")
    assert _rules_of(findings) == {"SH001"}
    assert "sqe" in findings[0].message


def test_sh001_silent_on_known_axes_and_concat(tmp_path):
    assert _scan(tmp_path, "mod.py", GOOD_SH001, known_axes=AXES,
                 select="SH001") == []


def test_sh001_vocabulary_extracted_from_real_tree():
    project = Project([str(REPO / "src" / "repro")])
    from repro.analysis.staticcheck.rules_sharding import _known_axes
    known = _known_axes(project)
    assert known is not None
    # ShardingConfig fields + resolve_axis aliases
    for ax in ("batch", "heads", "kv_seq", "pages", "kv_heads", "seq"):
        assert ax in known, ax


# -- AS001: bare serve-layer asserts -----------------------------------------

BAD_AS001 = """
    def fill(self, slot):
        assert slot >= 0
        return slot
"""


def test_as001_catches_serve_assert(tmp_path):
    findings = _scan(tmp_path, "serve/scheduler.py", BAD_AS001,
                     select="AS001")
    assert _rules_of(findings) == {"AS001"}


def test_as001_ignores_kernel_asserts(tmp_path):
    assert _scan(tmp_path, "kernels/kern.py", BAD_AS001,
                 select="AS001") == []


# -- jit-region resolver -----------------------------------------------------

def test_resolver_marks_make_factory_inner_defs(tmp_path):
    src = """
        import jax.numpy as jnp

        def make_serve_fn(cfg):
            def serve_step(x):
                if jnp.any(x > 0):          # traced: must flag
                    return x
                return -x
            return serve_step
    """
    findings = _scan(tmp_path, "steps.py", src, select="RC001")
    assert _rules_of(findings) == {"RC001"}


def test_resolver_follows_cross_module_references(tmp_path):
    (tmp_path / "helpers.py").write_text(textwrap.dedent("""
        import jax.numpy as jnp

        def inner(x):
            if jnp.any(x > 0):              # traced via steps.py's jit
                return x
            return -x
    """))
    (tmp_path / "steps.py").write_text(textwrap.dedent("""
        import jax
        from helpers import inner

        @jax.jit
        def step(x):
            return inner(x)
    """))
    project = Project([str(tmp_path)])
    names = {fn.name for _, fn in project.jit_functions()}
    assert {"step", "inner"} <= names
    findings = run_rules(project, select={"RC001"})
    assert _rules_of(findings) == {"RC001"}


# -- clean excerpt of the real tree ------------------------------------------

def test_all_rules_silent_on_serve_cache():
    """serve/cache.py is the conventions file (donation-rebind idiom,
    lazy jit factories, host/device split) — every rule must pass it."""
    project = Project([str(REPO / "src" / "repro" / "serve" / "cache.py")])
    assert run_rules(project) == []


def test_whole_tree_is_clean():
    """Acceptance criterion: the shipped tree carries no findings (the
    committed baseline is empty)."""
    project = Project([str(REPO / "src" / "repro")])
    assert run_rules(project) == []


# -- baseline + CLI ----------------------------------------------------------

def test_cli_baseline_roundtrip(tmp_path, capsys):
    bad = tmp_path / "serve" / "scheduler.py"
    bad.parent.mkdir()
    bad.write_text("def f(x):\n    assert x\n    return x\n")
    baseline = tmp_path / "baseline.txt"

    assert cli_main([str(bad)]) == 1                 # finding, no baseline
    assert cli_main([str(bad), "--write-baseline",
                     "--baseline", str(baseline)]) == 0
    assert cli_main([str(bad), "--baseline", str(baseline)]) == 0

    # editing the flagged line invalidates its fingerprint
    bad.write_text("def f(x):\n    assert x is not None\n    return x\n")
    assert cli_main([str(bad), "--baseline", str(baseline)]) == 1

    # fixing the finding makes the old entry stale (warned, still green)
    bad.write_text("def f(x):\n    return x\n")
    capsys.readouterr()
    assert cli_main([str(bad), "--baseline", str(baseline)]) == 0
    assert "stale baseline entry" in capsys.readouterr().err


def test_cli_select_and_ignore(tmp_path):
    bad = tmp_path / "serve" / "scheduler.py"
    bad.parent.mkdir()
    bad.write_text("def f(x):\n    assert x\n    return x\n")
    assert cli_main([str(bad), "--select", "PG001"]) == 0
    assert cli_main([str(bad), "--ignore", "AS001"]) == 0
    assert cli_main([str(bad), "--select", "AS001"]) == 1
    assert cli_main([str(bad), "--select", "NOPE"]) == 2
    assert cli_main(["/no/such/path"]) == 2


def test_cli_github_summary(tmp_path):
    bad = tmp_path / "serve" / "scheduler.py"
    bad.parent.mkdir()
    bad.write_text("def f(x):\n    assert x\n    return x\n")
    summary = tmp_path / "summary.md"
    assert cli_main([str(bad), "--github-summary", str(summary)]) == 1
    text = summary.read_text()
    assert "AS001" in text and "| location |" in text


def test_cli_list_rules(capsys):
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in RULES:
        assert rid in out
