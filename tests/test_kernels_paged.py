"""Fused paged-decode kernels: mode conformance + fused-vs-gathered serve
equality (ISSUE 6 acceptance).

Two tiers. The kernel tier pins each paged kernel's Pallas body
(``mode="interpret"`` — the CPU stand-in for compiled Mosaic, same body
per grid cell) against the jnp ref implementation that CPU serving
actually runs, so the two dispatch arms of ``kernels.ops`` cannot drift.
The serve tier runs real ``ServeEngine`` pairs per backend family: the
fused engine must emit bitwise the gathered engine's tokens at
temperature 0, reproduce sampled streams under shared seeds, and leave
speculative decoding unchanged (the draft wave inherits the fused step,
the verify wave stays full-width by design).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.models import transformer
from repro.models.ssm import compact_snapshot_steps, paged_read_plan
from repro.serve.engine import Request, ServeEngine
from serve_oracle import engine_outputs
from test_serve_backends import FAMILY_MODELS, MAX_LEN, family_rcfg, \
    family_setup


def rand(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.5).astype(dtype)


# ---------------------------------------------------------------------------
# Kernel tier: paged attention
# ---------------------------------------------------------------------------


def _attn_case(key, B, S, H, Hkv, hd, page_size, pages_per_slot):
    """Random pool + disjoint per-slot page tables + mixed lengths."""
    ks = jax.random.split(key, 3)
    n_pages = 1 + B * pages_per_slot                   # page 0 = scratch
    q = rand(ks[0], (B, S, H, hd))
    pk = rand(ks[1], (n_pages, page_size, Hkv, hd))
    pv = rand(ks[2], (n_pages, page_size, Hkv, hd))
    table = (1 + np.arange(B * pages_per_slot)).reshape(B, pages_per_slot)
    cap = pages_per_slot * page_size
    lengths = np.minimum(np.arange(B) * 3 + 1, cap - S).astype(np.int32)
    return q, pk, pv, jnp.asarray(table, jnp.int32), jnp.asarray(lengths)


@pytest.mark.parametrize("B,S,H,Hkv,hd", [
    (2, 1, 2, 2, 16),      # plain decode step
    (2, 4, 4, 2, 16),      # chunked prefill, GQA
    (3, 2, 4, 1, 32),      # MQA
])
def test_paged_attention_interpret_matches_ref(B, S, H, Hkv, hd):
    q, pk, pv, table, lengths = _attn_case(
        jax.random.PRNGKey(3), B, S, H, Hkv, hd, page_size=4,
        pages_per_slot=4)
    ref = kops.paged_attention(q, pk, pv, table, lengths, mode="ref")
    out = kops.paged_attention(q, pk, pv, table, lengths, mode="interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_ignores_garbage_beyond_length():
    """Pool rows past each slot's causal frontier carry exactly-zero
    probability mass: poisoning them with huge values must not move a
    single output bit (this is what makes page-table truncation and
    uninitialized pool rows safe)."""
    q, pk, pv, table, lengths = _attn_case(
        jax.random.PRNGKey(4), 2, 1, 2, 2, 16, page_size=4,
        pages_per_slot=4)
    clean = kops.paged_attention(q, pk, pv, table, lengths, mode="ref")
    page_size = pk.shape[1]
    rows = (np.asarray(table)[:, :, None] * page_size
            + np.arange(page_size)).reshape(2, -1)   # physical row of pos j
    cap = rows.shape[1]
    dead = np.arange(cap)[None, :] > np.asarray(lengths)[:, None]  # > qpos
    pk_flat = np.array(pk).reshape(-1, *pk.shape[2:])
    pv_flat = np.array(pv).reshape(-1, *pv.shape[2:])
    for b in range(2):
        pk_flat[rows[b][dead[b]]] = 1e30
        pv_flat[rows[b][dead[b]]] = 1e30
    poisoned = kops.paged_attention(
        q, jnp.asarray(pk_flat).reshape(pk.shape),
        jnp.asarray(pv_flat).reshape(pv.shape), table, lengths, mode="ref")
    np.testing.assert_array_equal(np.asarray(poisoned), np.asarray(clean))


def test_paged_attention_truncated_table_preserves_output():
    """Slicing the page table to the live-page bucket (the fused path's
    speed lever, serve/cache.CacheBackend._table_view) is exact: dropping
    columns no slot has reached leaves outputs bit-identical."""
    q, pk, pv, table, lengths = _attn_case(
        jax.random.PRNGKey(5), 2, 1, 2, 2, 16, page_size=4,
        pages_per_slot=4)
    full = kops.paged_attention(q, pk, pv, table, lengths, mode="ref")
    cut = kops.paged_attention(q, pk, pv, table[:, :2], lengths, mode="ref")
    np.testing.assert_array_equal(np.asarray(cut), np.asarray(full))


# ---------------------------------------------------------------------------
# Kernel tier: paged SSM update
# ---------------------------------------------------------------------------


def _ssm_case(key, B, S, R, ds, page_size, pages_per_slot, lengths, n_new):
    ks = jax.random.split(key, 6)
    n_pages = 1 + B * pages_per_slot
    dt = jax.nn.softplus(rand(ks[0], (B, S, R))) * 0.2
    x = rand(ks[1], (B, S, R))
    Bm, Cm = rand(ks[2], (B, S, ds)), rand(ks[3], (B, S, ds))
    A = -jnp.exp(rand(ks[4], (R, ds)))
    h_pool = rand(ks[5], (n_pages, R, ds))
    table = jnp.asarray(
        (1 + np.arange(B * pages_per_slot)).reshape(B, pages_per_slot),
        jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    n_new = jnp.asarray(n_new, jnp.int32)
    t_w, phys_w = compact_snapshot_steps(table, lengths, n_new, page_size, S)
    read_page, live = paged_read_plan(table, lengths, page_size)
    return (dt, x, Bm, Cm, A, h_pool, read_page, live, phys_w, t_w, n_new)


@pytest.mark.parametrize("order", ["dbx", "dxb"])
@pytest.mark.parametrize("S,lengths,n_new", [
    (1, [3, 0], [1, 1]),     # decode step; slot 0 crosses a page boundary
    (4, [2, 5], [4, 0]),     # chunked prefill + an idle slot
    (1, [0, 7], [1, 1]),     # empty slot (no live read page)
])
def test_paged_ssm_update_interpret_matches_ref(order, S, lengths, n_new):
    args = _ssm_case(jax.random.PRNGKey(6), 2, S, R=8, ds=4, page_size=4,
                     pages_per_slot=3, lengths=lengths, n_new=n_new)
    y_ref, pool_ref = kops.paged_ssm_update(*args, order=order, mode="ref")
    y_int, pool_int = kops.paged_ssm_update(*args, order=order,
                                            mode="interpret")
    # outputs at padded positions (>= n_new) are unspecified — the serve
    # step reads position n_new-1 only, so conformance covers valid rows
    valid = (np.arange(S)[None, :] < np.asarray(n_new)[:, None])[..., None]
    np.testing.assert_allclose(np.asarray(y_int) * valid,
                               np.asarray(y_ref) * valid,
                               rtol=1e-5, atol=1e-6)
    # pools must agree except scratch page 0, where idle slots' discarded
    # snapshots land in unspecified duplicate-scatter order
    np.testing.assert_allclose(np.asarray(pool_int)[1:],
                               np.asarray(pool_ref)[1:],
                               rtol=1e-5, atol=1e-6)


def test_paged_ssm_update_touches_only_planned_pages():
    """The compact write plan is what it claims: pages outside phys_w
    (and scratch) come back bit-identical — idle slots' state survives."""
    args = _ssm_case(jax.random.PRNGKey(7), 2, 1, R=8, ds=4, page_size=4,
                     pages_per_slot=3, lengths=[3, 6], n_new=[1, 0])
    h_pool, phys_w = args[5], args[8]
    _, new_pool = kops.paged_ssm_update(*args, order="dbx", mode="ref")
    planned = set(np.asarray(phys_w).reshape(-1).tolist()) | {0}
    for page in range(h_pool.shape[0]):
        if page not in planned:
            np.testing.assert_array_equal(np.asarray(new_pool[page]),
                                          np.asarray(h_pool[page]))


# ---------------------------------------------------------------------------
# Kernel tier: sort-free sampling mask
# ---------------------------------------------------------------------------


def _sampling_case(key, B=4, V=128):
    logits = rand(key, (B, V)) * 3.0
    top_ks = jnp.asarray([0, 5, 1, V], jnp.int32)[:B]
    top_ps = jnp.asarray([1.0, 0.9, 0.5, 0.73], jnp.float32)[:B]
    return logits, top_ks, top_ps


def test_topk_topp_mask_matches_sort_based_masking():
    """The binary-search mask must reproduce the sort-based
    launch.steps.apply_top_k_top_p bit-for-bit: same survivor set, same
    untouched survivor logits, same -1e30 fill — this equality is why the
    serve sampler can swap implementations without changing any stream."""
    from repro.launch.steps import apply_top_k_top_p
    logits, top_ks, top_ps = _sampling_case(jax.random.PRNGKey(8))
    got = kops.topk_topp_mask(logits, top_ks, top_ps, mode="ref")
    want = apply_top_k_top_p(logits, top_ks, top_ps)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_topk_topp_mask_interpret_matches_ref():
    logits, top_ks, top_ps = _sampling_case(jax.random.PRNGKey(9))
    ref = kops.topk_topp_mask(logits, top_ks, top_ps, mode="ref")
    out = kops.topk_topp_mask(logits, top_ks, top_ps, mode="interpret")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# Serve tier: fused engine == gathered engine, per backend family
# ---------------------------------------------------------------------------

GREEDY_REQS = [(np.array([5, 9, 3, 7, 2, 11], np.int32), 8),
               (np.array([1, 2, 3], np.int32), 6),
               (np.array([4], np.int32), 5)]
SAMPLED_REQS = [
    (np.array([5, 9, 3, 7, 2], np.int32), 7,
     dict(temperature=1.1, top_k=16, top_p=0.9, seed=7)),
    (np.array([4, 2, 9], np.int32), 6,
     dict(temperature=0.8, top_k=0, top_p=0.7, seed=123)),
    (np.array([8], np.int32), 6, dict(temperature=1.5, seed=1)),
]


@pytest.mark.parametrize("name", sorted(FAMILY_MODELS))
def test_fused_greedy_bitwise_equals_gathered(name):
    """Acceptance criterion: temperature-0 fused decode is token-for-token
    the gathered-view engine on every backend family — mixed prompt
    lengths, continuous batching, page-boundary crossings included."""
    rcfg, params, _ = family_setup(name)
    kw = dict(max_len=MAX_LEN, max_batch=2, page_size=4)
    _, ref = engine_outputs(rcfg, params, GREEDY_REQS, fused=False, **kw)
    _, got = engine_outputs(rcfg, params, GREEDY_REQS, **kw)
    for a, b in zip(ref, got, strict=True):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("name", sorted(FAMILY_MODELS))
def test_fused_sampled_stream_equals_gathered(name):
    """Sampled requests share the (seed, tokens_emitted) key schedule, so
    the fused sampler epilogue must reproduce the gathered engine's
    streams exactly — masking is bitwise, Gumbel keys are unchanged."""
    rcfg, params, _ = family_setup(name)
    kw = dict(max_len=MAX_LEN, max_batch=2, page_size=4)
    _, ref = engine_outputs(rcfg, params, SAMPLED_REQS, fused=False, **kw)
    _, got = engine_outputs(rcfg, params, SAMPLED_REQS, **kw)
    for a, b in zip(ref, got, strict=True):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("name", ["decoder", "ssm_mamba2", "hybrid"])
def test_spec_decode_unchanged_by_fused_step(name):
    """Spec decode over the fused engine: the draft wave runs the fused
    step, the verify wave stays full-width/unfused by design — greedy
    output must still equal the plain fused engine's bitwise."""
    from repro.serve.spec import SpecConfig
    rcfg, params, _ = family_setup(name)
    kw = dict(max_len=MAX_LEN, max_batch=2, page_size=4)
    _, ref = engine_outputs(rcfg, params, GREEDY_REQS, **kw)
    eng, got = engine_outputs(rcfg, params, GREEDY_REQS,
                              spec=SpecConfig(cf=2, k=3), **kw)
    for a, b in zip(ref, got, strict=True):
        np.testing.assert_array_equal(a, b)
    assert eng.stats["tokens_drafted"] > 0


def test_table_view_slices_to_live_page_bucket():
    """Host-side speed lever: _table_view hands the jitted step a
    power-of-two page-table slice covering every live slot, so shallow
    batches never pay full-capacity attention width (and the trace count
    stays <= log2(P)+1)."""
    rcfg, params, _ = family_setup("decoder")
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                      page_size=4)
    backend = eng.scheduler.backend
    table = np.zeros((2, 8), np.int32)      # capacity: 8 pages of 4

    def width(lengths, n_new):
        from repro.serve.cache import SlotBatch
        slots = SlotBatch.greedy(
            2, table, lengths=np.asarray(lengths, np.int32),
            n_new=np.asarray(n_new, np.int32))
        return backend._table_view(slots).shape[1]

    assert width([0, 0], [1, 1]) == 1       # first token: 1 page
    assert width([4, 2], [1, 1]) == 2       # deepest slot on page 2
    assert width([9, 1], [1, 1]) == 4       # 10 rows -> 3 pages -> pow2 4
    assert width([26, 0], [1, 1]) == 8      # near capacity: full table
    assert width([31, 0], [1, 0]) == 8      # never beyond capacity
