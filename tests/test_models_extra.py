"""Additional model-substrate tests: MoE properties, enc-dec decode oracle,
mixed-precision master weights, grouped-dispatch consistency."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ModelConfig, MoEConfig,
                                OptimizerConfig)
from repro.configs import registry
from repro.configs.reduce import reduce_config
from repro.models import transformer
from repro.models.moe import capacity, init_moe, moe_apply
from repro.optim import optimizers


def moe_cfg(group_size=0):
    return ModelConfig(name="m", family="decoder", n_layers=2, d_model=32,
                       n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                       moe=MoEConfig(num_experts=4, top_k=2, d_ff=64,
                                     group_size=group_size))


def test_moe_batch_permutation_equivariance():
    cfg = moe_cfg()
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32),
                          jnp.float32) * 0.5
    y = moe_apply(params, x, cfg)
    perm = jnp.array([2, 0, 3, 1])
    y_perm = moe_apply(params, x[perm], cfg)
    np.testing.assert_allclose(np.asarray(y[perm], np.float32),
                               np.asarray(y_perm, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_moe_grouping_close_to_ungrouped():
    """With ample capacity, 8-token groups route like whole-sequence
    dispatch (same experts, same gates)."""
    cfg0, cfgg = moe_cfg(0), moe_cfg(group_size=8)
    params = init_moe(jax.random.PRNGKey(0), cfg0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32)) * 0.5
    y0 = moe_apply(params, x, cfg0)
    yg = moe_apply(params, x, cfgg)
    # tokens dropped by capacity may differ at group boundaries; most of
    # the outputs must agree exactly
    close = np.isclose(np.asarray(y0, np.float32),
                       np.asarray(yg, np.float32), rtol=2e-2,
                       atol=2e-2).mean()
    assert close > 0.9, f"only {close:.2%} matched"


def test_moe_capacity_bounds():
    cfg = moe_cfg()
    c = capacity(128, cfg)
    assert 4 <= c <= 128
    assert c >= 128 * cfg.moe.top_k / cfg.moe.num_experts  # >= avg load


def test_encdec_decode_matches_teacher_forced():
    rcfg = reduce_config(registry.get_config("seamless_m4t_v2"))
    cfg = rcfg.model
    key = jax.random.PRNGKey(0)
    params = transformer.init_model(key, rcfg)
    B, T = 2, 6
    src = jax.random.normal(key, (B, 8, cfg.d_model)) * 0.1
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, T), 0,
                              cfg.vocab_size)
    full, _ = jax.jit(lambda p, b: transformer.forward(
        p, b, rcfg, mode="serial"))(
        params, {"src_embeds": src, "tokens": toks})
    # decode through the decoder trunk with cross-attention to the same
    # encoder output used by the full forward
    from repro.models.transformer import _trunk, _rope_for
    xe = src.astype(jnp.dtype(cfg.dtype))
    xN, _ = _trunk(params["enc_mid"], xe, rcfg, kind="attn_mlp",
                   causal=False, rope=_rope_for(cfg, 8), mode="serial")
    cache = transformer.init_cache(rcfg, B, T)
    step = jax.jit(lambda p, c, t: transformer.decode_step(p, c, t, rcfg,
                                                           xa=xN))
    outs = []
    for t in range(T):
        lg, cache = step(params, cache, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_bf16_master_weights_update_path():
    """bf16 stored params + fp32 master: repeated tiny updates accumulate
    in the master (they would vanish in bf16 alone)."""
    cfg = OptimizerConfig(name="sgd", lr=1e-4, warmup_steps=0,
                          schedule="constant", grad_clip=1e9)
    params = {"w": jnp.ones((16,), jnp.bfloat16)}
    state = optimizers.init_opt_state(cfg, params)
    assert "master" in state
    for _ in range(50):
        grads = {"w": jnp.full((16,), 0.05, jnp.bfloat16)}
        params, state, _ = optimizers.apply_updates(cfg, params, grads,
                                                    state)
    drift = 1.0 - float(state["master"]["w"][0])
    # 50 steps of lr*m accumulation visible in fp32 master
    assert drift > 1e-4
    assert params["w"].dtype == jnp.bfloat16


def test_gate_frozen_under_weight_decay():
    cfg = OptimizerConfig(name="adamw", lr=0.1, weight_decay=0.5,
                          warmup_steps=0, schedule="constant")
    params = {"mid": {"gate": jnp.array([1.0, 0.0]),
                      "params": {"w": jnp.ones((4,))}}}
    state = optimizers.init_opt_state(cfg, params)
    grads = jax.tree.map(jnp.ones_like, params)
    for _ in range(5):
        params, state, _ = optimizers.apply_updates(cfg, params, grads,
                                                    state)
    np.testing.assert_array_equal(np.asarray(params["mid"]["gate"]),
                                  [1.0, 0.0])
    assert float(params["mid"]["params"]["w"][0]) != 1.0
