"""CacheBackend family regression suite + streaming API.

Every decode-capable config family serves through the unified paged
engine and accepts sampled requests (the old dense fallback rejected
``temperature > 0`` — this is the regression net for that bugfix):

  (a) temperature 0 is token-for-token the dense serial-forward oracle
      (bitwise-greedy per backend),
  (b) sampled requests (temperature/top_k/top_p/seed) reproduce the
      dense-oracle logits + host-side ``sample_tokens`` stream exactly,
  (c) streaming (`ServeEngine.submit(..., stream=True)`) yields the same
      tokens as batch generation with incremental detokenization.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import (MGRITConfig, ModelConfig, MoEConfig,
                                OptimizerConfig, RunConfig, SSMConfig,
                                ShapeConfig)
from repro.models import transformer
from repro.serve.cache import (HybridBackend, PagedKVBackend,
                               SSMStateBackend, make_backend)
from repro.serve.engine import Request, ServeEngine, default_detokenize
from serve_oracle import dense_decode_oracle

pytestmark = pytest.mark.serve

VOCAB = 64
MAX_LEN = 32

FAMILY_MODELS = {
    "decoder": dict(family="decoder"),
    "decoder_moe": dict(family="decoder",
                        moe=MoEConfig(num_experts=4, top_k=2, d_ff=64)),
    "ssm_mamba1": dict(family="ssm", n_layers=4, act="silu", norm="rmsnorm",
                       ssm=SSMConfig(version=1, d_state=8, d_conv=3)),
    "ssm_mamba2": dict(family="ssm", n_layers=4, act="silu", norm="rmsnorm",
                       ssm=SSMConfig(version=2, d_state=8, d_conv=3,
                                     headdim=16)),
    "hybrid": dict(family="hybrid", n_layers=5, hybrid_attn_every=2,
                   act="silu", norm="rmsnorm",
                   ssm=SSMConfig(version=2, d_state=8, d_conv=3,
                                 headdim=16)),
}

EXPECTED_BACKEND = {
    "decoder": PagedKVBackend,
    "decoder_moe": PagedKVBackend,
    "ssm_mamba1": SSMStateBackend,
    "ssm_mamba2": SSMStateBackend,
    "hybrid": HybridBackend,
}


def family_rcfg(name: str) -> RunConfig:
    kw = dict(name=name, family="decoder", n_layers=8, d_model=32,
              n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=VOCAB,
              act="gelu", norm="layernorm", dtype="float32")
    kw.update(FAMILY_MODELS[name])
    return RunConfig(
        model=ModelConfig(**kw),
        mgrit=MGRITConfig(enabled=True, cf=2, levels=2, fwd_iters=1,
                          bwd_iters=1, n_open=1, n_close=1, pad_to=2),
        optimizer=OptimizerConfig(),
        shape=ShapeConfig(name, "train", 16, 4))


_PARAMS = {}


def family_setup(name: str):
    if name not in _PARAMS:
        rcfg = family_rcfg(name)
        params = transformer.init_model(
            jax.random.PRNGKey(sum(map(ord, name)) % 1000), rcfg)
        step = jax.jit(
            lambda p, c, t, _rcfg=rcfg: transformer.decode_step(
                p, c, t, _rcfg))
        _PARAMS[name] = (rcfg, params, step)
    return _PARAMS[name]


def dense_oracle(rcfg, params, step, req: Request) -> np.ndarray:
    return dense_decode_oracle(rcfg, params, step, req, MAX_LEN)


@pytest.mark.parametrize("name", sorted(FAMILY_MODELS))
def test_every_family_samples_and_temp0_is_greedy(name):
    """Regression for the deleted dense fallback: every family accepts
    sampled requests, and temperature 0 stays bitwise-greedy vs the
    dense serial oracle."""
    rcfg, params, step = family_setup(name)
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                      page_size=4)
    assert isinstance(eng.backend, EXPECTED_BACKEND[name])
    greedy = Request(prompt=np.array([5, 9, 3, 7, 2], np.int32),
                     max_new_tokens=5)
    sampled = Request(prompt=np.array([4, 2, 9], np.int32),
                      max_new_tokens=5, temperature=1.1, top_k=16,
                      top_p=0.9, seed=7)
    out = eng.generate([greedy, sampled])
    for r, ref in zip(out, (dense_oracle(rcfg, params, step, greedy),
                            dense_oracle(rcfg, params, step, sampled)),
                        strict=True):
        np.testing.assert_array_equal(r.output, ref)


@pytest.mark.parametrize("name", ["ssm_mamba1", "hybrid"])
def test_prefix_sharing_matches_no_sharing(name):
    """Snapshot-page prefix sharing (SSM/hybrid) computes fewer prefill
    tokens and never changes outputs."""
    rcfg, params, _ = family_setup(name)
    common = np.arange(1, 9, dtype=np.int32) % VOCAB     # 2 pages of 4

    def reqs():
        return [Request(prompt=np.concatenate(
                    [common, np.array([20 + i], np.int32)]),
                        max_new_tokens=4) for i in range(4)]

    base = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                       page_size=4, share_prefix=False)
    out_base = base.generate(reqs())
    shared = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                         page_size=4, share_prefix=True)
    out_shared = shared.generate(reqs())
    for a, b in zip(out_base, out_shared, strict=True):
        np.testing.assert_array_equal(a.output, b.output)
    sb, ss = base.scheduler.stats, shared.scheduler.stats
    assert ss["prefill_tokens"] < sb["prefill_tokens"]
    assert ss["shared_tokens"] > 0
    # pool fully drains once the trie lets go
    shared.scheduler.drop_prefix_cache()
    assert shared.scheduler.alloc.n_free \
        == shared.scheduler.alloc.n_pages - 1


def test_ssm_full_prompt_hit_recomputes_last_page_only():
    """A page-aligned full-prompt hit on a snapshot backend cannot fork
    mid-page; it drops the last shared page and recomputes exactly
    page_size tokens (the KV backend recomputes exactly 1)."""
    rcfg, params, _ = family_setup("ssm_mamba1")
    prompt = np.arange(1, 9, dtype=np.int32) % VOCAB     # exactly 2 pages
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=1,
                      page_size=4)
    a = eng.generate([Request(prompt=prompt, max_new_tokens=5)])[0]
    pt0 = eng.scheduler.stats["prefill_tokens"]
    b = eng.generate([Request(prompt=prompt, max_new_tokens=5)])[0]
    np.testing.assert_array_equal(a.output, b.output)
    assert eng.scheduler.stats["prefill_tokens"] == pt0 + 4
    eng.scheduler.drop_prefix_cache()
    assert eng.scheduler.alloc.n_free == eng.scheduler.alloc.n_pages - 1


def test_streaming_matches_generate_and_detokenizes():
    """submit(stream=True) yields (token_id, text_piece) pairs equal to
    batch generation, pieces concatenate to the full detokenization, and
    the Request is finalized on exhaustion."""
    rcfg, params, _ = family_setup("decoder")
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                      page_size=4)
    prompt = np.array([3, 1, 4, 1, 5], np.int32)
    ref = eng.generate([Request(prompt=prompt, max_new_tokens=6)])[0]
    sreq = Request(prompt=prompt, max_new_tokens=6)
    toks, pieces = [], []
    for tok, piece in eng.submit(sreq, stream=True):
        toks.append(tok)
        pieces.append(piece)
    np.testing.assert_array_equal(np.asarray(toks, np.int32), ref.output)
    assert "".join(pieces) == default_detokenize(ref.output)
    np.testing.assert_array_equal(sreq.output, ref.output)
    assert sreq.ttft_s is not None and sreq.latency_s is not None


def test_streaming_interleaves_with_queued_requests():
    """Pulling a stream drives the whole scheduler: queued requests decode
    lock-step and finish with the same outputs as solo runs."""
    rcfg, params, _ = family_setup("ssm_mamba1")
    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                      page_size=4)
    solo = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=2,
                       page_size=4)
    other = Request(prompt=np.array([9, 8, 7], np.int32), max_new_tokens=4)
    ref_other = solo.generate([Request(prompt=other.prompt,
                                       max_new_tokens=4)])[0]
    sreq = Request(prompt=np.array([1, 2], np.int32), max_new_tokens=6,
                   temperature=0.8, seed=11)
    stream = eng.submit(sreq, stream=True)
    rid_other = eng.submit(other)
    toks = [tok for tok, _ in stream]
    assert len(toks) == 6
    done = eng.scheduler.run()          # other finished alongside
    np.testing.assert_array_equal(
        np.asarray(done[rid_other].out, np.int32), ref_other.output)


def test_streaming_custom_detokenizer_diffs():
    """A multi-char detokenizer streams text diffs (incremental
    detokenization re-renders the prefix and emits only the new text)."""
    rcfg, params, _ = family_setup("decoder")

    def detok(ids):
        return " ".join(str(int(i)) for i in ids)

    eng = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=1,
                      page_size=4, detokenize=detok)
    sreq = Request(prompt=np.array([2, 4, 6], np.int32), max_new_tokens=4)
    pieces = [piece for _, piece in eng.submit(sreq, stream=True)]
    assert "".join(pieces) == detok(sreq.output)
    assert all(not p.startswith(" ") or i > 0
               for i, p in enumerate(pieces))
    # a non-prefix-monotonic detokenizer falls back to re-emitting the
    # full rendering instead of a broken diff
    eng2 = ServeEngine(rcfg, params, max_len=MAX_LEN, max_batch=1,
                       page_size=4,
                       detokenize=lambda ids: f"[{len(ids)} tokens]")
    sreq2 = Request(prompt=np.array([1, 3], np.int32), max_new_tokens=3)
    pieces2 = [p for _, p in eng2.submit(sreq2, stream=True)]
    assert pieces2 == ["[1 tokens]", "[2 tokens]", "[3 tokens]"]


def test_make_backend_rejects_non_decode_families():
    for fam, extra in (("encoder", {}),
                       ("encdec", {"n_dec_layers": 4})):
        kw = dict(name="x", family=fam, n_layers=4, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab_size=VOCAB, act="gelu",
                  norm="layernorm", dtype="float32", **extra)
        rcfg = RunConfig(
            model=ModelConfig(**kw),
            mgrit=MGRITConfig(enabled=False),
            optimizer=OptimizerConfig(),
            shape=ShapeConfig("x", "train", 16, 4))
        with pytest.raises(NotImplementedError, match="CacheBackend"):
            make_backend(rcfg, params=None)
