"""Parameter / cache / batch sharding assignment (logical axes by tree path).

``param_specs`` walks a params pytree (arrays or ShapeDtypeStructs) and
assigns every leaf a PartitionSpec:

  * stacked trunk leaves (under mid/enc_mid/dec_mid) get a leading "layers"
    axis — the MGRIT chunk axis, sharded over the physical 'model' axis in
    the paper's training regime;
  * weight-matrix dims map to logical heads/mlp/embed/vocab/experts axes
    (Megatron TP when the config routes them to 'model');
  * if ``sharding.fsdp`` is set, the largest still-unsharded dim of every
    big leaf is storage-sharded over the fsdp axis (ZeRO/FSDP; XLA
    all-gathers just-in-time) — this is what makes grok-1-314b fit;
  * every mapping is divisibility-checked against the mesh and dropped when
    it does not divide (e.g. 28 heads over 16-way model).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import RunConfig, ShardingConfig
from repro.parallel.sharding import resolve_axis

# logical axis tuples by (leaf name, ndim) — without the stacked prefix
_LEAF_AXES = {
    ("tok", 2): ("vocab", "embed"),
    ("out", 2): ("vocab", "embed"),
    ("wq", 3): ("embed", "heads", "head_dim"),
    ("wk", 3): ("embed", "kv_heads", "head_dim"),
    ("wv", 3): ("embed", "kv_heads", "head_dim"),
    ("wo", 3): ("heads", "head_dim", "embed"),
    ("w_in", 2): ("embed", "mlp"),
    ("w_gate", 2): ("embed", "mlp"),
    ("w_out", 2): ("mlp", "embed"),
    ("w_in", 3): ("experts", "embed", "mlp"),
    ("w_gate", 3): ("experts", "embed", "mlp"),
    ("w_out", 3): ("experts", "mlp", "embed"),
    ("router", 2): ("embed", "experts"),
    ("in_proj", 2): ("embed", "mlp"),
    ("x_proj", 2): ("mlp", None),
    ("dt_proj", 2): (None, "mlp"),
    ("A_log", 2): ("mlp", None),
    ("conv_w", 2): (None, "mlp"),
    ("out_proj", 2): ("mlp", "embed"),
}

_STACKED_ROOTS = ("mid", "enc_mid", "dec_mid")
_FSDP_MIN_SIZE = 1 << 22  # only storage-shard leaves >= 4M elements


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
    return tuple(names)


def logical_axes_for(path, shape) -> Tuple[Optional[str], ...]:
    names = set(_path_names(path))
    leaf = _path_names(path)[-1] if path else ""
    in_trunk = bool(names & set(_STACKED_ROOTS))
    in_buffer = bool(names & {"open", "close", "backbone"})
    stacked = in_trunk or in_buffer
    if leaf == "gate":
        return ("layers",)
    base_ndim = len(shape) - (1 if stacked else 0)
    base = _LEAF_AXES.get((leaf, base_ndim), (None,) * base_ndim)
    if stacked:
        return (("layers",) if in_trunk else (None,)) + base
    return base


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def build_spec(logical: Tuple[Optional[str], ...], shape,
               cfg: ShardingConfig, mesh: Mesh,
               nbytes: int = 0) -> P:
    """Resolve logical names -> physical axes with divisibility checks,
    per-tensor axis dedupe, and an FSDP fallback for large leaves."""
    used = set()
    phys = []
    for dim, name in zip(shape, logical, strict=True):
        ax = resolve_axis(name, cfg, mesh)
        if ax is not None:
            axs = ax if isinstance(ax, tuple) else (ax,)
            if any(a in used for a in axs) or dim % _axis_size(mesh, ax):
                ax = None
            else:
                used.update(axs)
        phys.append(ax)
    # FSDP: storage-shard the largest unsharded dim of big leaves
    if cfg.fsdp and cfg.fsdp in mesh.axis_names and cfg.fsdp not in used \
            and int(np.prod(shape)) >= _FSDP_MIN_SIZE:
        fs = mesh.shape[cfg.fsdp]
        cands = [(d, i) for i, (d, ax) in enumerate(zip(shape, phys, strict=True))
                 if ax is None and d % fs == 0]
        if cands:
            _, i = max(cands)
            phys[i] = cfg.fsdp
    return P(*phys)


def param_specs(params, rcfg: RunConfig, mesh: Mesh):
    """Pytree of NamedShardings matching `params` (arrays or SDS)."""
    cfg = rcfg.sharding

    def one(path, leaf):
        logical = logical_axes_for(path, leaf.shape)
        spec = build_spec(logical, leaf.shape, cfg, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# Batches and caches
# ---------------------------------------------------------------------------

_BATCH_AXES = {
    "tokens": ("batch", None),
    "labels": ("batch", None),
    "src_tokens": ("batch", None),
    "mm_embeds": ("batch", None, "embed"),
    "src_embeds": ("batch", None, "embed"),
}

_CACHE_AXES = {
    ("k", 5): (None, "batch", "kv_seq", "kv_heads", "head_dim"),
    ("v", 5): (None, "batch", "kv_seq", "kv_heads", "head_dim"),
    ("conv", 4): (None, "batch", None, "mlp"),
    ("h", 4): (None, "batch", "mlp", None),
    ("h", 5): (None, "batch", "mlp", None, None),
    ("index", 0): (),
}

# Paged-serving page pools (repro.serve.cache state trees). Same leaf
# names as the dense caches but the batch/seq axes are replaced by one
# global physical-page axis (axis 1 by the CacheBackend convention):
#   KV pages        k/v   (L, n_pages, page_size, Hkv, hd)
#   mamba1 snapshots conv (L, n_pages, K-1, d_inner), h (L, n_pages,
#                    d_inner, d_state)
#   mamba2 snapshots conv (L, n_pages, K-1, d_inner+2*d_state), h
#                    (L, n_pages, n_heads, headdim, d_state)
# The "pages" logical axis is the serving data-parallel dimension
# (ShardingConfig.pages, 'data' under registry.serve_sharding): each
# shard stores a slice of the physical pages while page ids stay global
# — the host allocator/trie/scheduler never see the mesh. Head/inner
# dims ride the same TP mapping as the weights so a TP shard keeps its
# own heads' KV local.
_PAGED_POOL_AXES = {
    ("k", 5): (None, "pages", None, "kv_heads", "head_dim"),
    ("v", 5): (None, "pages", None, "kv_heads", "head_dim"),
    ("conv", 4): (None, "pages", None, "mlp"),
    ("h", 4): (None, "pages", "mlp", None),
    ("h", 5): (None, "pages", "heads", None, None),
}


def batch_specs(batch, rcfg: RunConfig, mesh: Mesh):
    cfg = rcfg.sharding

    def one(path, leaf):
        name = _path_names(path)[-1]
        logical = _BATCH_AXES.get(name, ("batch",) + (None,) * (leaf.ndim - 1))
        return NamedSharding(mesh, build_spec(logical, leaf.shape, cfg, mesh))

    return jax.tree_util.tree_map_with_path(one, batch)


def cache_specs(cache, rcfg: RunConfig, mesh: Mesh):
    """Pytree of NamedShardings for a dense decode cache (stacked over
    layers, per-slot batch axis)."""
    return _state_specs(cache, rcfg, mesh, _CACHE_AXES)


def paged_state_specs(state, rcfg: RunConfig, mesh: Mesh):
    """Pytree of NamedShardings for a CacheBackend page-pool state tree
    (``_PAGED_POOL_AXES``): physical pages sharded over the serving DP
    axis, head/inner dims over TP, with the usual divisibility checks —
    a non-divisible mapping is dropped (replicated), never an error."""
    return _state_specs(state, rcfg, mesh, _PAGED_POOL_AXES)


def _state_specs(tree, rcfg: RunConfig, mesh: Mesh, table):
    cfg = rcfg.sharding

    def one(path, leaf):
        name = _path_names(path)[-1]
        logical = table.get((name, leaf.ndim), (None,) * leaf.ndim)
        return NamedSharding(mesh, build_spec(logical, leaf.shape, cfg, mesh))

    return jax.tree_util.tree_map_with_path(one, tree)
