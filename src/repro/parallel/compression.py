"""Gradient compression for cross-pod reduction (int8 + error feedback).

At multi-pod scale the pod-to-pod hop is the thinnest link; quantizing the
gradient all-reduce payload to int8 with per-block scales cuts its bytes 4x
(vs fp32) at the cost of quantization noise, which error feedback (residual
carried to the next step) removes in expectation — the standard
EF-SGD/PowerSGD-style trick. Enabled per-config via
``sharding.compress_grads``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 2048


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block symmetric int8 quantization. Returns (q, scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale, shape) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compress_leaf(g, err):
    """Error-feedback compression of one gradient leaf.

    Returns (g_compressed, new_err): g_compressed is what enters the
    cross-pod all-reduce (int8-representable values, materialized as f32 so
    the psum stays a single fused collective); new_err is the residual."""
    g32 = g.astype(jnp.float32) + err
    q, s = quantize_int8(g32)
    gq = dequantize_int8(q, s, g32.shape)
    return gq.astype(g.dtype), (g32 - gq)


def compress_tree(grads, err_tree):
    out = jax.tree.map(compress_leaf, grads, err_tree)
    g = jax.tree.map(lambda t: t[0], out,
                     is_leaf=lambda x: isinstance(x, tuple))
    e = jax.tree.map(lambda t: t[1], out,
                     is_leaf=lambda x: isinstance(x, tuple))
    return g, e


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
