"""Logical-axis sharding (MaxText-style rules).

Tensors throughout the model code are annotated with *logical* axis names
(e.g. ``("batch", "seq", "embed")``). A ``ShardingConfig`` maps logical names
to physical mesh axes. ``logical_constraint`` applies
``with_sharding_constraint`` when called under an active mesh + rules context;
it is a no-op otherwise, so the same model code runs on one CPU device in
tests and on a 512-chip mesh in the dry-run.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ShardingConfig

_ctx = threading.local()


def _state():
    if not hasattr(_ctx, "stack"):
        _ctx.stack = []
    return _ctx.stack


@contextlib.contextmanager
def axis_rules(mesh: Optional[Mesh], cfg: ShardingConfig):
    """Activate logical->physical mapping for the enclosed trace."""
    _state().append((mesh, cfg))
    try:
        yield
    finally:
        _state().pop()


def current_rules() -> Tuple[Optional[Mesh], Optional[ShardingConfig]]:
    st = _state()
    return st[-1] if st else (None, None)


def resolve_axis(logical: Optional[str], cfg: ShardingConfig,
                 mesh: Mesh):
    """Logical axis name -> physical mesh axis (or None)."""
    if logical is None:
        return None
    phys = getattr(cfg, logical, None) if hasattr(cfg, logical) else None
    # aliases that share a physical mapping
    if phys is None:
        alias = {"kv_heads": "heads", "seq": None, "head_dim": None,
                 "state": None, "conv": None}.get(logical, None)
        if alias is not None:
            phys = getattr(cfg, alias, None)
    if phys is None:
        return None
    if "+" in phys:  # compound mapping, e.g. "data+pod", "data+model"
        axes = tuple(a for a in phys.split("+") if a in mesh.axis_names)
        if phys == "data+pod":  # pod leads for contiguous batch shards
            axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        return axes if axes else None
    if phys not in mesh.axis_names:
        return None
    return phys


def _axis_size(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        size = 1
        for a in ax:
            size *= mesh.shape[a]
        return size
    return mesh.shape[ax]


def spec_for(names: Sequence[Optional[str]], cfg: ShardingConfig,
             mesh: Mesh, shape: Optional[Sequence[int]] = None) -> P:
    axes = []
    used = set()
    for i, n in enumerate(names):
        ax = resolve_axis(n, cfg, mesh)
        if ax is not None:
            flat = ax if isinstance(ax, tuple) else (ax,)
            bad = any(a in used for a in flat)
            if shape is not None and shape[i] % _axis_size(mesh, ax):
                bad = True  # non-divisible: drop instead of erroring
            if bad:
                ax = None
            else:
                used.update(flat)
        axes.append(ax)
    return P(*axes)


def logical_constraint(x, names: Sequence[Optional[str]]):
    """Apply with_sharding_constraint using the active rules (no-op without)."""
    mesh, cfg = current_rules()
    if mesh is None or cfg is None:
        return x
    if x.ndim != len(names):
        raise ValueError(f"rank {x.ndim} != names {names}")
    spec = spec_for(names, cfg, mesh, x.shape)
    # skip if nothing shards (avoids HLO noise)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, cfg: ShardingConfig,
                   names: Sequence[Optional[str]]) -> NamedSharding:
    return NamedSharding(mesh, spec_for(names, cfg, mesh))


def tree_shardings(mesh: Mesh, cfg: ShardingConfig, logical_tree):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return jax.tree.map(
        lambda names: named_sharding(mesh, cfg, names),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(n, (str, type(None))) for n in x),
    )
