"""Data pipeline: deterministic synthetic LM stream + memmap token reader.

Determinism contract for fault tolerance: batch(step) is a pure function of
(seed, step), so a restarted job replays the exact stream — checkpoints
store only the step counter. Batches are placed with the mesh's batch
sharding when a mesh is active.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig


class SyntheticLM:
    """Markov-ish synthetic token stream with learnable structure (bigram
    transitions), so losses genuinely decrease during the examples."""

    def __init__(self, rcfg: RunConfig, seed: int = 0,
                 batch_override: Optional[int] = None,
                 seq_override: Optional[int] = None):
        self.rcfg = rcfg
        self.seed = seed
        self.vocab = rcfg.model.vocab_size
        self.batch = batch_override or rcfg.shape.global_batch
        self.seq = seq_override or rcfg.shape.seq_len
        rng = np.random.default_rng(seed)
        # sparse bigram structure: each token prefers a few successors
        self.succ = rng.integers(0, self.vocab, size=(self.vocab, 4))

    def batch_at(self, step: int) -> Dict[str, Any]:
        rng = np.random.default_rng((self.seed * 1_000_003 + step) & 0x7FFFFFFF)
        toks = np.empty((self.batch, self.seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=self.batch)
        noise = rng.random((self.batch, self.seq))
        choice = rng.integers(0, 4, size=(self.batch, self.seq))
        rand_tok = rng.integers(0, self.vocab, size=(self.batch, self.seq))
        for t in range(self.seq):
            nxt = self.succ[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(noise[:, t] < 0.15, rand_tok[:, t], nxt)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        cfg = self.rcfg.model
        if cfg.family == "encdec":
            if cfg.frontend == "audio":   # stubbed frame embeddings
                batch["src_embeds"] = rng.standard_normal(
                    (self.batch, self.seq, cfg.d_model)).astype(
                        np.float32) * 0.1
            else:                          # text source (MT)
                batch["src_tokens"] = rng.integers(
                    0, self.vocab, size=(self.batch, self.seq)).astype(
                        np.int32)
        if cfg.frontend == "vision":
            batch["mm_embeds"] = rng.standard_normal(
                (self.batch, 4, cfg.d_model)).astype(np.float32) * 0.1
        return batch

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, Any]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class MemmapLM:
    """nanoGPT-style flat token file reader (``train.bin`` of uint16)."""

    def __init__(self, path: str, rcfg: RunConfig, seed: int = 0):
        self.data = np.memmap(path, dtype=np.uint16, mode="r")
        self.rcfg = rcfg
        self.seed = seed
        self.batch = rcfg.shape.global_batch
        self.seq = rcfg.shape.seq_len

    def batch_at(self, step: int) -> Dict[str, Any]:
        rng = np.random.default_rng((self.seed * 1_000_003 + step) & 0x7FFFFFFF)
        ix = rng.integers(0, len(self.data) - self.seq - 1, size=self.batch)
        toks = np.stack([self.data[i:i + self.seq + 1].astype(np.int32)
                         for i in ix])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_pipeline(rcfg: RunConfig, seed: int = 0, data_path: str = "",
                  **kw):
    if data_path and os.path.exists(data_path):
        return MemmapLM(data_path, rcfg, seed)
    return SyntheticLM(rcfg, seed, **kw)


def shard_batch(batch, mesh, rcfg: RunConfig):
    """Place host numpy batch with the configured batch sharding."""
    from repro.parallel.params import batch_specs
    if mesh is None:
        return jax.tree.map(jnp.asarray, batch)
    specs = batch_specs(jax.tree.map(np.asarray, batch), rcfg, mesh)
    return jax.tree.map(lambda a, s: jax.device_put(a, s), batch, specs)
