"""Flash-decode paged-attention Pallas kernel (TPU target).

Decode-side twin of :mod:`repro.kernels.flash_attention`: instead of a
contiguous (B, H, Sk, hd) K/V tensor, keys live in the serve engine's page
pool (n_pages, page_size, Hkv, hd) and each batch slot owns a row of the
page table. The kernel walks that row **in-kernel** — the page table and
per-slot lengths are scalar-prefetch operands, so the BlockSpec index maps
resolve logical page p of slot b to physical page ``page_table[b, p]``
while the grid runs. One grid block per (slot, head, page); the online-
softmax state (m, l, acc) for the S query rows lives in VMEM scratch
across the sequential page dimension, exactly like the prefill kernel's
k-block dimension. GQA stays an index-map concern: query head h reads KV
head ``h // group``.

Dead pages (beyond ``lengths[b] + S - 1``) are skipped with ``pl.when`` —
the page walk does the work the gathered-dense-view path spends on a
(B, P*page_size, Hkv, hd) gather plus a full-width masked softmax.

``paged_attention_ref`` is the jnp oracle AND the CPU production path
(:mod:`repro.kernels.ops` mode="ref"): it reproduces the gathered-view
math bit-for-bit — same gather construction, same einsum contractions,
same mask constant — so fused serving at temperature 0 emits exactly the
tokens the gathered path emits. Its speed lever is the caller slicing the
page table to the live page count (``repro.serve.cache`` buckets it to a
power of two) rather than gathering the table's full width.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_flash_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, page_size: int,
                        n_pages: int, s_q: int, scale: float):
    b = pl.program_id(0)
    p = pl.program_id(2)          # logical page index (sequential)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # a page is live iff its first key position can be attended by the
    # last query row (absolute position lengths[b] + s_q - 1)
    live = (p * page_size) <= (len_ref[b] + s_q - 1)

    @pl.when(live)
    def _page():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (s_q, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (ps, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = len_ref[b] + jax.lax.broadcasted_iota(
            jnp.int32, (s_q, page_size), 0)
        kpos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (s_q, page_size), 1)
        # one mask covers causality AND staleness: key slots past a
        # query's absolute position are either future tokens or garbage
        # beyond the slot's written length
        s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        pexp = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(pexp, axis=1)
        m_ref[...] = m_new
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(p == n_pages - 1)
    def _finish():
        o_ref[0, 0, ...] = (acc_ref[...]
                            / jnp.maximum(l_ref[...][:, None], 1e-30)
                            ).astype(o_ref.dtype)


def paged_flash_attention_bhsd(q, pk, pv, page_table, lengths, *,
                               interpret: bool = False):
    """q: (B, H, S, hd); pk/pv: (n_pages, page_size, Hkv, hd);
    page_table: (B, P) int32; lengths: (B,) int32 — q row i of slot b sits
    at absolute position ``lengths[b] + i``. Returns (B, H, S, hd).

    S is tiny (1 in steady-state decode, k+1 in speculative verify, the
    prompt bucket in chunked prefill); the page walk supplies the K
    extent, so P — not S — carries the flash tiling.
    """
    B, H, S, hd = q.shape
    Hkv, page_size = pk.shape[2], pk.shape[1]
    P = page_table.shape[1]
    g = H // Hkv
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(_paged_flash_kernel, page_size=page_size,
                               n_pages=P, s_q=S, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, H, P),
        in_specs=[
            pl.BlockSpec((1, 1, S, hd),
                         lambda b, h, p, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda b, h, p, pt, ln: (pt[b, p], 0, h // g, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda b, h, p, pt, ln: (pt[b, p], 0, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, S, hd),
                               lambda b, h, p, pt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((S,), jnp.float32),        # m
            pltpu.VMEM((S,), jnp.float32),        # l
            pltpu.VMEM((S, hd), jnp.float32),     # acc
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32), q, pk, pv)


def _dot_attention_paged(q, kd, vd, lengths, *, scale=None):
    """Dense GQA attention with per-slot causal offsets — a verbatim twin
    of ``repro.models.attention.dot_attention(..., q_offset=lengths)``
    (same contractions, same mask constant, same dtype casts) so the ref
    path stays bitwise-identical to the gathered-view model path. Kept
    here rather than imported: kernels/ must not depend on models/."""
    B, Sq, H, hd = q.shape
    Hkv = kd.shape[2]
    g = H // Hkv
    scale = scale if scale is not None else hd ** -0.5
    qg = q.reshape(B, Sq, Hkv, g, hd)
    logits = jnp.einsum("bqhgk,bshk->bhgqs", qg.astype(jnp.float32),
                        kd.astype(jnp.float32)) * scale
    Sk = kd.shape[1]
    qoff = jnp.asarray(lengths)
    qpos = qoff[..., None] + jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    mask = qpos[..., :, None] >= kpos
    mask = mask[:, None, None] if mask.ndim == 3 else mask[None, None, None]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqs,bshk->bqhgk", probs.astype(vd.dtype), vd)
    return out.reshape(B, Sq, H, hd)


def paged_attention_ref(q, pk, pv, page_table, lengths):
    """jnp oracle in model layout — q: (B, S, H, hd); pk/pv page pools.

    This IS the gathered-view computation over however many table columns
    the caller passes: slicing the table to the live-page bucket is what
    makes it the fast CPU path, and because masked key slots contribute
    exactly-zero probability mass, truncating dead pages leaves the
    surviving logits (and the temperature-0 argmax) unchanged.
    """
    B = q.shape[0]
    n_pages, page_size = pk.shape[0], pk.shape[1]
    pk_flat = pk.reshape(n_pages * page_size, *pk.shape[2:])
    pv_flat = pv.reshape(n_pages * page_size, *pv.shape[2:])
    gather = (page_table[:, :, None] * page_size
              + jnp.arange(page_size)[None, None, :]).reshape(B, -1)
    return _dot_attention_paged(q, pk_flat[gather], pv_flat[gather], lengths)
