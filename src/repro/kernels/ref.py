"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (B, H, Sq, hd); k/v: (B, Hkv, Sk, hd) — dense softmax attention."""
    B, H, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Hkv, g, Sq, hd).astype(jnp.float32) / jnp.sqrt(hd)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32))
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, hd).astype(q.dtype)


def rmsnorm_ref(x, w, *, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(ms + eps) * w.astype(jnp.float32)
            ).astype(x.dtype)


def ssm_scan_ref(dt, x, A, B, C, D):
    """Serial reference recurrence for the chunked SSM kernel."""
    dt32 = dt.astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    B32, C32 = B.astype(jnp.float32), C.astype(jnp.float32)
    A32, D32 = A.astype(jnp.float32), D.astype(jnp.float32)
    Bb, S, di = x.shape
    ds = A.shape[1]

    def step(h, inp):
        dt_t, x_t, b_t, c_t = inp
        dA = jnp.exp(dt_t[:, :, None] * A32[None])
        h = dA * h + (dt_t * x_t)[:, :, None] * b_t[:, None, :]
        y = jnp.sum(h * c_t[:, None, :], axis=-1) + D32[None] * x_t
        return h, y

    h0 = jnp.zeros((Bb, di, ds), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (dt32.transpose(1, 0, 2),
                                    x32.transpose(1, 0, 2),
                                    B32.transpose(1, 0, 2),
                                    C32.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2).astype(x.dtype)
