"""Fused RMSNorm Pallas kernel (TPU target).

Pre-LN transformers evaluate LN twice per ODE step; fusing the reduction +
scale into one VMEM pass removes two HBM round-trips per call. Rows are
tiled (row_block x D) so a block fits VMEM with D up to 8k.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_2d(x, w, *, eps: float = 1e-6, row_block: int = 256,
               interpret=None):
    """x: (R, D) rows; w: (D,). ``interpret=None`` defers to the mode
    owner in :mod:`repro.kernels.ops` (interpret on CPU)."""
    if interpret is None:
        from repro.kernels import ops
        interpret = ops._interpret_default()
    R, D = x.shape
    row_block = min(row_block, R)
    assert R % row_block == 0
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(R // row_block,),
        in_specs=[
            pl.BlockSpec((row_block, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((row_block, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(x, w)
