"""Jit'd public wrappers for the Pallas kernels.

Every kernel entry point resolves its execution mode HERE — single owner,
no per-call-site flag to forget (the old per-kernel ``interpret: bool =
False`` defaults silently picked compiled Mosaic on CPU unless each
caller remembered to pass the flag; now an unspecified mode always asks
:func:`_interpret_default` / :func:`kernel_mode`).

Two tiers of dispatch:

* The training-side kernels (``flash_attention``/``rmsnorm``/``ssm_scan``)
  keep their boolean contract: compiled on TPU, interpret on CPU.
* The paged-decode kernels (``paged_attention``/``paged_ssm_update``/
  ``topk_topp_mask``) are three-way — mode "pallas" (compiled Mosaic, the
  TPU default), "interpret" (the same Pallas body executed per grid cell
  on CPU: the conformance-test contract, far too slow to serve with), or
  "ref" (a jnp implementation of identical math, the CPU default — XLA
  serves it fast, and the kernel files document that ref and kernel are
  oracle-checked against each other in ``tests/test_kernels_paged.py``).
  ``REPRO_KERNEL_MODE`` overrides the default for debugging.
"""
from __future__ import annotations

import functools
import os

import jax

from repro.kernels import flash_attention as fa
from repro.kernels import paged_attention as pa
from repro.kernels import paged_ssm as ps
from repro.kernels import rmsnorm as rn
from repro.kernels import sampling as sp
from repro.kernels import ssm_scan as ss

_MODES = ("pallas", "interpret", "ref")


def _interpret_default() -> bool:
    return jax.default_backend() == "cpu"


def kernel_mode(mode: str | None = None) -> str:
    """Resolve the paged-kernel execution mode (single owner).

    Explicit argument wins, then the ``REPRO_KERNEL_MODE`` env var, then
    the platform default: compiled Pallas on TPU, jnp ref on CPU.
    """
    if mode is None:
        mode = os.environ.get("REPRO_KERNEL_MODE") or (
            "ref" if _interpret_default() else "pallas")
    if mode not in _MODES:
        raise ValueError(f"kernel mode {mode!r} not in {_MODES}")
    return mode


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, interpret=None):
    """q: (B, S, H, hd); k/v: (B, S, Hkv, hd) — model layout."""
    interpret = _interpret_default() if interpret is None else interpret
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = fa.flash_attention_bhsd(qt, kt, vt, causal=causal,
                                interpret=interpret)
    return o.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rmsnorm(x, w, *, interpret=None):
    """x: (..., D) any leading dims."""
    interpret = _interpret_default() if interpret is None else interpret
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    y = rn.rmsnorm_2d(x2, w, interpret=interpret)
    return y.reshape(shape)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(dt, x, A, B, C, D, *, chunk: int = 64, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return ss.ssm_scan(dt, x, A, B, C, D, chunk=chunk, interpret=interpret)


# ---------------------------------------------------------------------------
# Paged-decode kernels (serving hot path)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("mode",))
def paged_attention(q, pk, pv, page_table, lengths, *, mode=None):
    """Paged flash-decode attention in model layout.

    q: (B, S, H, hd) new-token queries (post-rope); pk/pv: (n_pages,
    page_size, Hkv, hd) pools (new k/v already scattered in);
    page_table: (B, P) — pass the table sliced to the live page bucket,
    that slice is the fused path's speed lever; lengths: (B,). Returns
    (B, S, H, hd), bitwise-matching the gathered-view ``dot_attention``
    path at every unpadded position.
    """
    mode = kernel_mode(mode)
    if mode == "ref":
        return pa.paged_attention_ref(q, pk, pv, page_table, lengths)
    out = pa.paged_flash_attention_bhsd(
        q.transpose(0, 2, 1, 3), pk, pv, page_table, lengths,
        interpret=(mode == "interpret"))
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("order", "mode"))
def paged_ssm_update(dt, x, Bm, Cm, A, h_pool, read_page, live, phys_w,
                     t_w, n_new, *, order: str, mode=None):
    """Paged SSM recurrence + compact snapshot commit, rows layout.

    dt/x: (B, S, R); Bm/Cm: (B, S, ds); A: (R, ds); h_pool: (N, R, ds)
    float32. read_page/live/n_new: (B,); phys_w/t_w: (B, W) — the compact
    write plan from ``repro.models.ssm.compact_snapshot_steps``. ``order``
    selects the mamba1 ("dbx") vs mamba2 ("dxb") product grouping.
    Returns (y (B, S, R) float32, updated h_pool).
    """
    mode = kernel_mode(mode)
    if mode == "ref":
        return ps.paged_ssm_update_ref(dt, x, Bm, Cm, A, h_pool, read_page,
                                       live, phys_w, t_w, n_new, order=order)
    return ps.paged_ssm_update_pallas(dt, x, Bm, Cm, A, h_pool, read_page,
                                      live, phys_w, t_w, n_new, order=order,
                                      interpret=(mode == "interpret"))


@functools.partial(jax.jit, static_argnames=("mode",))
def topk_topp_mask(logits, top_ks, top_ps, *, mode=None):
    """Sort-free top-k/top-p masking: survivors keep their logits
    bit-unchanged, the rest drop to -1e30. logits: (B, V); top_ks: (B,)
    int32 (<= 0 disables); top_ps: (B,) float in (0, 1]."""
    mode = kernel_mode(mode)
    if mode == "ref":
        return sp.topk_topp_mask_ref(logits, top_ks, top_ps)
    return sp.topk_topp_mask_pallas(logits, top_ks, top_ps,
                                    interpret=(mode == "interpret"))
