"""Jit'd public wrappers for the Pallas kernels.

On this CPU container kernels execute in interpret mode (the Python body
runs per grid cell); on TPU they compile to Mosaic. The model layer calls
these through ``use_pallas=True`` configs.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import flash_attention as fa
from repro.kernels import rmsnorm as rn
from repro.kernels import ssm_scan as ss


def _interpret_default() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, interpret=None):
    """q: (B, S, H, hd); k/v: (B, S, Hkv, hd) — model layout."""
    interpret = _interpret_default() if interpret is None else interpret
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = fa.flash_attention_bhsd(qt, kt, vt, causal=causal,
                                interpret=interpret)
    return o.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rmsnorm(x, w, *, interpret=None):
    """x: (..., D) any leading dims."""
    interpret = _interpret_default() if interpret is None else interpret
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    y = rn.rmsnorm_2d(x2, w, interpret=interpret)
    return y.reshape(shape)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(dt, x, A, B, C, D, *, chunk: int = 64, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return ss.ssm_scan(dt, x, A, B, C, D, chunk=chunk, interpret=interpret)
