"""Fused flash-attention Pallas kernel (TPU target).

The dominant FLOP term of the ODE right-hand side F. TPU adaptation of the
GPU flash algorithm: the (q-block, k-block) tiling is mapped onto the
sequential last grid dimension with the online-softmax state (m, l, acc)
held in VMEM scratch across k-steps — the systolic MXU consumes
(q_block x head_dim) @ (head_dim x k_block) tiles with 128-aligned shapes.

GQA is handled in the index maps: query head h reads KV head h // group.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, q_block: int, k_block: int, n_k: int,
                  scale: float):
    i = pl.program_id(2)          # q block index
    j = pl.program_id(3)          # k block index (sequential, innermost)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (qb, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (kb, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (qb, kb)
    if causal:
        qpos = i * q_block + jax.lax.broadcasted_iota(jnp.int32,
                                                      (q_block, k_block), 0)
        kpos = j * k_block + jax.lax.broadcasted_iota(jnp.int32,
                                                      (q_block, k_block), 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    m_ref[...] = m_new
    v = v_ref[0, 0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == n_k - 1)
    def _finish():
        o_ref[0, 0, ...] = (acc_ref[...]
                            / jnp.maximum(l_ref[...][:, None], 1e-30)
                            ).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         q_block: int = 128, k_block: int = 128,
                         interpret=None):
    """q: (B, H, Sq, hd); k/v: (B, Hkv, Sk, hd). Returns (B, H, Sq, hd).

    ``interpret=None`` defers to the single mode owner in
    :mod:`repro.kernels.ops` (interpret on CPU, compiled on TPU) — an
    unqualified call can no longer hand XLA:CPU an unloweable Mosaic
    kernel just because the call site forgot the flag.
    """
    if interpret is None:
        from repro.kernels import ops
        interpret = ops._interpret_default()
    B, H, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = H // Hkv
    q_block = min(q_block, Sq)
    k_block = min(k_block, Sk)
    assert Sq % q_block == 0 and Sk % k_block == 0
    n_q, n_k = Sq // q_block, Sk // k_block
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(_flash_kernel, causal=causal,
                               q_block=q_block, k_block=k_block, n_k=n_k,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, q_block, hd),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, k_block, hd),
                         lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, k_block, hd),
                         lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),      # m
            pltpu.VMEM((q_block,), jnp.float32),      # l
            pltpu.VMEM((q_block, hd), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q, k, v)
