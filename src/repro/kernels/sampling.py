"""Sort-free top-k/top-p logit masking (fused sampling epilogue).

``repro.launch.steps.apply_top_k_top_p`` drives both filters off one full
descending argsort per row — an O(V log V) sort plus three gather/scatter
round-trips over the vocab axis, all to find two scalar thresholds. This
module computes those thresholds directly by binary search over the
*sortable-integer* encoding of float32:

    u = bitcast(x, uint32);  u ^= (0x80000000 | (0xFFFFFFFF if x < 0))

is strictly monotone in x for finite floats, so unsigned comparisons on
``u`` order logits without sorting. 32 fixed iterations then find

  * tau_k — the k-th largest logit (largest threshold keeping >= k values),
  * tau_p — the smallest logit whose strictly-greater survivor mass is
    still < p (the nucleus boundary; the argmax satisfies it vacuously),

and the row mask is just ``u >= max(tau_k, tau_p)``: O(V) streaming
passes, no sort, no scatter. Gumbel noise stays *outside* the kernel —
the sampler's key schedule (``fold_in(PRNGKey(seed), counter)``) is
request-reproducibility contract surface and must not change.

Tie semantics caveat (distinct logits are unaffected): threshold masking
keeps *every* logit tied with the k-th value, where the sort path keeps
only the ties that argsort happened to rank first. Equal logits do not
occur with real model outputs, matching the documented contract of
``apply_top_k_top_p``. The p-boundary comparison accumulates survivor
mass in vocab order rather than sorted order, so a row whose cumulative
mass hits p within one float ulp of the boundary could flip one
borderline token — deterministic for a given input, and temperature-0
slots never enter this path at all.

``topk_topp_mask_ref`` (vectorized jnp, no sort) is the oracle and the
CPU production path; ``topk_topp_mask_pallas`` runs one grid row per
batch slot for TPU/interpret.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_MASKED = -1e30
_SEARCH_BITS = 32


def _sortable_u32(x):
    """Monotone uint32 encoding of float32 (finite values)."""
    u = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    flip = jnp.where(u >> jnp.uint32(31) != 0,
                     jnp.uint32(0xFFFFFFFF), jnp.uint32(0x80000000))
    return u ^ flip


def _search_kth(u, k_eff):
    """Largest threshold tau with count(u >= tau) >= k_eff, per row.
    u: (B, V) uint32; k_eff: (B,) int32 in [1, V]."""
    B = u.shape[0]

    def step(_, lh):
        lo, hi = lh
        # ceil((hi-lo)/2) without the uint32 overflow of (hi-lo+1) at the
        # full 2^32 initial range
        span = hi - lo
        mid = lo + (span >> jnp.uint32(1)) + (span & jnp.uint32(1))
        cnt = jnp.sum((u >= mid[:, None]).astype(jnp.int32), axis=-1)
        ok = cnt >= k_eff
        return (jnp.where(ok, mid, lo),
                jnp.where(ok, hi, mid - jnp.uint32(1)))

    lo = jnp.zeros((B,), jnp.uint32)
    hi = jnp.full((B,), 0xFFFFFFFF, jnp.uint32)
    lo, _ = jax.lax.fori_loop(0, _SEARCH_BITS, step, (lo, hi))
    return lo


def _search_nucleus(u, e, p_z):
    """Smallest threshold tau with mass(u > tau) < p_z, per row.
    e: (B, V) unnormalized survivor weights; p_z: (B,) = p * sum(e)."""
    B = u.shape[0]

    def step(_, lh):
        lo, hi = lh
        mid = lo + ((hi - lo) >> jnp.uint32(1))
        mass = jnp.sum(jnp.where(u > mid[:, None], e, 0.0), axis=-1)
        ok = mass < p_z
        return (jnp.where(ok, lo, mid + jnp.uint32(1)),
                jnp.where(ok, mid, hi))

    lo = jnp.zeros((B,), jnp.uint32)
    hi = jnp.full((B,), 0xFFFFFFFF, jnp.uint32)
    _, hi = jax.lax.fori_loop(0, _SEARCH_BITS, step, (lo, hi))
    return hi


def _mask_rows(lf, top_ks, top_ps):
    """Shared mask math for ref and kernel paths. lf: (B, V) float32."""
    V = lf.shape[-1]
    u = _sortable_u32(lf)
    k_eff = jnp.clip(jnp.where(top_ks <= 0, V, top_ks), 1, V)
    tau_k = _search_kth(u, k_eff)
    keep_k = u >= tau_k[:, None]
    masked_k = jnp.where(keep_k, lf, _MASKED)
    m = jnp.max(masked_k, axis=-1, keepdims=True)
    e = jnp.exp(masked_k - m)                 # exact 0 for masked entries
    p_z = top_ps.astype(jnp.float32) * jnp.sum(e, axis=-1)
    tau_p = _search_nucleus(u, e, p_z)
    keep = keep_k & (u >= tau_p[:, None])
    return jnp.where(keep, lf, _MASKED)


def topk_topp_mask_ref(logits, top_ks, top_ps):
    """Mask all but each row's top-k/top-p survivors to ``_MASKED``.
    logits: (B, V); top_ks: (B,) int32 (<= 0 disables); top_ps: (B,)
    float in (0, 1]. Survivor logits pass through bit-unchanged."""
    return _mask_rows(logits.astype(jnp.float32), top_ks, top_ps)


def _sampling_kernel(ks_ref, ps_ref, x_ref, o_ref):
    b = pl.program_id(0)
    lf = x_ref[...].astype(jnp.float32)                        # (1, V)
    o_ref[...] = _mask_rows(lf, ks_ref[b][None], ps_ref[b][None])


def topk_topp_mask_pallas(logits, top_ks, top_ps, *,
                          interpret: bool = False):
    """Pallas twin of :func:`topk_topp_mask_ref`: one grid row per slot,
    the whole (1, V) logit row resident in VMEM, both threshold searches
    and the final mask fused into a single pass with no HBM sort."""
    B, V = logits.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, V), lambda b, ks, ps: (b, 0))],
        out_specs=pl.BlockSpec((1, V), lambda b, ks, ps: (b, 0)),
    )
    return pl.pallas_call(
        _sampling_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, V), jnp.float32),
        interpret=interpret,
    )(top_ks.astype(jnp.int32), top_ps.astype(jnp.float32),
      logits.astype(jnp.float32))
