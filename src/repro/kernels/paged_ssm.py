"""Paged SSM decode/chunked-prefill Pallas kernel (TPU target).

Serving keeps SSM state as snapshot *pages* (``repro.models.ssm``): page p
of a slot holds the recurrent state after exactly (p+1)*page_size tokens.
The gathered-view decode path re-runs a full ``lax.scan`` and then
scatters a snapshot for **every** (slot, table-column) pair — B*P pages of
pool traffic per layer per step, almost all of it rewriting scratch
page 0. This kernel walks the snapshot schedule in-kernel instead: grid
(B, W) with W = the at-most ``ceil((S + page_size - 1)/page_size)`` pages
a call of S tokens can finalize; window w of slot b advances the
recurrence from local step ``t_w[b, w-1]+1`` through ``t_w[b, w]`` with
the running state h carried in VMEM scratch, then writes h — which at the
end of window w *is* the snapshot after step ``t_w[b, w]`` — straight
into physical page ``phys_w[b, w]`` of the pool via an aliased,
scalar-prefetch-indexed output block. The initial state is read in-kernel
from ``read_page[b]`` the same way. ``kernels/ssm_scan.py`` is the serial
(non-paged) chunked reference for the recurrence itself.

Rows layout: both mamba versions are expressed as R independent rows over
a shared (B, ds) B/C stream — mamba1 maps rows to the di channels
(A: (di, ds), term order (dt⊙B)⊙x, ``order="dbx"``), mamba2 flattens
(heads, headdim) to rows with per-head dt/A tiled across headdim (term
order (dt⊙x)⊙B, ``order="dxb"``). The orders are NOT interchangeable —
float multiplication is not associative-bitwise, and the fused path must
reproduce the gathered scan's exact product order.

``paged_ssm_update_ref`` is the jnp oracle and the CPU production path
(mode="ref" in :mod:`repro.kernels.ops`): the same masked scan the
gathered path runs, plus the *compact* snapshot scatter (W pages per slot
instead of P). Pools may differ from the gathered path only at scratch
page 0, which is never read back as real state.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def max_write_pages(seq_len: int, page_size: int) -> int:
    """Most snapshot pages S consecutive tokens can finalize, over every
    possible start offset within a page: ceil((page_size-1 + S)/page_size)."""
    return (seq_len + page_size - 2) // page_size + 1


def _paged_ssm_kernel(rp_ref, pw_ref, tw_ref, nn_ref, lv_ref,
                      dt_ref, x_ref, b_ref, c_ref, a_ref, hin_ref,
                      y_ref, hout_ref, h_scr, *, order: str):
    b = pl.program_id(0)
    w = pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)
        h0 = hin_ref[0].astype(jnp.float32)
        h_scr[...] = jnp.where(lv_ref[b] > 0, h0, jnp.zeros_like(h0))

    # window w advances the recurrence through local steps (t0 .. t_w[b,w]];
    # trailing windows past the slot's last written page are empty ranges
    t0 = jnp.where(w == 0, 0, tw_ref[b, jnp.maximum(w - 1, 0)] + 1)
    t1 = tw_ref[b, w]
    A = a_ref[...]
    n_new = nn_ref[b]

    def body(t, h):
        act = t < n_new                      # idle slots: state frozen
        dt_t = dt_ref[0, t]                  # (R,)
        x_t = x_ref[0, t]                    # (R,)
        b_t = b_ref[0, t]                    # (ds,)
        c_t = c_ref[0, t]                    # (ds,)
        dA = jnp.exp(dt_t[:, None] * A)
        if order == "dbx":
            term = dt_t[:, None] * b_t[None, :] * x_t[:, None]
        else:
            term = (dt_t * x_t)[:, None] * b_t[None, :]
        h2 = dA * h + term
        h = jnp.where(act, h2, h)
        y_ref[0, t] = jnp.where(act, jnp.sum(h * c_t[None, :], axis=1),
                                y_ref[0, t])
        return h

    h = jax.lax.fori_loop(t0, t1 + 1, body, h_scr[...])
    h_scr[...] = h
    # end of window w == snapshot after step t_w[b, w]; unwritten windows
    # route to scratch page 0 (phys_w == 0), which is never read as state
    hout_ref[0] = h


def paged_ssm_update_pallas(dt, x, Bm, Cm, A, h_pool, read_page, live,
                            phys_w, t_w, n_new, *, order: str,
                            interpret: bool = False):
    """Rows-layout paged SSM update. dt/x: (B, S, R) f32; Bm/Cm: (B, S, ds)
    f32; A: (R, ds) f32; h_pool: (N, R, ds) f32. read_page/live/n_new: (B,);
    phys_w/t_w: (B, W) from the caller's compact snapshot plan. Returns
    (y (B, S, R) f32, new h_pool — the input buffer, donated/aliased).
    """
    assert order in ("dbx", "dxb"), order
    B, S, R = dt.shape
    ds = Bm.shape[-1]
    N = h_pool.shape[0]
    W = phys_w.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(B, W),
        in_specs=[
            pl.BlockSpec((1, S, R), lambda b, w, *_: (b, 0, 0)),     # dt
            pl.BlockSpec((1, S, R), lambda b, w, *_: (b, 0, 0)),     # x
            pl.BlockSpec((1, S, ds), lambda b, w, *_: (b, 0, 0)),    # Bm
            pl.BlockSpec((1, S, ds), lambda b, w, *_: (b, 0, 0)),    # Cm
            pl.BlockSpec((R, ds), lambda b, w, *_: (0, 0)),          # A
            pl.BlockSpec((1, R, ds),
                         lambda b, w, rp, pw, tw, nn, lv: (rp[b], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, R), lambda b, w, *_: (b, 0, 0)),     # y
            pl.BlockSpec((1, R, ds),
                         lambda b, w, rp, pw, tw, nn, lv: (pw[b, w], 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((R, ds), jnp.float32)],
    )
    y, new_pool = pl.pallas_call(
        functools.partial(_paged_ssm_kernel, order=order),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, S, R), jnp.float32),
                   jax.ShapeDtypeStruct((N, R, ds), h_pool.dtype)],
        # operand 10 = h_pool (after the 5 scalar-prefetch operands)
        input_output_aliases={10: 1},
        interpret=interpret,
    )(read_page.astype(jnp.int32), phys_w.astype(jnp.int32),
      t_w.astype(jnp.int32), n_new.astype(jnp.int32),
      live.astype(jnp.int32), dt, x, Bm, Cm, A, h_pool)
    return y, new_pool


def paged_ssm_update_ref(dt, x, Bm, Cm, A, h_pool, read_page, live,
                         phys_w, t_w, n_new, *, order: str):
    """jnp oracle / CPU production path, same contract as the kernel.

    The scan body is copied from ``repro.models.ssm.mamba{1,2}_paged_apply``
    operation-for-operation (including the ``order`` product grouping and
    the frozen-state ``where``) so fused ref-mode decode stays bitwise
    equal to the gathered-view path; only the commit differs — a compact
    (B, W) scatter instead of the (B, P) full-table one. Outputs at steps
    >= n_new[b] reproduce the gathered scan's values too (frozen-state
    readout), so even padded positions match bitwise.
    """
    assert order in ("dbx", "dxb"), order
    B, S, R = dt.shape
    h0 = h_pool[read_page]
    h0 = jnp.where(live[:, None, None], h0, jnp.zeros_like(h0))
    valid = jnp.arange(S)[None, :] < n_new[:, None]

    def step(h, inp):
        dt_t, x_t, b_t, c_t, v_t = inp
        dA = jnp.exp(dt_t[:, :, None] * A[None])
        if order == "dbx":
            term = dt_t[:, :, None] * b_t[:, None, :] * x_t[:, :, None]
        else:
            term = (dt_t * x_t)[:, :, None] * b_t[:, None, :]
        h2 = dA * h + term
        h = jnp.where(v_t[:, None, None], h2, h)
        y = jnp.einsum("brs,bs->br", h, c_t)
        return h, (h, y)

    xs = (dt.transpose(1, 0, 2), x.transpose(1, 0, 2),
          Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2), valid.T)
    _, (hs, ys) = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2)                                  # (B, S, R)
    hs_b = jnp.swapaxes(hs, 0, 1)                              # (B, S, R, ds)
    snaps = hs_b[jnp.arange(B)[:, None], t_w]                  # (B, W, R, ds)
    new_pool = h_pool.at[phys_w.reshape(-1)].set(
        snaps.reshape((-1,) + snaps.shape[2:]).astype(h_pool.dtype))
    return y, new_pool
