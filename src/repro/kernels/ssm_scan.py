"""Chunked selective-scan Pallas kernel (Mamba1 core; TPU target).

The GPU reference implementation relies on warp-level parallel prefix
scans; the TPU-native adaptation instead keeps the SSM state h (d_inner x
d_state) resident in VMEM scratch across *sequence chunks* (the sequential
last grid dimension), so the recurrence never round-trips HBM between
steps. Within a chunk, steps are a fori_loop over VMEM-resident tiles —
the same chunking idea MGRIT applies over depth, here applied over the
sequence ("time") dimension of the SSM.

  h_{t} = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t        (B outer d)
  y_t   = (h_t * C_t).sum(d_state) + D * x_t
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(dt_ref, x_ref, A_ref, B_ref, C_ref, D_ref, o_ref, h_ref, *,
                chunk: int, n_chunks: int):
    c = pl.program_id(1)        # chunk index (sequential)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = A_ref[...].astype(jnp.float32)                  # (di, ds)
    D = D_ref[...].astype(jnp.float32)                  # (di,)

    def step(t, h):
        dt_t = dt_ref[0, t, :].astype(jnp.float32)      # (di,)
        x_t = x_ref[0, t, :].astype(jnp.float32)        # (di,)
        b_t = B_ref[0, t, :].astype(jnp.float32)        # (ds,)
        c_t = C_ref[0, t, :].astype(jnp.float32)        # (ds,)
        dA = jnp.exp(dt_t[:, None] * A)                 # (di, ds)
        h = dA * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y = jnp.sum(h * c_t[None, :], axis=1) + D * x_t
        o_ref[0, t, :] = y.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h


def ssm_scan(dt, x, A, B, C, D, *, chunk: int = 64, interpret=None):
    """dt/x: (Bb, S, di); A: (di, ds); B/C: (Bb, S, ds); D: (di,).
    Returns y (Bb, S, di). ``interpret=None`` defers to the mode owner in
    :mod:`repro.kernels.ops` (interpret on CPU)."""
    if interpret is None:
        from repro.kernels import ops
        interpret = ops._interpret_default()
    Bb, S, di = x.shape
    ds = A.shape[1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk
    kernel = functools.partial(_ssm_kernel, chunk=chunk, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=(Bb, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, di), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, di), lambda b, c: (b, c, 0)),
            pl.BlockSpec((di, ds), lambda b, c: (0, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, c: (b, c, 0)),
            pl.BlockSpec((di,), lambda b, c: (0,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, di), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((Bb, S, di), x.dtype),
        scratch_shapes=[pltpu.VMEM((di, ds), jnp.float32)],
        interpret=interpret,
    )(dt, x, A, B, C, D)
