"""Roofline terms from a compiled dry-run artifact (TPU v5e targets).

  compute term    = HLO_FLOPs / (chips x peak_FLOPs)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes_per_chip / link_bw

cost_analysis() reports whole-program flops/bytes for the SPMD *per-device*
program in recent jax (flops already per-shard); we treat them as per-chip
and divide by per-chip peaks. collective bytes come from the HLO parser.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float          # per chip
    hlo_bytes: float          # per chip
    coll_bytes: float         # per chip
    model_flops: float        # 6*N*D (active) whole step, all chips
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0
    peak_fraction: float = 0.0
    coll_detail: Optional[Dict[str, int]] = None
    memory_per_chip: float = 0.0

    def finalize(self):
        self.t_compute = self.hlo_flops / PEAK_FLOPS
        self.t_memory = self.hlo_bytes / HBM_BW
        self.t_collective = self.coll_bytes / ICI_BW
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        self.useful_ratio = (self.model_flops / self.chips) / max(
            self.hlo_flops, 1.0)
        t_step = max(self.t_compute, self.t_memory, self.t_collective)
        self.peak_fraction = (self.model_flops / self.chips / max(t_step, 1e-30)
                              ) / PEAK_FLOPS
        return self

    def row(self):
        return (f"| {self.arch} | {self.shape} | {self.mesh} | "
                f"{self.t_compute*1e3:.2f} | {self.t_memory*1e3:.2f} | "
                f"{self.t_collective*1e3:.2f} | {self.bottleneck} | "
                f"{self.useful_ratio:.2f} | {self.peak_fraction*100:.1f}% |")

    def to_json(self):
        d = dataclasses.asdict(self)
        return json.dumps(d, indent=1, default=float)


def model_flops_train(rcfg, tokens_per_step: int) -> float:
    """6*N(active)*D for a train step (fwd+bwd); 2*N*D for inference."""
    n = rcfg.model.active_param_count()
    mult = 6.0 if rcfg.shape.kind == "train" else 2.0
    return mult * n * tokens_per_step


def from_compiled(arch, shape, mesh_name, chips, compiled, rcfg,
                  tokens_per_step):
    """Roofline terms from the compiled artifact.

    flops/bytes/collectives come from the trip-count-aware HLO analyzer
    (analysis/hlo_cost.py): XLA's cost_analysis() counts lax.scan bodies
    once (calibrated in EXPERIMENTS.md §Methodology), which would
    undercount every relaxation sweep / coarse solve / SSM recurrence.
    cost_analysis() values are kept in the record as `xla_*` for
    comparison."""
    from repro.analysis import hlo_cost
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    text = compiled.as_text()
    cost = hlo_cost.analyze(text)
    flops = float(cost.flops)
    # memory term uses the fused-bytes model (elementwise chains fuse into
    # producers on TPU); the unfused upper bound is recorded alongside
    nbytes = float(cost.fused_bytes)
    coll = dict(cost.coll_by_kind)
    coll["total"] = float(cost.coll_bytes)
    coll["unfused_bytes"] = float(cost.bytes)
    coll["xla_flops"] = float(ca.get("flops", 0.0))
    coll["xla_bytes"] = float(ca.get("bytes accessed", 0.0))
    for tag, (f, b) in cost.scopes.items():
        coll[f"scope_{tag}_flops"] = float(f)
        coll[f"scope_{tag}_fused_bytes"] = float(b)
    mem = compiled.memory_analysis()
    mem_bytes = 0.0
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes"):
        mem_bytes += float(getattr(mem, attr, 0.0) or 0.0)
    alias = float(getattr(mem, "alias_size_in_bytes", 0.0) or 0.0)
    mem_bytes -= alias
    r = Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=nbytes,
        coll_bytes=float(coll.get("total", 0)),
        model_flops=model_flops_train(rcfg, tokens_per_step),
        coll_detail=coll, memory_per_chip=mem_bytes)
    return r.finalize()


HEADER = ("| arch | shape | mesh | t_comp (ms) | t_mem (ms) | t_coll (ms) "
          "| bottleneck | useful | roofline frac |\n"
          "|---|---|---|---|---|---|---|---|---|")
