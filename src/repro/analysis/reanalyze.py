"""Recompute roofline records from cached dry-run HLO (no recompilation).

Usage:
  PYTHONPATH=src python -m repro.analysis.reanalyze \
      --hlo experiments/hlo --out experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

from repro.analysis import hlo_cost
from repro.analysis import roofline as rl
from repro.configs import registry


def reanalyze_one(hlo_path: str, out_dir: str):
    tag = os.path.basename(hlo_path)[: -len(".hlo.gz")]
    arch, shape, mesh_name = tag.split("__")
    rcfg = registry.get_config(arch, shape)
    chips = 512 if "2x16" in mesh_name else 256
    with gzip.open(hlo_path, "rt") as f:
        text = f.read()
    cost = hlo_cost.analyze(text)
    tokens = (rcfg.shape.global_batch * rcfg.shape.seq_len
              if rcfg.shape.kind != "decode" else rcfg.shape.global_batch)
    coll = dict(cost.coll_by_kind)
    coll["total"] = float(cost.coll_bytes)
    coll["unfused_bytes"] = float(cost.bytes)
    for t, (fl, b) in cost.scopes.items():
        coll[f"scope_{t}_flops"] = float(fl)
        coll[f"scope_{t}_fused_bytes"] = float(b)
    roof = rl.Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=float(cost.flops), hlo_bytes=float(cost.fused_bytes),
        coll_bytes=float(cost.coll_bytes),
        model_flops=rl.model_flops_train(rcfg, tokens),
        coll_detail=coll).finalize()
    rec_path = os.path.join(out_dir, tag.replace("pod16x16", "single")
                            .replace("pod2x16x16", "multi") + ".json")
    # merge into the existing record when present (keeps memory_analysis)
    rec = {}
    for cand in (rec_path,
                 os.path.join(out_dir, f"{arch}__{shape}__single.json"),
                 os.path.join(out_dir, f"{arch}__{shape}__multi.json")):
        if os.path.exists(cand):
            rec_path = cand
            with open(cand) as f:
                rec = json.load(f)
            break
    rec.update({"arch": arch, "shape": shape, "mesh": mesh_name,
                "status": "ok", "chips": chips,
                "roofline": json.loads(roof.to_json())})
    with open(rec_path, "w") as f:
        json.dump(rec, f, indent=1)
    return roof


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--hlo", default="experiments/hlo")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)
    print(rl.HEADER)
    for p in sorted(glob.glob(os.path.join(args.hlo, "*.hlo.gz"))):
        roof = reanalyze_one(p, args.out)
        print(roof.row())


if __name__ == "__main__":
    main()
