"""HLO text analysis: collective-communication byte accounting.

``cost_analysis()`` has no collective term, so we parse the optimized HLO
and sum operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute. Optimized HLO references operands by name
only, so we first build a symbol table (instruction -> shape bytes) from
the definitions, then resolve each collective's operands. Sizes are
*per-shard* (the HLO is the SPMD per-device program), which is exactly what
the per-chip roofline needs.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# definition:  %name = <shape or tuple> op(...)
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _result_bytes(rhs: str) -> int:
    """Total bytes of the result type at the start of the rhs (handles
    tuples '(f32[..], u32[..])')."""
    end = rhs.find(" ", rhs.find("]") + 1) if "[" in rhs else len(rhs)
    head = rhs[: max(end, 0)] or rhs
    # take every shape appearing before the op name token
    op_m = re.search(r"\)\s*([a-z][\w-]*)\(", rhs)
    head = rhs[: rhs.index("(", 0)] if "(" in rhs and rhs.startswith("(") \
        else head
    total = 0
    for m in _SHAPE_RE.finditer(head):
        total += _shape_bytes(m.group(1), m.group(2))
    return total


def _op_name(rhs: str) -> str:
    """The op called on this line: first identifier followed by '(' after
    the result type."""
    m = re.search(r"\]\S*\s+([a-z][\w\-]*)\(", rhs)
    if m:
        return m.group(1)
    m = re.search(r"^\([^=]*\)\s+([a-z][\w\-]*)\(", rhs)
    return m.group(1) if m else ""


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of operand bytes per collective kind, plus 'total'."""
    sizes: Dict[str, int] = {}
    lines = hlo_text.splitlines()
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        sizes[m.group(1)] = _result_bytes(m.group(2))

    out = defaultdict(int)
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        rhs = m.group(2)
        op = _op_name(rhs)
        base = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-") or op.startswith(c + "."):
                base = c
                break
        if base is None or op.endswith("-done"):
            continue
        paren = rhs.find(op + "(")
        args = rhs[paren + len(op) + 1:]
        depth = 1
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args = args[:i]
                    break
        nbytes = 0
        for om in _OPERAND_RE.finditer(args):
            nbytes += sizes.get(om.group(1), 0)
        out[base] += nbytes
        out["total"] += nbytes
    return dict(out)


def count_ops(hlo_text: str) -> Dict[str, int]:
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            op = _op_name(m.group(2))
            if op:
                counts[op] += 1
    return dict(counts)
