"""Baseline file: grandfathered findings that don't fail the build.

Format — one finding per line, comments and blanks ignored:

    RULE_ID  path  fingerprint    # why this is grandfathered

The fingerprint is ``sha1(stripped source line)[:12]``, not a line
number, so unrelated edits above a finding don't invalidate its
baseline entry; editing the flagged line itself does (on purpose —
touched code must come clean).  Entries whose finding disappeared are
reported as stale so the file shrinks over time instead of rotting.
"""
from __future__ import annotations

import hashlib
from typing import Iterable, List, Set, Tuple

from .core import Finding


def fingerprint(finding: Finding, source_lines: List[str]) -> str:
    try:
        text = source_lines[finding.line - 1].strip()
    except IndexError:
        text = ""
    return hashlib.sha1(text.encode()).hexdigest()[:12]


def entry_key(finding: Finding, source_lines: List[str]) -> Tuple[str, str,
                                                                  str]:
    path = finding.path.replace("\\", "/")
    return (finding.rule, path, fingerprint(finding, source_lines))


def load(path: str) -> Set[Tuple[str, str, str]]:
    out: Set[Tuple[str, str, str]] = set()
    with open(path, encoding="utf-8") as fh:
        for raw in fh:
            line = raw.split("#", 1)[0].strip()
            if not line:
                continue
            parts = line.split()
            if len(parts) >= 3:
                out.add((parts[0], parts[1].replace("\\", "/"), parts[2]))
    return out


def render(findings: Iterable[Tuple[Finding, List[str]]]) -> str:
    lines = ["# staticcheck baseline — RULE_ID path fingerprint  # reason",
             "# regenerate: python -m repro.analysis.staticcheck "
             "--write-baseline <paths>"]
    for finding, src in findings:
        rid, path, fp = entry_key(finding, src)
        lines.append(f"{rid}  {path}  {fp}  # {finding.message}")
    return "\n".join(lines) + "\n"
