"""Core of the repo-native static checker.

Stdlib-only (``ast`` + ``os``) so it runs in the jax-free CI lint job.
The pieces:

- ``Finding`` — one diagnostic, rendered ``file:line · RULE_ID · message
  · fix: hint``.
- ``rule(...)`` / ``RULES`` — the registry.  A rule is a generator over a
  ``Project`` yielding ``Finding``s.
- ``ModuleInfo`` — one parsed file with its import-alias maps and a
  parent map (ast has no uplinks).
- ``Project`` — the scanned file set plus the *jit-region resolver*: the
  set of functions reachable from ``jax.jit`` / ``pl.pallas_call`` /
  the lazily-jitted ``make_*`` factories (serve/cache.py, serve/spec.py,
  launch/steps.py), closed transitively over cross-module references.

Rules import nothing outside this package, so fixture tests can build a
``Project`` over a temp directory and assert exact findings.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Set, Tuple

# --------------------------------------------------------------------------
# findings + registry


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str          # as scanned (repo-relative when invoked from root)
    line: int
    rule: str
    message: str
    hint: str = ""

    def render(self) -> str:
        out = f"{self.path}:{self.line} · {self.rule} · {self.message}"
        if self.hint:
            out += f" · fix: {self.hint}"
        return out

    def sort_key(self) -> Tuple[str, int, str]:
        return (self.path, self.line, self.rule)


@dataclasses.dataclass(frozen=True)
class Rule:
    rule_id: str
    summary: str
    check: Callable[["Project"], Iterable[Finding]]


RULES: Dict[str, Rule] = {}


def rule(rule_id: str, summary: str):
    """Register ``fn`` as the checker for ``rule_id``."""
    def deco(fn):
        if rule_id in RULES:
            raise ValueError(f"duplicate rule id {rule_id}")
        RULES[rule_id] = Rule(rule_id, summary, fn)
        return fn
    return deco


# --------------------------------------------------------------------------
# per-module model


class ModuleInfo:
    def __init__(self, path: str, relpath: str, source: str):
        self.path = path
        self.relpath = relpath
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        # local alias -> dotted module ("jnp" -> "jax.numpy",
        # "steps_mod" -> "repro.launch.steps", and from-imports of
        # modules: "transformer" -> "repro.models.transformer")
        self.module_aliases: Dict[str, str] = {}
        # local name -> (module, original name) for `from m import n`
        self.from_imports: Dict[str, Tuple[str, str]] = {}
        # function name -> all defs with that name (any nesting depth)
        self.defs_by_name: Dict[str, List[ast.FunctionDef]] = {}
        # module-level defs only (cross-module resolution target)
        self.toplevel_funcs: Dict[str, ast.FunctionDef] = {}
        self.parents: Dict[ast.AST, ast.AST] = {}
        self.dotted = _dotted_name(relpath)
        self._index()

    def _index(self) -> None:
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parents[child] = node
            if isinstance(node, ast.Import):
                for alias in node.names:
                    local = alias.asname or alias.name.split(".")[0]
                    self.module_aliases[local] = (
                        alias.name if alias.asname else alias.name.split(".")[0])
                    if alias.asname:
                        self.module_aliases[alias.asname] = alias.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for alias in node.names:
                    local = alias.asname or alias.name
                    self.from_imports[local] = (node.module, alias.name)
                    # `from repro.models import transformer` also binds a
                    # module object; record both interpretations.
                    self.module_aliases.setdefault(
                        local, f"{node.module}.{alias.name}")
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs_by_name.setdefault(node.name, []).append(node)
        for node in self.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.toplevel_funcs[node.name] = node

    # -- expression helpers -------------------------------------------------

    def raw_chain(self, expr: ast.AST) -> Optional[str]:
        """Literal dotted text of a Name/Attribute chain, else None."""
        parts: List[str] = []
        while isinstance(expr, ast.Attribute):
            parts.append(expr.attr)
            expr = expr.value
        if isinstance(expr, ast.Name):
            parts.append(expr.id)
            return ".".join(reversed(parts))
        return None

    def resolved_chain(self, expr: ast.AST) -> Optional[str]:
        """Import-resolved dotted name ("jnp.any" -> "jax.numpy.any")."""
        raw = self.raw_chain(expr)
        if raw is None:
            return None
        root, _, rest = raw.partition(".")
        if root in self.module_aliases:
            base = self.module_aliases[root]
            return f"{base}.{rest}" if rest else base
        if root in self.from_imports and not rest:
            mod, orig = self.from_imports[root]
            return f"{mod}.{orig}"
        if root in self.from_imports and rest:
            mod, orig = self.from_imports[root]
            return f"{mod}.{orig}.{rest}"
        return raw

    def enclosing_stmt(self, node: ast.AST) -> Optional[ast.stmt]:
        while node is not None and not isinstance(node, ast.stmt):
            node = self.parents.get(node)
        return node

    def enclosing_function(self, node: ast.AST) -> Optional[ast.FunctionDef]:
        node = self.parents.get(node)
        while node is not None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return node
            node = self.parents.get(node)
        return None

    def loop_ancestor(self, node: ast.AST,
                      stop: ast.AST) -> Optional[ast.stmt]:
        """Innermost For/While between ``node`` and ``stop`` (exclusive)."""
        cur = self.parents.get(node)
        while cur is not None and cur is not stop:
            if isinstance(cur, (ast.For, ast.While, ast.AsyncFor)):
                return cur
            cur = self.parents.get(cur)
        return None


def _dotted_name(relpath: str) -> str:
    parts = relpath.replace("\\", "/").split("/")
    if parts[-1].endswith(".py"):
        parts[-1] = parts[-1][:-3]
    if parts[-1] == "__init__":
        parts = parts[:-1]
    if "repro" in parts:
        parts = parts[parts.index("repro"):]
    else:
        # fixture/temp trees: the stem is the import name
        parts = parts[-1:]
    return ".".join(parts) if parts else relpath


# --------------------------------------------------------------------------
# project + jit-region resolver

_JIT_WRAPPERS = {"jax.jit", "jax.pmap", "jax.vmap", "jax.grad",
                 "jax.value_and_grad", "jax.checkpoint", "jax.remat"}


class Project:
    def __init__(self, paths: Iterable[str],
                 known_axes: Optional[Set[str]] = None):
        self.known_axes = known_axes  # SH001 override for fixture tests
        self.modules: List[ModuleInfo] = []
        for path in paths:
            for fpath, rel in _collect(path):
                with open(fpath, encoding="utf-8") as fh:
                    src = fh.read()
                self.modules.append(ModuleInfo(fpath, rel, src))
        self.by_dotted: Dict[str, ModuleInfo] = {
            m.dotted: m for m in self.modules}
        # (module dotted, func name) -> (mod, node), module-level defs
        self.func_index: Dict[Tuple[str, str],
                              Tuple[ModuleInfo, ast.FunctionDef]] = {}
        for m in self.modules:
            for name, node in m.toplevel_funcs.items():
                self.func_index[(m.dotted, name)] = (m, node)
        self._jit: Dict[int, Tuple[ModuleInfo, ast.FunctionDef]] = {}
        self._resolve_jit_regions()

    # -- scanning helpers ---------------------------------------------------

    def iter_modules(self) -> Iterator[ModuleInfo]:
        return iter(self.modules)

    def find_module(self, suffix: str) -> Optional[ModuleInfo]:
        suffix = suffix.replace("\\", "/")
        for m in self.modules:
            if m.relpath.replace("\\", "/").endswith(suffix):
                return m
        return None

    def jit_functions(self) -> List[Tuple[ModuleInfo, ast.FunctionDef]]:
        return list(self._jit.values())

    def is_jit(self, node: ast.AST) -> bool:
        return id(node) in self._jit

    # -- cross-module function resolution ----------------------------------

    def resolve_func(self, mod: ModuleInfo, expr: ast.AST
                     ) -> List[Tuple[ModuleInfo, ast.FunctionDef]]:
        out: List[Tuple[ModuleInfo, ast.FunctionDef]] = []
        if isinstance(expr, ast.Name):
            for node in mod.defs_by_name.get(expr.id, ()):
                out.append((mod, node))
            if not out and expr.id in mod.from_imports:
                m, orig = mod.from_imports[expr.id]
                hit = self.func_index.get((_canon(m), orig))
                if hit:
                    out.append(hit)
        elif isinstance(expr, ast.Attribute) and isinstance(expr.value,
                                                            ast.Name):
            base = expr.value.id
            dotted = mod.module_aliases.get(base)
            if dotted:
                hit = self.func_index.get((_canon(dotted), expr.attr))
                if hit:
                    out.append(hit)
        return out

    # -- jit-region computation --------------------------------------------

    def _resolve_jit_regions(self) -> None:
        work: List[Tuple[ModuleInfo, ast.FunctionDef]] = []

        def mark(mod: ModuleInfo, fn: ast.AST) -> None:
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and id(fn) not in self._jit:
                self._jit[id(fn)] = (mod, fn)
                work.append((mod, fn))

        for mod in self.modules:
            for node in ast.walk(mod.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    # seed 1: decorated with jax.jit / partial(jax.jit, ...)
                    for dec in node.decorator_list:
                        if self._is_jit_expr(mod, dec):
                            mark(mod, node)
                    # seed 2: every inner def of a make_* factory — the
                    # repo convention for lazily-jitted step builders
                    # (launch/steps.py consumed by serve/cache.py,
                    # serve/spec.py).  Over-approximates: inner helpers
                    # are traced too when the returned fn calls them.
                    if node.name.startswith("make_"):
                        for sub in ast.walk(node):
                            if sub is not node and isinstance(
                                    sub, ast.FunctionDef):
                                mark(mod, sub)
                elif isinstance(node, ast.Call):
                    target = self._wrapped_fn_arg(mod, node)
                    if target is not None:
                        for tmod, tfn in self.resolve_func(mod, target):
                            mark(tmod, tfn)
        # transitive closure: anything a traced function references is
        # itself traced when called.
        while work:
            mod, fn = work.pop()
            for node in ast.walk(fn):
                if isinstance(node, (ast.Name, ast.Attribute)):
                    for tmod, tfn in self.resolve_func(mod, node):
                        mark(tmod, tfn)

    def _is_jit_expr(self, mod: ModuleInfo, expr: ast.AST) -> bool:
        """Is ``expr`` jax.jit or functools.partial(jax.jit, ...)?"""
        d = mod.resolved_chain(expr)
        if d in _JIT_WRAPPERS:
            return True
        if isinstance(expr, ast.Call):
            fd = mod.resolved_chain(expr.func)
            if fd in _JIT_WRAPPERS:
                return True
            if fd in ("functools.partial", "partial") and expr.args:
                return self._is_jit_expr(mod, expr.args[0])
        return False

    def _wrapped_fn_arg(self, mod: ModuleInfo,
                        call: ast.Call) -> Optional[ast.AST]:
        """First function-valued operand of a tracing wrapper call:
        jax.jit(f) / pl.pallas_call(kernel, ...) / functools.partial(f)."""
        d = mod.resolved_chain(call.func) or ""
        raw = mod.raw_chain(call.func) or ""
        if not call.args:
            return None
        arg0: ast.AST = call.args[0]
        if isinstance(arg0, ast.Call):
            fd = mod.resolved_chain(arg0.func)
            if fd in ("functools.partial", "partial") and arg0.args:
                arg0 = arg0.args[0]
        if d in _JIT_WRAPPERS:
            return arg0
        if raw.endswith("pallas_call") or d.endswith("pallas_call"):
            return arg0
        if d in ("functools.partial", "partial"):
            # partial(project_fn, ...) — the serve backends hand these
            # straight to jitted factories (cache.py _decode_fn).
            return arg0
        return None


def _canon(dotted: str) -> str:
    parts = dotted.split(".")
    if "repro" in parts:
        parts = parts[parts.index("repro"):]
    return ".".join(parts)


def _collect(path: str) -> Iterator[Tuple[str, str]]:
    """Yield (path-as-walked, same) — display paths stay exactly as the
    caller spelled the root, so baselines written from the repo root are
    stable ("src/repro/...")."""
    if os.path.isfile(path):
        yield path, path
        return
    for root, dirs, files in os.walk(path):
        dirs[:] = sorted(d for d in dirs
                         if d not in ("__pycache__", ".git"))
        for name in sorted(files):
            if name.endswith(".py"):
                full = os.path.join(root, name)
                yield full, full


def run_rules(project: Project,
              select: Optional[Set[str]] = None,
              ignore: Optional[Set[str]] = None) -> List[Finding]:
    findings: List[Finding] = []
    seen: Set[Tuple[str, int, str, str]] = set()
    for rid in sorted(RULES):
        if select and rid not in select:
            continue
        if ignore and rid in ignore:
            continue
        for f in RULES[rid].check(project):
            key = (f.path, f.line, f.rule, f.message)
            if key not in seen:
                seen.add(key)
                findings.append(f)
    findings.sort(key=Finding.sort_key)
    return findings
