"""PL001 — Pallas BlockSpec index maps must stay pure.

An index map runs at *trace* time, once per grid position, and must be
a pure function of its parameters: the grid indices plus (with
``PrefetchScalarGridSpec``) the scalar-prefetch refs threaded in front
of them.  Three things break that contract:

- calling anything (``jnp.floor_divide(h, g)`` materializes an op into
  the index computation — the lowering wants plain index arithmetic);
- subscripting a *captured* array (only prefetch-ref params may be
  indexed — a closed-over table silently bakes trace-time contents in);
- touching jnp/np/jax attributes at all.

Closure capture of plain scalars is explicitly allowed: the repo's GQA
maps (`kernels/paged_attention.py`) capture the static int ``g = H //
Hkv`` and index with ``h // g`` — that is idiomatic and must not flag.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional, Set

from .core import Finding, ModuleInfo, Project, rule

_MODULE_ROOTS = ("jax", "jax.numpy", "numpy")


def _index_map_expr(call: ast.Call) -> Optional[ast.AST]:
    """The index_map operand of a BlockSpec(...) call, if any."""
    for kw in call.keywords:
        if kw.arg == "index_map":
            return kw.value
    if len(call.args) >= 2:
        return call.args[1]
    return None


def _check_body(mod: ModuleInfo, body: ast.AST, params: Set[str],
                where: int) -> Iterator[Finding]:
    for node in ast.walk(body):
        if isinstance(node, ast.Call):
            yield Finding(
                mod.relpath, node.lineno, "PL001",
                "index_map calls/materializes an op — index maps must be "
                "plain arithmetic over grid indices and prefetch refs",
                "precompute outside the BlockSpec, or pass the value via "
                "scalar prefetch")
            return
        if isinstance(node, ast.Subscript):
            root = node.value
            while isinstance(root, ast.Attribute):
                root = root.value
            if isinstance(root, ast.Name) and root.id not in params:
                yield Finding(
                    mod.relpath, node.lineno, "PL001",
                    f"index_map subscripts closed-over `{root.id}` — only "
                    "grid indices and scalar-prefetch ref parameters may "
                    "be indexed",
                    "thread the table through PrefetchScalarGridSpec "
                    "scalar prefetch instead of the closure")
                return
        if isinstance(node, ast.Attribute):
            d = mod.resolved_chain(node)
            if d and any(d == r or d.startswith(r + ".")
                         for r in _MODULE_ROOTS):
                yield Finding(
                    mod.relpath, node.lineno, "PL001",
                    f"index_map references `{mod.raw_chain(node)}` — "
                    "module state inside an index map runs per grid "
                    "position at trace time",
                    "keep index maps to arithmetic over their parameters")
                return


@rule("PL001", "impure Pallas index_map")
def check_pl001(project: Project) -> Iterator[Finding]:
    for mod in project.iter_modules():
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            raw = mod.raw_chain(node.func) or ""
            if raw.rsplit(".", 1)[-1] != "BlockSpec":
                continue
            imap = _index_map_expr(node)
            if imap is None:
                continue
            if isinstance(imap, ast.Lambda):
                params = {a.arg for a in imap.args.args}
                yield from _check_body(mod, imap.body, params, imap.lineno)
            elif isinstance(imap, ast.Name):
                for dmod, dfn in project.resolve_func(mod, imap):
                    params = {a.arg for a in dfn.args.args}
                    for stmt in dfn.body:
                        yield from _check_body(dmod, stmt, params,
                                               dfn.lineno)
