"""DN001 — donated operands read after the donating call.

`jax.jit(..., donate_argnums=...)` and Pallas `input_output_aliases`
hand the operand's buffer to the callee; the caller's reference is
invalidated the moment dispatch happens.  Reading it afterwards is a
use-after-free that jax only sometimes catches (a copy on CPU hides
it; on TPU it is garbage).  The serve stack's convention — rebind the
donated name in the same assignment (`nxt, state = step_fn(p, state)`)
— is recognized and never flagged.

Tracked operand shapes: a bare name (`state`) or a dotted attribute
(`self.state`).  Anything else (subscripts, call results) is untracked.
Loops get the stricter treatment: a donating call inside a loop body
flags any non-rebound read of the operand anywhere in that body, since
iteration 2 reads what iteration 1 donated.
"""
from __future__ import annotations

import ast
from typing import Dict, FrozenSet, Iterator, List, Optional, Tuple

from .core import Finding, ModuleInfo, Project, rule


def _donation_registry(project: Project) -> Dict[str, FrozenSet[int]]:
    """Map callable tail-name -> donated positional indices, from
    `x = jax.jit(f, donate_argnums=...)` assignments and
    `@partial(jax.jit, donate_argnums=...)` decorated defs."""
    reg: Dict[str, FrozenSet[int]] = {}

    def positions(call: ast.Call) -> FrozenSet[int]:
        for kw in call.keywords:
            if kw.arg == "donate_argnums":
                vals = [s.value for s in ast.walk(kw.value)
                        if isinstance(s, ast.Constant)
                        and isinstance(s.value, int)]
                return frozenset(vals)
        return frozenset()

    def jit_call(mod: ModuleInfo, expr: ast.AST) -> Optional[ast.Call]:
        if not isinstance(expr, ast.Call):
            return None
        d = mod.resolved_chain(expr.func)
        if d == "jax.jit":
            return expr
        if d in ("functools.partial", "partial") and expr.args and \
                mod.resolved_chain(expr.args[0]) == "jax.jit":
            return expr
        return None

    for mod in project.iter_modules():
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign):
                call = jit_call(mod, node.value)
                if call is None:
                    continue
                pos = positions(call)
                if not pos:
                    continue
                for tgt in node.targets:
                    raw = mod.raw_chain(tgt)
                    if raw:
                        reg[raw.rsplit(".", 1)[-1]] = pos
            elif isinstance(node, ast.FunctionDef):
                for dec in node.decorator_list:
                    call = jit_call(mod, dec)
                    if call is not None:
                        pos = positions(call)
                        if pos:
                            reg[node.name] = pos
    return reg


def _operand_key(mod: ModuleInfo, expr: ast.AST) -> Optional[str]:
    raw = mod.raw_chain(expr)
    if raw and all(p.isidentifier() for p in raw.split(".")):
        return raw
    return None


def _loads_of(mod: ModuleInfo, scope: ast.AST, key: str,
              exclude: ast.AST) -> List[ast.AST]:
    skip = {id(n) for n in ast.walk(exclude)}
    out = []
    for node in ast.walk(scope):
        if id(node) in skip:
            continue
        if mod.raw_chain(node) == key and isinstance(
                getattr(node, "ctx", None), ast.Load):
            out.append(node)
    return sorted(out, key=lambda n: n.lineno)


def _rebinds(stmt: ast.stmt, key: str, mod: ModuleInfo) -> bool:
    targets: List[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    for t in targets:
        for sub in ast.walk(t):
            if mod.raw_chain(sub) == key:
                return True
    return False


def _stores_between(mod: ModuleInfo, fn: ast.AST, key: str,
                    lo: int, hi: int) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)) and \
                lo <= node.lineno <= hi and _rebinds(node, key, mod):
            return True
    return False


def _donated_call_sites(project: Project, mod: ModuleInfo
                        ) -> Iterator[Tuple[ast.Call, int, str]]:
    """(call node, donated position, callee label) pairs in ``mod``."""
    reg = project._dn_registry  # computed once per run in check_dn001
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        raw = mod.raw_chain(node.func)
        if raw is not None:
            tail = raw.rsplit(".", 1)[-1]
            for pos in reg.get(tail, ()):
                yield node, pos, tail
        # pl.pallas_call(..., input_output_aliases={i: j})(operands...)
        if isinstance(node.func, ast.Call):
            inner = node.func
            d = mod.resolved_chain(inner.func) or ""
            if d.endswith("pallas_call"):
                for kw in inner.keywords:
                    if kw.arg == "input_output_aliases" and isinstance(
                            kw.value, ast.Dict):
                        for k in kw.value.keys:
                            if isinstance(k, ast.Constant) and isinstance(
                                    k.value, int):
                                yield node, k.value, "pallas_call"


@rule("DN001", "donated operand read after the donating call")
def check_dn001(project: Project) -> Iterator[Finding]:
    project._dn_registry = _donation_registry(project)
    for mod in project.iter_modules():
        for call, pos, label in _donated_call_sites(project, mod):
            if pos >= len(call.args):
                continue
            key = _operand_key(mod, call.args[pos])
            if key is None:
                continue
            fn = mod.enclosing_function(call)
            if fn is None:
                continue
            stmt = mod.enclosing_stmt(call)
            if stmt is None:
                continue
            if _rebinds(stmt, key, mod):
                continue        # `nxt, state = step_fn(p, state)` idiom
            loop = mod.loop_ancestor(stmt, fn)
            if loop is not None:
                scope, lo = loop, loop.lineno
            else:
                scope, lo = fn, (stmt.end_lineno or stmt.lineno)
            for load in _loads_of(mod, scope, key, exclude=call):
                if loop is None and load.lineno <= lo:
                    continue
                if loop is None and _stores_between(
                        mod, fn, key, lo, load.lineno):
                    break       # rebound before the read: later loads fine
                yield Finding(
                    mod.relpath, load.lineno, "DN001",
                    f"`{key}` was donated to `{label}` (operand {pos}, "
                    f"line {call.lineno}) and is read afterwards — its "
                    "buffer belongs to the callee",
                    "rebind the result over the operand in the same "
                    "assignment, or pass a copy")
                break           # one finding per donated call site
