"""SH001 — logical axis names must exist in the sharding vocabulary.

The vocabulary is extracted from the tree being scanned (so it can
never drift from the code): the `ShardingConfig` string fields in
`configs/base.py` plus the alias keys of `resolve_axis`'s dict in
`parallel/sharding.py`.  Everything that names logical axes is then
checked against it: `logical_constraint` / `spec_for` /
`named_sharding` / `resolve_axis` call sites (string constants inside
any tuple/list argument — `pre + ("pages", None, "mlp")` is walked),
and the `_*_AXES` placement tables in `parallel/params.py` (dict
*values* only; the keys hold parameter names).

A typo'd axis doesn't crash — `resolve_axis` returns None and the
tensor silently replicates, which is exactly the kind of perf bug that
survives every correctness test.  Fixture projects can inject a
vocabulary via ``Project(known_axes=...)``.
"""
from __future__ import annotations

import ast
import re
from typing import Iterator, Optional, Set

from .core import Finding, ModuleInfo, Project, rule

_AXIS_CALLEES = ("logical_constraint", "spec_for", "named_sharding",
                 "resolve_axis", "tree_shardings")
_TABLE_RE = re.compile(r"^_[A-Z0-9_]*AXES$")


def _known_axes(project: Project) -> Optional[Set[str]]:
    if project.known_axes is not None:
        return set(project.known_axes)
    known: Set[str] = set()
    base = project.find_module("configs/base.py")
    if base is not None:
        for node in ast.walk(base.tree):
            if isinstance(node, ast.ClassDef) and \
                    node.name == "ShardingConfig":
                for stmt in node.body:
                    if isinstance(stmt, ast.AnnAssign) and \
                            isinstance(stmt.target, ast.Name) and \
                            "str" in ast.dump(stmt.annotation):
                        known.add(stmt.target.id)
    shard = project.find_module("parallel/sharding.py")
    if shard is not None:
        for node in ast.walk(shard.tree):
            if isinstance(node, ast.FunctionDef) and \
                    node.name == "resolve_axis":
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Dict):
                        for k in sub.keys:
                            if isinstance(k, ast.Constant) and \
                                    isinstance(k.value, str):
                                known.add(k.value)
    return known or None


def _tuple_strings(expr: ast.AST) -> Iterator[ast.Constant]:
    """String constants inside tuple/list displays anywhere in expr —
    catches `pre + ("pages", None, "mlp")` concatenations."""
    for node in ast.walk(expr):
        if isinstance(node, (ast.Tuple, ast.List)):
            for elt in node.elts:
                if isinstance(elt, ast.Constant) and \
                        isinstance(elt.value, str):
                    yield elt


@rule("SH001", "unknown logical sharding axis")
def check_sh001(project: Project) -> Iterator[Finding]:
    known = _known_axes(project)
    if known is None:
        return      # no vocabulary in this tree (fixture without one)
    hint = ("add the axis to ShardingConfig / the resolve_axis aliases, "
            "or fix the name — unknown axes silently replicate")
    for mod in project.iter_modules():
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                raw = mod.raw_chain(node.func) or ""
                if raw.rsplit(".", 1)[-1] not in _AXIS_CALLEES:
                    continue
                exprs = list(node.args) + [kw.value for kw in node.keywords]
                if raw.rsplit(".", 1)[-1] == "resolve_axis" and node.args \
                        and isinstance(node.args[0], ast.Constant) and \
                        isinstance(node.args[0].value, str) and \
                        node.args[0].value not in known:
                    yield Finding(
                        mod.relpath, node.lineno, "SH001",
                        f"logical axis `{node.args[0].value}` is not in "
                        "the sharding vocabulary", hint)
                for expr in exprs:
                    for const in _tuple_strings(expr):
                        if const.value not in known:
                            yield Finding(
                                mod.relpath, const.lineno, "SH001",
                                f"logical axis `{const.value}` is not in "
                                "the sharding vocabulary", hint)
            elif isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Dict) and any(
                        isinstance(t, ast.Name) and _TABLE_RE.match(t.id)
                        for t in node.targets):
                for val in node.value.values:
                    for const in _tuple_strings(val):
                        if const.value not in known:
                            yield Finding(
                                mod.relpath, const.lineno, "SH001",
                                f"logical axis `{const.value}` in a "
                                "placement table is not in the sharding "
                                "vocabulary", hint)
