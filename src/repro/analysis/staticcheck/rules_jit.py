"""RC001 (recompile hazards) and RC002 (host sync) inside jit regions.

Both rules only look *inside* the jit-region set computed by
``Project`` — host-side scheduler/engine code may branch on numpy
values freely; the hazard is doing it under trace, where a Python
branch bakes one arm into the compiled graph (silent wrong results or
a retrace per distinct value) and a host pull blocks the dispatch
pipeline every decode wave.
"""
from __future__ import annotations

import ast
from typing import Iterator

from .core import Finding, ModuleInfo, Project, rule

_TRACED_PREFIXES = ("jax.numpy.", "jax.lax.", "jax.nn.", "jax.scipy.")
_SYNC_METHODS = (".any", ".all", ".item")
_NP_PULLS = {"numpy.asarray", "numpy.array"}
_NP_REDUCTIONS = {"numpy.max", "numpy.min", "numpy.sum", "numpy.mean",
                  "numpy.argmax", "numpy.argmin", "numpy.any", "numpy.all"}


def _is_traced_call(mod: ModuleInfo, call: ast.Call) -> bool:
    d = mod.resolved_chain(call.func) or ""
    if d.startswith(_TRACED_PREFIXES):
        return True
    raw = mod.raw_chain(call.func) or ""
    return raw.endswith(_SYNC_METHODS)


def _looks_computed(mod: ModuleInfo, expr: ast.AST) -> bool:
    """Conservative "clearly a traced value": contains a jnp/jax call,
    a subscript, or arithmetic over one.  Plain names are NOT flagged —
    closure-captured static ints (page_size, n_heads) are idiomatic."""
    for sub in ast.walk(expr):
        if isinstance(sub, ast.Call) and _is_traced_call(mod, sub):
            return True
        if isinstance(sub, ast.Subscript):
            # x.shape[0] / x.strides[1] are static metadata, not tracers
            if isinstance(sub.value, ast.Attribute) and sub.value.attr in (
                    "shape", "strides", "dims"):
                continue
            return True
    return False


@rule("RC001", "recompile hazard inside a jit region")
def check_rc001(project: Project) -> Iterator[Finding]:
    for mod, fn in project.jit_functions():
        for node in ast.walk(fn):
            # (a) Python control flow on a traced value
            if isinstance(node, (ast.If, ast.While, ast.IfExp, ast.Assert)):
                test = node.test
                for sub in ast.walk(test):
                    if isinstance(sub, ast.Call) and _is_traced_call(mod, sub):
                        kind = type(node).__name__
                        yield Finding(
                            mod.relpath, sub.lineno, "RC001",
                            f"Python {kind} on a traced value inside jit "
                            f"region `{fn.name}` — concretizes the tracer "
                            "(TracerBoolConversionError or a retrace per "
                            "value)",
                            "use jax.lax.cond / jnp.where, or hoist the "
                            "decision to the host caller")
                        break
            # (b) container display materialized under trace
            if isinstance(node, ast.Call):
                d = mod.resolved_chain(node.func) or ""
                if d in ("jax.numpy.asarray", "jax.numpy.array") and node.args:
                    a0 = node.args[0]
                    if isinstance(a0, (ast.List, ast.Dict, ast.Set,
                                       ast.ListComp)):
                        yield Finding(
                            mod.relpath, node.lineno, "RC001",
                            f"jnp.{d.rsplit('.', 1)[1]} of a Python "
                            f"container inside jit region `{fn.name}` — "
                            "rebuilt (and re-hashed) every trace; tracer "
                            "elements silently devolve to concretization",
                            "hoist to a module-level np constant, or "
                            "jnp.stack for traced elements")
    yield from _static_arg_hazards(project)


def _static_arg_hazards(project: Project) -> Iterator[Finding]:
    """(c) unhashable values passed to declared static jit args.

    Collects `static_argnames` specs from jit-wrapped defs and
    `g = jax.jit(f, static_argnames=...)` assignments, then flags call
    sites handing a list/dict/set (or a call producing one) to a static
    parameter — jax hashes statics per call, so an unhashable raises
    and a fresh-per-call hashable (tuple rebuilt from a list) retraces.
    """
    static_names = {}   # callable name -> set of static kwarg names
    for mod in project.iter_modules():
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.FunctionDef):
                for dec in node.decorator_list:
                    names = _static_spec(mod, dec)
                    if names:
                        static_names.setdefault(node.name, set()).update(names)
            elif isinstance(node, ast.Assign) and isinstance(node.value,
                                                             ast.Call):
                names = _static_spec(mod, node.value)
                if not names:
                    continue
                for tgt in node.targets:
                    raw = mod.raw_chain(tgt)
                    if raw:
                        static_names.setdefault(
                            raw.rsplit(".", 1)[-1], set()).update(names)
    if not static_names:
        return
    for mod in project.iter_modules():
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            raw = mod.raw_chain(node.func) or ""
            tail = raw.rsplit(".", 1)[-1]
            spec = static_names.get(tail)
            if not spec:
                continue
            for kw in node.keywords:
                if kw.arg in spec and _unhashable(mod, kw.value):
                    yield Finding(
                        mod.relpath, node.lineno, "RC001",
                        f"unhashable value for static arg `{kw.arg}` of "
                        f"jitted `{tail}`",
                        "pass a tuple/str/int — statics are hashed into "
                        "the compilation-cache key")


def _static_spec(mod: ModuleInfo, expr: ast.AST):
    """static_argnames declared by a jax.jit(...) / partial(jax.jit, ...)
    expression, as a set of strings (argnums handled by name lookup at
    the def, so only names are collected)."""
    if not isinstance(expr, ast.Call):
        return set()
    d = mod.resolved_chain(expr.func)
    if d in ("functools.partial", "partial") and expr.args and \
            (mod.resolved_chain(expr.args[0]) == "jax.jit"):
        call = expr
    elif d == "jax.jit":
        call = expr
    else:
        return set()
    out = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.Constant) and isinstance(sub.value,
                                                                str):
                    out.add(sub.value)
    return out


def _unhashable(mod: ModuleInfo, expr: ast.AST) -> bool:
    if isinstance(expr, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return True
    if isinstance(expr, ast.Call):
        d = mod.resolved_chain(expr.func) or ""
        if d in ("list", "dict", "set", "numpy.array", "numpy.asarray",
                 "jax.numpy.array", "jax.numpy.asarray"):
            return True
    return False


@rule("RC002", "host sync inside a jit region")
def check_rc002(project: Project) -> Iterator[Finding]:
    for mod, fn in project.jit_functions():
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            d = mod.resolved_chain(node.func) or ""
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "item" and not node.args:
                yield Finding(
                    mod.relpath, node.lineno, "RC002",
                    f".item() inside jit region `{fn.name}` — forces a "
                    "device→host sync under trace",
                    "keep the value on-device; pull it after the jitted "
                    "call returns")
            elif d in _NP_PULLS and node.args and not isinstance(
                    node.args[0], (ast.Constant, ast.List, ast.Tuple)):
                yield Finding(
                    mod.relpath, node.lineno, "RC002",
                    f"np.{d.rsplit('.', 1)[1]} on a traced value inside "
                    f"jit region `{fn.name}` — concretizes (host pull or "
                    "TracerArrayConversionError)",
                    "use jnp.asarray, or move the conversion host-side")
            elif d in _NP_REDUCTIONS and node.args and _looks_computed(
                    mod, node.args[0]):
                yield Finding(
                    mod.relpath, node.lineno, "RC002",
                    f"numpy reduction `{d}` over a traced value inside "
                    f"jit region `{fn.name}`",
                    f"use jnp.{d.rsplit('.', 1)[1]}")
            elif isinstance(node.func, ast.Name) and \
                    node.func.id in ("int", "float", "bool") and \
                    node.args and _looks_computed(mod, node.args[0]):
                yield Finding(
                    mod.relpath, node.lineno, "RC002",
                    f"{node.func.id}() of a computed value inside jit "
                    f"region `{fn.name}` — concretizes the tracer",
                    "keep it as a jnp scalar; cast host-side after the "
                    "call")
