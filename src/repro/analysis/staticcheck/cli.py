"""CLI: ``python -m repro.analysis.staticcheck [paths] [options]``.

Exit codes: 0 clean (or everything baselined), 1 unbaselined findings,
2 usage error.  ``--github-summary FILE`` appends a markdown findings
table (the CI lint job points it at ``$GITHUB_STEP_SUMMARY``).
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

from . import baseline as baseline_mod
from .core import RULES, Finding, Project, run_rules

DEFAULT_BASELINE = "staticcheck-baseline.txt"


def _split_ids(value: Optional[str]) -> Optional[set]:
    if not value:
        return None
    ids = {v.strip() for v in value.replace(",", " ").split() if v.strip()}
    unknown = ids - set(RULES)
    if unknown:
        raise SystemExit(
            f"staticcheck: unknown rule id(s): {', '.join(sorted(unknown))} "
            f"(have: {', '.join(sorted(RULES))})")
    return ids


def _github_table(findings: List[Finding], n_baselined: int) -> str:
    lines = ["## staticcheck", ""]
    if not findings:
        lines.append(f"No findings ({n_baselined} baselined). "
                     f"{len(RULES)} rules active.")
    else:
        lines += ["| location | rule | message | fix |",
                  "|---|---|---|---|"]
        for f in findings:
            msg = f.message.replace("|", "\\|")
            hint = f.hint.replace("|", "\\|")
            lines.append(f"| `{f.path}:{f.line}` | {f.rule} | {msg} "
                         f"| {hint} |")
        lines.append("")
        lines.append(f"**{len(findings)} finding(s)** "
                     f"({n_baselined} baselined).")
    return "\n".join(lines) + "\n"


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.staticcheck",
        description="repo-native AST checker for jit/Pallas/refcount/"
                    "sharding contracts")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories to scan (default: src/repro)")
    ap.add_argument("--select", help="comma-separated rule ids to run")
    ap.add_argument("--ignore", help="comma-separated rule ids to skip")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline file (default: ./{DEFAULT_BASELINE} "
                         "when present)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write all current findings to the baseline file "
                         "and exit 0")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--github-summary", metavar="FILE",
                    help="append a markdown findings table to FILE")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid in sorted(RULES):
            print(f"{rid}  {RULES[rid].summary}")
        return 0

    paths = args.paths or ["src/repro"]
    for p in paths:
        if not os.path.exists(p):
            print(f"staticcheck: no such path: {p}", file=sys.stderr)
            return 2

    try:
        select = _split_ids(args.select)
        ignore = _split_ids(args.ignore)
    except SystemExit as exc:
        print(exc, file=sys.stderr)
        return 2

    project = Project(paths)
    findings = run_rules(project, select=select, ignore=ignore)
    src_lines = {m.relpath: m.lines for m in project.iter_modules()}

    baseline_path = args.baseline or (
        DEFAULT_BASELINE if os.path.exists(DEFAULT_BASELINE) else None)

    if args.write_baseline:
        out = args.baseline or DEFAULT_BASELINE
        pairs = [(f, src_lines.get(f.path, [])) for f in findings]
        with open(out, "w", encoding="utf-8") as fh:
            fh.write(baseline_mod.render(pairs))
        print(f"staticcheck: wrote {len(findings)} entries to {out}")
        return 0

    known = set()
    if baseline_path:
        known = baseline_mod.load(baseline_path)

    fresh: List[Finding] = []
    n_baselined = 0
    seen_keys = set()
    for f in findings:
        key = baseline_mod.entry_key(f, src_lines.get(f.path, []))
        seen_keys.add(key)
        if key in known:
            n_baselined += 1
        else:
            fresh.append(f)

    for f in fresh:
        print(f.render())
    stale = known - seen_keys
    for rid, path, fp in sorted(stale):
        print(f"staticcheck: stale baseline entry {rid} {path} {fp} — "
              "finding no longer present, remove it", file=sys.stderr)

    if args.github_summary:
        with open(args.github_summary, "a", encoding="utf-8") as fh:
            fh.write(_github_table(fresh, n_baselined))

    n_rules = len(select) if select else len(RULES) - len(ignore or ())
    status = "clean" if not fresh else f"{len(fresh)} finding(s)"
    print(f"staticcheck: {status} — {n_rules} rules over "
          f"{len(project.modules)} files ({n_baselined} baselined)")
    return 1 if fresh else 0
