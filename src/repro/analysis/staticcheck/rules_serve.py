"""AS001 — bare `assert` guarding a serve-layer invariant.

`python -O` strips asserts.  In the serve layer these statements guard
allocator refcounts, page-size agreement, and speculative-row shapes —
invariants whose violation must fail loudly in production, not only in
dev runs.  PR 7 set the precedent with `COWViolationError`; this rule
enumerates what is left so the fix (a typed raise) can't regress.

Scope: any module with a `serve` path component.  Kernel-layer asserts
(mode/order dispatch in `kernels/`) stay out of scope: they run at
trace time on static values and an -O production build that somehow
passes a bad static arg fails in lowering anyway.
"""
from __future__ import annotations

import ast
from typing import Iterator

from .core import Finding, Project, rule


@rule("AS001", "bare assert in the serve layer")
def check_as001(project: Project) -> Iterator[Finding]:
    for mod in project.iter_modules():
        parts = mod.relpath.replace("\\", "/").split("/")
        if "serve" not in parts:
            continue
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assert):
                cond = ast.unparse(node.test) if hasattr(ast, "unparse") \
                    else "<condition>"
                yield Finding(
                    mod.relpath, node.lineno, "AS001",
                    f"bare `assert {cond}` is stripped under python -O — "
                    "a serve-layer invariant must survive production "
                    "builds",
                    "raise a typed error (see COWViolationError in "
                    "scheduler.py) instead of assert")
