"""repro.analysis.staticcheck — repo-native AST linter.

Stdlib-only.  Importing the package registers every rule module; the
registry (``RULES``) is the single source of truth for rule ids — the
doc-lint test (tests/test_docs.py) checks docs/static-analysis.md
against it.

Usage::

    PYTHONPATH=src python -m repro.analysis.staticcheck src/repro
"""
from .core import RULES, Finding, Project, Rule, rule, run_rules
from . import (rules_donate, rules_jit, rules_pages,  # noqa: F401
               rules_pallas, rules_serve, rules_sharding)

__all__ = ["RULES", "Finding", "Project", "Rule", "rule", "run_rules"]
