"""PG001 — allocator pages acquired but not released on some path.

Scope: scheduler.py / engine.py module basenames (the two files that
own page lifetimes; kv_pages.py *is* the allocator and engine probes
run under it).  The model, per function body:

- acquire: `x = <anything>.alloc(...)` / `x = <anything>.alloc_view(...)`
  binds fresh refcounts to `x`; `<anything>.share(x)` bumps refcounts
  on pages already bound to `x`; `state, x = <anything>.fork_partial(...)`
  (the CacheBackend partial-page COW fork returns `(state, dst_page)`)
  binds the freshly copied page to the *last* Name in the tuple target.
- a `return` statement reachable after the acquire must satisfy one of:
  the returned expression mentions `x` (ownership handed to the
  caller); a release/free call naming `x` happened first; `x` escaped
  (passed to any call, stored into an attribute/subscript, or aliased
  into another binding — someone else now owns it); or the return sits
  under an `x is None` / `not x` guard (the allocation *failed*, there
  is nothing to release).
- a function that falls off the end without any of the above leaks too.

Line-interval approximation: "happened first" means a smaller line
number within the same binding's live range — branches that release on
a sibling path can mask a leak on this one, which keeps the rule quiet
enough to gate CI.  The runtime refcount fuzz suite covers the rest.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Tuple

from .core import Finding, ModuleInfo, Project, rule

_SCOPE_BASENAMES = ("scheduler.py", "engine.py")
_ACQUIRE = ("alloc", "alloc_view")
_ACQUIRE_TUPLE = ("fork_partial",)   # returns (state, page): bind the page
_RELEASE = ("release", "free")


def _call_tail(mod: ModuleInfo, call: ast.Call) -> str:
    raw = mod.raw_chain(call.func) or ""
    return raw.rsplit(".", 1)[-1]


def _mentions(node: ast.AST, name: str) -> bool:
    return any(isinstance(s, ast.Name) and s.id == name
               for s in ast.walk(node))


def _none_guarded(mod: ModuleInfo, stmt: ast.stmt, fn: ast.AST,
                  name: str) -> bool:
    """Is ``stmt`` under an `if <name> is None` / `if not <name>` arm?"""
    cur = mod.parents.get(stmt)
    while cur is not None and cur is not fn:
        if isinstance(cur, ast.If):
            t = cur.test
            if isinstance(t, ast.Compare) and _mentions(t, name) and any(
                    isinstance(c, ast.Constant) and c.value is None
                    for c in t.comparators):
                return True
            if isinstance(t, ast.UnaryOp) and isinstance(t.op, ast.Not) \
                    and _mentions(t.operand, name):
                return True
            if isinstance(t, ast.BoolOp) and any(
                    isinstance(v, ast.Compare) and _mentions(v, name) and any(
                        isinstance(c, ast.Constant) and c.value is None
                        for c in v.comparators)
                    for v in t.values):
                return True
        cur = mod.parents.get(cur)
    return False


def _acquisitions(mod: ModuleInfo, fn: ast.FunctionDef
                  ) -> List[Tuple[str, int]]:
    out = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            tail = _call_tail(mod, node.value)
            if tail in _ACQUIRE and len(node.targets) == 1 and \
                    isinstance(node.targets[0], ast.Name):
                out.append((node.targets[0].id, node.lineno))
            elif tail in _ACQUIRE_TUPLE and len(node.targets) == 1 and \
                    isinstance(node.targets[0], (ast.Tuple, ast.List)):
                # `self.state, dst = backend.fork_partial(...)`: the new
                # page rides in the last element of the tuple target
                last = node.targets[0].elts[-1] if node.targets[0].elts \
                    else None
                if isinstance(last, ast.Name):
                    out.append((last.id, node.lineno))
        elif isinstance(node, ast.Expr) and isinstance(node.value, ast.Call):
            call = node.value
            if _call_tail(mod, call) == "share" and call.args and \
                    isinstance(call.args[0], ast.Name):
                out.append((call.args[0].id, node.lineno))
    return out


def _live_range(mod: ModuleInfo, fn: ast.FunctionDef, name: str,
                bind_line: int) -> Tuple[int, int]:
    """[bind, next re-acquire or fn end] — each binding checked alone."""
    hi = fn.end_lineno or bind_line
    for other, line in _acquisitions(mod, fn):
        if other == name and bind_line < line <= hi:
            hi = line - 1
    return bind_line, hi


def _handled_before(mod: ModuleInfo, fn: ast.FunctionDef, name: str,
                    lo: int, hi: int) -> bool:
    """Did `name` get released or escape within [lo, hi]?"""
    for node in ast.walk(fn):
        line = getattr(node, "lineno", None)
        if line is None or not lo <= line <= hi:
            continue
        if isinstance(node, ast.Call):
            tail = _call_tail(mod, node)
            if tail in _ACQUIRE or tail in _ACQUIRE_TUPLE or \
                    tail == "share":
                continue    # the acquire itself is not an escape
            if any(_mentions(a, name) for a in node.args) or any(
                    _mentions(kw.value, name) for kw in node.keywords):
                return True     # released, or escaped into a callee
        elif isinstance(node, ast.Assign):
            if _mentions(node.value, name):
                for tgt in node.targets:
                    if isinstance(tgt, (ast.Attribute, ast.Subscript)):
                        return True     # stored: owner is elsewhere now
                    if isinstance(tgt, ast.Name) and tgt.id != name:
                        return True     # aliased into another binding
            for tgt in node.targets:
                if isinstance(tgt, (ast.Tuple, ast.List)) and \
                        _mentions(node.value, name):
                    return True
    return False


@rule("PG001", "allocated pages leak on some path")
def check_pg001(project: Project) -> Iterator[Finding]:
    for mod in project.iter_modules():
        base = mod.relpath.replace("\\", "/").rsplit("/", 1)[-1]
        if base not in _SCOPE_BASENAMES:
            continue
        for fn in (n for n in ast.walk(mod.tree)
                   if isinstance(n, ast.FunctionDef)):
            returns = [n for n in ast.walk(fn) if isinstance(n, ast.Return)]
            for name, bind_line in _acquisitions(mod, fn):
                lo, hi = _live_range(mod, fn, name, bind_line)
                exits: List[Tuple[int, Optional[ast.Return]]] = [
                    (r.lineno, r) for r in returns if lo < r.lineno <= hi]
                body_ends_in_return = bool(fn.body) and isinstance(
                    fn.body[-1], ast.Return)
                if not body_ends_in_return:
                    exits.append((hi, None))    # implicit `return None`
                for line, ret in exits:
                    if ret is not None and ret.value is not None and \
                            _mentions(ret.value, name):
                        continue    # ownership returned to the caller
                    if ret is not None and _none_guarded(mod, ret, fn, name):
                        continue    # allocation-failed bail-out
                    if _handled_before(mod, fn, name, lo, line):
                        continue
                    where = "falls off the end" if ret is None else \
                        f"returns at line {line}"
                    yield Finding(
                        mod.relpath, bind_line, "PG001",
                        f"pages bound to `{name}` (line {bind_line}) are "
                        f"never released: `{fn.name}` {where} without "
                        "release/free, return, or handoff",
                        "release on every early exit, or return the pages "
                        "so the caller owns them")
                    break           # one finding per acquisition
