"""Trip-count-aware HLO cost analyzer.

XLA's ``cost_analysis()`` counts while-loop (lax.scan) bodies ONCE,
regardless of trip count — verified by calibration (see EXPERIMENTS.md
§Roofline methodology). A layer-parallel program is built out of scans
(relaxation sweeps, the serial coarse solve, buffer layers, SSM recurrences,
chunked attention), so we re-derive costs from the optimized HLO text with
loop bodies multiplied by their trip counts:

  * flops: dot_general from parsed dimension numbers (2*M*N*K),
    elementwise/reduce/transcendental ops at 1 flop/element;
  * bytes: sum of operand + result bytes per instruction (an upper bound —
    the O0 module is unfused; fused TPU code re-reads much less);
  * collective bytes: operand bytes of collective ops, trip-multiplied.

Computation graph: fusion -> calls=..., while -> body/condition,
call -> to_apply. While trip counts are recovered from the loop condition's
`compare(iv, constant)` pattern (scan lowering); unknown loops count once.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"n"\s*:\s*"?([0-9]+)')

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "tanh", "log", "rsqrt", "sqrt", "negate", "abs",
    "compare", "select", "and", "or", "xor", "clamp", "floor", "ceil",
    "sign", "cosine", "sine", "logistic", "remainder", "atan2",
    "exponential-minus-one", "log-plus-one", "round-nearest-afz",
    "round-nearest-even", "cbrt", "erf",
}


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    result_shapes: List[Tuple[str, Tuple[int, ...]]]
    operands: List[str]
    attrs: str
    line: str


# named_scope tags attributed in per-scope accounting (jax.named_scope in
# the model code shows up in instruction metadata op_name paths)
SCOPE_TAGS = ("attn_core", "mlp_core", "moe_core", "ssm_core")


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0        # unfused upper bound: all operand+result bytes
    fused_bytes: float = 0.0  # elementwise ops assumed fused into producers
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    # per named_scope: {tag: [flops, fused_bytes]}
    scopes: Dict[str, List[float]] = dataclasses.field(
        default_factory=lambda: defaultdict(lambda: [0.0, 0.0]))

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.fused_bytes += other.fused_bytes
        self.coll_bytes += other.coll_bytes
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] += v
        for k, (f, b) in other.scopes.items():
            self.scopes[k][0] += f
            self.scopes[k][1] += b
        return self

    def scaled(self, k: float) -> "Cost":
        c = Cost(self.flops * k, self.bytes * k, self.fused_bytes * k,
                 self.coll_bytes * k)
        for kk, v in self.coll_by_kind.items():
            c.coll_by_kind[kk] = v * k
        for kk, (f, b) in self.scopes.items():
            c.scopes[kk] = [f * k, b * k]
        return c

    def add_scoped(self, line: str, flops: float, fused: float):
        for tag in SCOPE_TAGS:
            if tag in line:
                self.scopes[tag][0] += flops
                self.scopes[tag][1] += fused
                return


def _shapes_of(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = tuple(int(d) for d in m.group(2).split(",")) \
            if m.group(2) else ()
        out.append((m.group(1), dims))
    return out


def _nbytes(shapes) -> float:
    total = 0.0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _nelems(shapes) -> float:
    total = 0.0
    for _, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self._parse(text)
        self._trip_cache: Dict[str, float] = {}
        self._cost_cache: Dict[str, Cost] = {}

    # -- parsing --
    def _parse(self, text: str):
        cur: Optional[str] = None
        self.entry = None
        for raw in text.splitlines():
            line = re.sub(r"/\*.*?\*/", "", raw.rstrip())
            s = line.strip()
            # computation header: "%name (args) -> result {" — instruction
            # lines contain "name = op(...)" and never end with "{"
            if (s.endswith("{") and "->" in s
                    and (s.startswith("%") or s.startswith("ENTRY"))
                    and "=" not in s.split("->")[0]):
                hdr = _COMP_HDR.match(s)
                if hdr:
                    cur = hdr.group(1)
                    self.computations[cur] = []
                    if s.startswith("ENTRY"):
                        self.entry = cur
                    continue
            if line.strip() == "}":
                continue
            if cur is None:
                continue
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            op, args, attrs = self._split_rhs(rhs)
            if op is None:
                continue
            self.computations[cur].append(Instr(
                name=name, op=op,
                result_shapes=_shapes_of(rhs[:rhs.find(op + "(")]
                                         if op + "(" in rhs else rhs),
                operands=_OPERAND_RE.findall(args),
                attrs=attrs, line=line))

    @staticmethod
    def _split_rhs(rhs: str):
        m = re.search(r"\s([a-z][\w\-]*)\(", rhs)
        if not m:
            return None, "", ""
        op = m.group(1)
        start = m.end()
        depth = 1
        i = start
        while i < len(rhs) and depth:
            if rhs[i] == "(":
                depth += 1
            elif rhs[i] == ")":
                depth -= 1
            i += 1
        return op, rhs[start:i - 1], rhs[i:]

    # -- trip counts --
    def trip_count(self, cond_name: str) -> float:
        if cond_name in self._trip_cache:
            return self._trip_cache[cond_name]
        trips = 1.0
        names = [cond_name]
        for ins in self.computations.get(cond_name, []):
            mcalls = re.search(r"calls=%?([\w.\-]+)", ins.line)
            if mcalls:
                names.append(mcalls.group(1))
        consts: Dict[str, float] = {}
        for nm in names:
            for ins in self.computations.get(nm, []):
                if ins.op == "constant":
                    mc = re.search(r"constant\(([-0-9]+)\)", ins.line)
                    if mc:
                        consts[ins.name] = float(mc.group(1))
        for nm in names:
            for ins in self.computations.get(nm, []):
                if ins.op == "compare" and "direction=LT" in ins.line:
                    for o in ins.operands:
                        if o in consts:
                            trips = max(trips, consts[o])
                    for c2 in consts.values():
                        trips = max(trips, c2)
        self._trip_cache[cond_name] = trips
        return trips

    # -- costs --
    def _dot_flops(self, ins: Instr, shapes: Dict[str, List]) -> float:
        out_elems = _nelems(ins.result_shapes)
        lhs = shapes.get(ins.operands[0]) if ins.operands else None
        if not lhs:
            return 0.0
        mdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs
                          + ins.line)
        k = 1
        if mdims and mdims.group(1):
            _, ldims = lhs[0]
            for d in mdims.group(1).split(","):
                di = int(d)
                if di < len(ldims):
                    k *= ldims[di]
        return 2.0 * out_elems * k

    def _fusion_param_charges(self, name: str) -> Dict[int, str]:
        """For a fused computation, classify each parameter:
        'slice' = consumed only via dynamic-slice/slice/gather (charge the
        window, not the whole operand). Returns {param_index: 'slice'}."""
        if name in getattr(self, "_pcharge_cache", {}):
            return self._pcharge_cache[name]
        if not hasattr(self, "_pcharge_cache"):
            self._pcharge_cache = {}
        instrs = self.computations.get(name, [])
        pidx: Dict[str, int] = {}
        for ins in instrs:
            if ins.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", ins.line)
                if m:
                    pidx[ins.name] = int(m.group(1))
        uses: Dict[str, List[Instr]] = defaultdict(list)
        for ins in instrs:
            for o in ins.operands:
                uses[o].append(ins)
        PASS = ("bitcast", "reshape", "transpose", "copy", "convert")

        def window_bytes(vname: str, depth: int = 0):
            """Total bytes of slice windows if `vname` is consumed only by
            slicing (possibly through layout ops); None otherwise."""
            if depth > 4:
                return None
            u = uses.get(vname, [])
            if not u:
                return None
            total = 0.0
            for ins in u:
                if ins.op in ("dynamic-slice", "slice", "gather"):
                    total += _nbytes(ins.result_shapes)
                    continue
                if ins.op in PASS:
                    sub = window_bytes(ins.name, depth + 1)
                    if sub is not None:
                        total += sub
                        continue
                return None
            return total

        out: Dict[int, float] = {}
        for pname, idx in pidx.items():
            wb = window_bytes(pname)
            if wb is not None:
                out[idx] = wb
        self._pcharge_cache[name] = out
        return out

    def computation_cost(self, name: str) -> Cost:
        if name in self._cost_cache:
            return self._cost_cache[name]
        self._cost_cache[name] = Cost()  # cycle guard
        total = Cost()
        shapes: Dict[str, List] = {}
        for ins in self.computations.get(name, []):
            shapes[ins.name] = ins.result_shapes
        for ins in self.computations.get(name, []):
            c = Cost()
            own_flops = own_fused = 0.0
            op = ins.op
            out_bytes = _nbytes(ins.result_shapes)
            in_bytes = sum(_nbytes(shapes.get(o, [])) for o in ins.operands)
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "copy", "copy-start", "copy-done"):
                pass
            elif op == "dot":
                df = self._dot_flops(ins, shapes)
                c.flops += df
                c.bytes += in_bytes + out_bytes
                c.fused_bytes += in_bytes + out_bytes
                own_flops += df
                own_fused += in_bytes + out_bytes
            elif op == "fusion":
                mcalls = re.search(r"calls=%?([\w.\-]+)", ins.line)
                if mcalls:
                    sub = self.computation_cost(mcalls.group(1))
                    # fusion internals contribute flops/collectives; the
                    # fused-bytes model charges only the boundary
                    c.flops += sub.flops
                    c.bytes += sub.bytes
                    c.coll_bytes += sub.coll_bytes
                    for kk, v in sub.coll_by_kind.items():
                        c.coll_by_kind[kk] += v
                # boundary accounting with two in-place/windowed patterns:
                #  * aliased accumulator (scan ys-stacking lowers to a DUS
                #    fusion whose output aliases a same-shaped operand):
                #    charge only the update traffic, not the buffer;
                #  * sliced reads (scan xs-consumption lowers to a fusion
                #    whose parameter is consumed only by dynamic-slice):
                #    charge the window, approximated by the fusion output.
                res = ins.result_shapes
                pch = self._fusion_param_charges(mcalls.group(1)) \
                    if mcalls else {}
                has_dus = any(
                    i2.op == "dynamic-update-slice"
                    for i2 in self.computations.get(
                        mcalls.group(1) if mcalls else "", []))
                alias = False
                eff_in = 0.0
                for i, o in enumerate(ins.operands):
                    osh = shapes.get(o, [])
                    if (not alias and has_dus and _nbytes(osh) == out_bytes
                            and out_bytes > (1 << 20)):
                        alias = True       # aliased accumulator: in-place
                        continue
                    if i in pch:
                        eff_in += min(_nbytes(osh), pch[i])
                    else:
                        eff_in += _nbytes(osh)
                boundary = eff_in + (min(out_bytes, max(eff_in, 1.0))
                                     if alias else out_bytes)
                c.bytes += boundary
                c.fused_bytes += boundary
                own_fused += boundary
            elif op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                mt = _TRIP_RE.search(ins.line)
                if mt:
                    trips = float(mt.group(1))
                else:
                    mc = re.search(r"condition=%?([\w.\-]+)", ins.line)
                    trips = self.trip_count(mc.group(1)) if mc else 1.0
                if mb:
                    c += self.computation_cost(mb.group(1)).scaled(trips)
            elif op in ("call", "custom-call"):
                mcalls = re.search(r"to_apply=%?([\w.\-]+)", ins.line)
                if mcalls:
                    c += self.computation_cost(mcalls.group(1))
                c.bytes += in_bytes + out_bytes
                c.fused_bytes += in_bytes + out_bytes
                own_fused += in_bytes + out_bytes
            elif op == "conditional":
                for mm in re.finditer(
                        r"(?:true_computation|false_computation|branch_computations)=\{?%?([\w.\-,% ]+)",
                        ins.line):
                    for nm in re.findall(r"[\w.\-]+", mm.group(1)):
                        c += self.computation_cost(nm)
            else:
                base = None
                for coll in _COLLECTIVES:
                    if op == coll or op.startswith(coll + "-") or \
                            op.startswith(coll + "."):
                        base = coll
                        break
                if base and not op.endswith("-done"):
                    c.coll_bytes += in_bytes
                    c.coll_by_kind[base] += in_bytes
                    c.bytes += in_bytes + out_bytes
                    c.fused_bytes += in_bytes + out_bytes
                    own_fused += in_bytes + out_bytes
                elif op in _ELEMENTWISE or op in (
                        "reduce", "broadcast", "reshape", "transpose",
                        "concatenate", "slice", "dynamic-slice",
                        "dynamic-update-slice", "pad", "convert", "iota",
                        "reverse", "gather", "scatter", "map",
                        "reduce-window", "convolution", "rng",
                        "rng-bit-generator", "sort", "dot-general"):
                    if op in _ELEMENTWISE or op == "reduce":
                        ef = _nelems(ins.result_shapes if op != "reduce"
                                     else shapes.get(ins.operands[0], []))
                        c.flops += ef
                        own_flops += ef
                    if op == "convolution":
                        c.flops += 2.0 * _nelems(ins.result_shapes) * 8
                        own_flops += 2.0 * _nelems(ins.result_shapes) * 8
                    # slicing ops touch only the sliced window, not the
                    # whole operand (a 4k-step SSM scan would otherwise be
                    # charged the full sequence EVERY step)
                    if op in ("dynamic-slice", "slice", "gather"):
                        moved = 2.0 * out_bytes
                    elif op == "dynamic-update-slice":
                        upd = _nbytes(shapes.get(ins.operands[1], [])) \
                            if len(ins.operands) > 1 else out_bytes
                        moved = 2.0 * upd
                    elif op == "scatter":
                        upd = _nbytes(shapes.get(ins.operands[-1], []))
                        moved = 2.0 * upd
                    else:
                        moved = in_bytes + out_bytes
                    c.bytes += moved
                    # fused-bytes model: elementwise / layout ops fuse into
                    # their producers; genuine data movement still counts
                    if op in ("reduce", "concatenate", "slice",
                              "dynamic-slice", "dynamic-update-slice",
                              "gather", "scatter", "sort", "convolution",
                              "pad"):
                        c.fused_bytes += moved
                        own_fused += moved
                else:
                    c.bytes += in_bytes + out_bytes
                    c.fused_bytes += in_bytes + out_bytes
                    own_fused += in_bytes + out_bytes
            if own_flops or own_fused:
                c.add_scoped(ins.line, own_flops, own_fused)
            total += c
        self._cost_cache[name] = total
        return total

    def entry_cost(self) -> Cost:
        return self.computation_cost(self.entry)


def analyze(hlo_text: str) -> Cost:
    return HloModule(hlo_text).entry_cost()
