"""Serve-layer observability: metrics registry, lifecycle tracing,
profiler hooks.

One :class:`Observability` bundle per engine threads three host-side,
hot-path-cheap surfaces through the serve stack (docs/observability.md):

- :mod:`repro.obs.metrics` — the typed registry that owns every serve
  counter/gauge/histogram (Prometheus text + JSON snapshot; exact
  p50/p95/p99).
- :mod:`repro.obs.trace` — the bounded request-lifecycle event ring,
  exported as Chrome/Perfetto trace-event JSON.
- :mod:`repro.obs.profile` — compile-event counters around every jitted
  serve callable + opt-in ``jax.profiler`` span annotations.

Everything is append-only host work — no device sync is ever introduced
on the jitted path — and ``Observability(enabled=False)`` collapses the
whole stack to no-ops (the ``serve/obs_overhead`` bench row holds the
enabled/disabled throughput delta to ≤3%). The package is stdlib-only
at import time so the dependency-free lint CI job can load the metric
catalog.
"""
from __future__ import annotations

from repro.obs import profile
from repro.obs.metrics import METRIC_CATALOG, Histogram, MetricsRegistry
from repro.obs.trace import (RequestOutcome, TraceBuffer,
                             lifecycle_violations, request_outcomes)

__all__ = ["METRIC_CATALOG", "Histogram", "MetricsRegistry",
           "Observability", "RequestOutcome", "TraceBuffer",
           "lifecycle_violations", "request_outcomes", "profile"]


class Observability:
    """Per-engine observability bundle.

    Attributes:
        metrics: the :class:`~repro.obs.metrics.MetricsRegistry` (a
            disabled shell when ``enabled=False``).
        trace: the :class:`~repro.obs.trace.TraceBuffer`, or None when
            disabled or ``trace_capacity=0`` (emission sites guard on
            ``trace is not None``).
        compile_counts: ``{callable name: XLA traces}`` — every jitted
            serve callable registers itself here via
            :func:`repro.obs.profile.count_traces`.
        span: ``name -> context manager`` for profiler annotations
            (no-op unless profiling is opted in, see
            :func:`repro.obs.profile.spans_enabled`).
    """

    def __init__(self, enabled: bool = True, trace_capacity: int = 65536,
                 profile_spans=None):
        self.enabled = enabled
        self.metrics = MetricsRegistry(enabled=enabled)
        self.trace = TraceBuffer(trace_capacity) \
            if enabled and trace_capacity > 0 else None
        self.compile_counts: dict = {}
        self.span = profile.span_factory(
            enabled and profile.spans_enabled(profile_spans))
