"""Compile-event counters and opt-in ``jax.profiler`` span hooks.

**Compile counters** promote the technique ``tests/test_serve_trace.py``
proves in tests into production: a jitted function's *Python body* runs
once per XLA trace, so wrapping the pre-jit callable with
:func:`count_traces` counts compilations exactly — zero cost on cached
calls beyond one dict increment at trace time. Every jitted serve
callable (backend step/verify, draft prefill/wave, dense decode) wraps
itself into its backend's ``compile_counts`` dict;
:func:`compiles_per_callable` is the derived gauge the registry exposes
(``engine.compiles_per_callable``) — a recompile leak shows up as this
number creeping above the expected O(log max_len) bucket count.

**Profiler spans** are opt-in (``REPRO_PROFILE=1`` or an explicit flag):
:func:`span_factory` returns a ``name -> context manager`` callable that
is a shared no-op ``nullcontext`` when disabled (nothing allocated per
call) and ``jax.profiler.TraceAnnotation`` when enabled, so the jitted
prefill/decode/verify dispatches show up named in a ``jax.profiler``
/ TensorBoard / Perfetto device trace.

The module itself imports neither jax nor numpy (jax loads lazily
inside the enabled-spans path only), keeping the obs package importable
in the dependency-free lint job.
"""
from __future__ import annotations

import contextlib
import os
from typing import Callable, Dict

_NULL = contextlib.nullcontext()


def count_traces(name: str, fn: Callable, counts: Dict[str, int]) \
        -> Callable:
    """Wrap a pre-jit callable so each XLA trace of it increments
    ``counts[name]`` (the body only runs when jit traces)."""
    counts.setdefault(name, 0)

    def traced(*args):
        counts[name] = counts.get(name, 0) + 1
        return fn(*args)
    return traced


def compiles_per_callable(counts: Dict[str, int]) -> float:
    """Mean traces per registered jitted callable (0 before any jit)."""
    if not counts:
        return 0.0
    return sum(counts.values()) / len(counts)


def spans_enabled(flag=None) -> bool:
    """Profiler spans are opt-in: an explicit flag wins, else the
    ``REPRO_PROFILE=1`` environment switch."""
    if flag is not None:
        return bool(flag)
    return os.environ.get("REPRO_PROFILE", "0") == "1"


def span_factory(enabled: bool) -> Callable:
    """``name -> context manager`` for annotating host dispatch regions.
    Disabled: one shared reusable nullcontext (no per-call allocation).
    Enabled: ``jax.profiler.TraceAnnotation`` (imported lazily here —
    the only jax touch in this package)."""
    if not enabled:
        return lambda name: _NULL
    import jax

    return lambda name: jax.profiler.TraceAnnotation(name)
