"""Typed metrics registry for the serve layer.

One process-wide catalog (:data:`METRIC_CATALOG`) declares every metric
the serve stack may emit — name, kind, help string. The registry is the
single owner of what used to be scattered across ``scheduler.stats``,
``PrefixCache.stats``, and the spec counters: the scheduler asks the
registry for its counter dicts (:meth:`MetricsRegistry.stats_dict`), so
the *same* plain-dict objects the rest of the code mutates are what the
registry reads at snapshot time. Nothing on the hot path goes through a
method call per increment — counters stay ``stats["k"] += n`` — which is
how the observability overhead stays within the ≤3% contract
(``serve/obs_overhead`` bench row).

Three kinds:

- **counter** — monotone int/float, owned by a registered stats dict.
- **gauge** — a zero-arg callable sampled at snapshot time (queue depth,
  free pages, derived rates). Never called on the hot path.
- **histogram** — fixed log-spaced buckets (4/decade across 1e-5..1e2
  seconds) for Prometheus exposition **plus** the raw samples for exact
  p50/p95/p99 readout (:meth:`Histogram.quantile` reproduces
  ``numpy.percentile``'s default linear interpolation bit-for-bit; past
  ``sample_cap`` it degrades to seeded reservoir sampling so memory
  stays bounded).

Export surfaces: :meth:`MetricsRegistry.snapshot` (JSON-able dict,
``--metrics-json``) and :meth:`MetricsRegistry.to_prometheus`
(text exposition format).

This module is **stdlib-only** (no numpy/jax): the docs drift gate
(``tests/test_docs.py``) imports the catalog inside the lint CI job,
which installs nothing but ruff + pytest.
"""
from __future__ import annotations

import math
import random
from typing import Callable, Dict, List, Optional, Tuple

#: histogram bucket upper bounds: 4 per decade, 1e-5 s .. 1e2 s — wide
#: enough for a sub-50us fused decode wave and a 100 s overloaded tail.
DEFAULT_BUCKETS: Tuple[float, ...] = tuple(
    10.0 ** (e / 4.0) for e in range(-20, 9))

#: raw samples kept per histogram before switching to reservoir
#: sampling (exact quantiles below the cap; tests stay under it).
SAMPLE_CAP = 262144

#: every metric the serve stack may emit: name -> (kind, help).
#: ``docs/observability.md`` documents exactly this set and
#: ``tests/test_docs.py`` enforces the equality in both directions;
#: :meth:`MetricsRegistry.stats_dict` enforces the runtime half (a
#: stats key that is not in the catalog raises at construction).
METRIC_CATALOG: Dict[str, Tuple[str, str]] = {
    # scheduler counters (the legacy scheduler.stats keys, 1:1)
    "scheduler.prefill_tokens": (
        "counter", "prompt tokens written by batched chunked prefill"),
    "scheduler.prefill_s": (
        "counter", "wall seconds inside jitted prefill calls"),
    "scheduler.prefill_calls": (
        "counter", "batched prefill calls (one per admission wave)"),
    "scheduler.decode_tokens": (
        "counter", "tokens emitted by decode/verify waves"),
    "scheduler.decode_s": (
        "counter", "wall seconds inside jitted decode/verify calls"),
    "scheduler.decode_steps": (
        "counter", "decode (or speculative) waves executed"),
    "scheduler.shared_tokens": (
        "counter", "prompt tokens reused via the prefix trie"),
    "scheduler.pages_allocated": (
        "counter", "fresh pages taken from the pool"),
    "scheduler.pages_shared": (
        "counter", "pages mapped read-only from the prefix trie"),
    "scheduler.draft_calls": (
        "counter", "coarse-draft jitted calls (spec decode)"),
    "scheduler.verify_calls": (
        "counter", "full-model verify waves (spec decode)"),
    "scheduler.tokens_drafted": (
        "counter", "tokens proposed by the coarse draft"),
    "scheduler.tokens_accepted": (
        "counter", "drafted tokens the verifier accepted"),
    "scheduler.requests_rejected": (
        "counter", "requests rejected at submit (can never fit the pool)"),
    "scheduler.requests_failed": (
        "counter", "requests finished with error set (incl. rejections)"),
    "scheduler.preemptions": (
        "counter", "running requests evicted for a more urgent one"),
    "scheduler.pages_spilled": (
        "counter", "preempted pages copied to host memory"),
    "scheduler.pages_restored": (
        "counter", "spilled pages scattered back on resume"),
    "scheduler.preempt_recomputes": (
        "counter", "preemptions resolved by re-prefill instead of spill"),
    "scheduler.prefix_partial_hits": (
        "counter", "admissions that reused a token-granular partial page"),
    "scheduler.prefix_partial_tokens_shared": (
        "counter", "prompt tokens reused via partial-page fork_partial"),
    "scheduler.prefill_chunks": (
        "counter", "budget-bounded prefill ingest waves (chunked mode)"),
    # prefix-trie counters (legacy PrefixCache.stats keys, 1:1)
    "trie.hit_pages": (
        "counter", "physical pages served from the prefix trie"),
    "trie.miss_prompts": (
        "counter", "prompts with no usable trie prefix"),
    "trie.evicted": (
        "counter", "trie-pinned pages evicted under pool pressure"),
    # request/wave latency histograms
    "request.ttft_s": (
        "histogram", "time to first token per finished request (s)"),
    "request.tpot_s": (
        "histogram", "mean seconds per output token after the first"),
    "request.latency_s": (
        "histogram", "submit-to-done wall time per finished request (s)"),
    "wave.prefill_s": (
        "histogram", "wall seconds per batched prefill call"),
    "wave.decode_s": (
        "histogram", "wall seconds per decode/verify wave"),
    # gauges (sampled at snapshot time, never on the hot path)
    "pool.free_pages": (
        "gauge", "free pages in the physical page pool"),
    "scheduler.queue_depth": (
        "gauge", "requests waiting for admission"),
    "scheduler.n_active": (
        "gauge", "occupied decode slots"),
    "scheduler.accept_rate": (
        "gauge", "fraction of drafted tokens accepted (0 when spec off)"),
    "trie.hit_rate": (
        "gauge", "shared / (shared + prefilled) prompt tokens"),
    "engine.compiles_per_callable": (
        "gauge", "mean XLA traces per jitted serve callable"),
}


class Histogram:
    """Log-spaced bucket counts + raw samples for exact quantiles.

    ``observe`` is O(log buckets) + one list append; quantiles sort
    lazily at readout. Below :data:`SAMPLE_CAP` samples,
    :meth:`quantile` is exact and matches ``numpy.percentile(...,
    method='linear')``; past the cap, a fixed-seed reservoir keeps the
    estimate unbiased at bounded memory.
    """

    def __init__(self, name: str, help_: str = "",
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        self.name = name
        self.help = help_
        self.bounds = tuple(sorted(buckets))
        self.bucket_counts = [0] * (len(self.bounds) + 1)  # +1: +Inf
        self.count = 0
        self.sum = 0.0
        self._samples: List[float] = []
        self._sorted = True
        self._reservoir = random.Random(0)

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        lo, hi = 0, len(self.bounds)
        while lo < hi:                      # first bound >= v
            mid = (lo + hi) // 2
            if self.bounds[mid] < v:
                lo = mid + 1
            else:
                hi = mid
        self.bucket_counts[lo] += 1
        if len(self._samples) < SAMPLE_CAP:
            self._samples.append(v)
            self._sorted = False
        else:
            j = self._reservoir.randrange(self.count)
            if j < SAMPLE_CAP:
                self._samples[j] = v
                self._sorted = False

    def quantile(self, q: float) -> Optional[float]:
        """Exact q-quantile (0 <= q <= 1) of the retained samples, with
        numpy's default linear interpolation; None when empty."""
        if not self._samples:
            return None
        if not self._sorted:
            self._samples.sort()
            self._sorted = True
        s = self._samples
        h = (len(s) - 1) * q
        lo = math.floor(h)
        hi = min(lo + 1, len(s) - 1)
        return s[lo] + (s[hi] - s[lo]) * (h - lo)

    def percentiles(self) -> Dict[str, Optional[float]]:
        return {"p50": self.quantile(0.50), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}


class MetricsRegistry:
    """Owner of every serve-layer metric (see module docstring).

    ``enabled=False`` turns the registry into a shell: ``stats_dict``
    hands back plain unregistered dicts, ``observe`` is a no-op, and
    ``snapshot()`` is empty — the zero-overhead arm of the
    ``serve/obs_overhead`` bench row.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._stats: Dict[str, Dict] = {}            # namespace -> dict
        self._gauges: Dict[str, Callable[[], float]] = {}
        self._hists: Dict[str, Histogram] = {}
        if enabled:
            for name, (kind, help_) in METRIC_CATALOG.items():
                if kind == "histogram":
                    self._hists[name] = Histogram(name, help_)

    @staticmethod
    def _check(name: str, kind: str) -> None:
        got = METRIC_CATALOG.get(name)
        if got is None or got[0] != kind:
            raise KeyError(
                f"metric {name!r} is not a catalogued {kind} — add it to "
                "METRIC_CATALOG (and docs/observability.md; the docs "
                "drift gate enforces the catalog in both directions)")

    def stats_dict(self, namespace: str, initial: Dict) -> Dict:
        """A counter dict registered under ``namespace`` — the caller
        keeps mutating it in place (``d[k] += n``); the registry reads
        it at snapshot time. Every ``namespace.key`` must be in the
        catalog. Returns ``initial`` itself, so existing code that
        resets counters via ``stats[k] = 0`` keeps working."""
        if self.enabled:
            for key in initial:
                self._check(f"{namespace}.{key}", "counter")
            self._stats[namespace] = initial
        return initial

    def gauge(self, name: str, fn: Callable[[], float]) -> None:
        """Register a zero-arg sampler called only at snapshot time."""
        if self.enabled:
            self._check(name, "gauge")
            self._gauges[name] = fn

    def histogram(self, name: str) -> Optional[Histogram]:
        return self._hists.get(name)

    def observe(self, name: str, value) -> None:
        """Record one histogram sample (no-op when disabled or None)."""
        if not self.enabled or value is None:
            return
        self._hists[name].observe(value)

    # -- export surfaces ----------------------------------------------------

    def snapshot(self) -> Dict[str, object]:
        """JSON-able view of every registered metric: counters as
        numbers, gauges sampled now, histograms as
        {count, sum, p50, p95, p99}."""
        out: Dict[str, object] = {}
        for ns, d in self._stats.items():
            for k, v in d.items():
                out[f"{ns}.{k}"] = v
        for name, fn in self._gauges.items():
            out[name] = float(fn())
        for name, h in self._hists.items():
            out[name] = {"count": h.count, "sum": h.sum, **h.percentiles()}
        return out

    def to_prometheus(self, prefix: str = "repro") -> str:
        """Prometheus text exposition of the same metrics (counters get
        the ``_total`` suffix, histograms the cumulative ``_bucket`` /
        ``_sum`` / ``_count`` triple)."""
        lines: List[str] = []

        def emit(name: str, kind: str, help_: str):
            flat = f"{prefix}_{name.replace('.', '_')}"
            lines.append(f"# HELP {flat} {help_}")
            lines.append(f"# TYPE {flat} {kind}")
            return flat

        for ns, d in self._stats.items():
            for k, v in d.items():
                name = f"{ns}.{k}"
                flat = emit(name, "counter", METRIC_CATALOG[name][1])
                lines.append(f"{flat}_total {v}")
        for name, fn in self._gauges.items():
            flat = emit(name, "gauge", METRIC_CATALOG[name][1])
            lines.append(f"{flat} {float(fn())}")
        for name, h in self._hists.items():
            flat = emit(name, "histogram", METRIC_CATALOG[name][1])
            cum = 0
            for bound, c in zip(h.bounds, h.bucket_counts[:-1],
                                strict=True):
                cum += c
                lines.append(f'{flat}_bucket{{le="{bound:.6g}"}} {cum}')
            lines.append(f'{flat}_bucket{{le="+Inf"}} {h.count}')
            lines.append(f"{flat}_sum {h.sum}")
            lines.append(f"{flat}_count {h.count}")
        return "\n".join(lines) + "\n"
