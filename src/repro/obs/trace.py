"""Bounded ring-buffer request-lifecycle event log + Perfetto export.

The scheduler appends one small host-side tuple per lifecycle edge —
submit → queued → admit → prefill → decode waves → preempt/spill/restore
→ finish/fail/cancel — with monotonic (``time.perf_counter``) timestamps
and wave-scoped spans. Appends are O(1) into a bounded deque (oldest
events drop first, counted in :attr:`TraceBuffer.dropped`); nothing here
ever touches a device array, so tracing adds no sync points to the
jitted hot path.

Event tuples are ``(ph, ts, dur, kind, rid, slot, wave, args)``:

- ``ph`` — "i" instant, "X" complete span, "C" counter sample
  (deliberately the Chrome trace-event phase letters).
- ``ts`` / ``dur`` — perf_counter seconds (span start + duration).
- ``kind`` — the lifecycle edge (see :data:`EVENT_KINDS`) or, for
  counters, the counter name.
- ``rid`` / ``slot`` / ``wave`` — request id, decode slot, scheduler
  wave; -1 where not applicable.
- ``args`` — small dict of host scalars (or None).

**Lifecycle invariant** (tested across seeded fuzz scenarios): every
submitted rid emits *exactly one* terminal event — ``finish``, ``fail``
or ``cancel``. :func:`request_outcomes` folds a buffer into per-request
outcome records and :func:`lifecycle_violations` checks the invariant;
``bench_traffic`` recomputes its goodput/preemption/rejection accounting
from these records and asserts exact agreement with the scheduler's
counters (silent event loss fails the bench).

:meth:`TraceBuffer.to_perfetto` renders the buffer as Chrome/Perfetto
trace-event JSON — load the file at https://ui.perfetto.dev (or
``chrome://tracing``): one track per decode slot, a scheduler-wave
track, an allocator counter track, and one async span per request from
submit to its terminal event.

Stdlib-only, like :mod:`repro.obs.metrics` (the lint CI job imports
this package without numpy/jax installed).
"""
from __future__ import annotations

import collections
import json
import time
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Tuple

INSTANT, SPAN, COUNTER = "i", "X", "C"

#: request-lifecycle instants the scheduler emits (counter names and
#: span kinds — prefill/prefill_chunk/decode/spec_wave/admit_wave —
#: ride alongside).
EVENT_KINDS = ("submit", "queued", "admit", "resume", "first_token",
               "preempt", "restore", "finish", "fail", "cancel")

#: exactly one of these per submitted request (the lifecycle invariant)
TERMINAL_KINDS = ("finish", "fail", "cancel")

#: Perfetto track (tid) layout: per-slot tracks start at _SLOT_TID0
_SCHED_TID, _ALLOC_TID, _SLOT_TID0 = 0, 1, 100


class TraceBuffer:
    """Bounded append-only event ring (see module docstring)."""

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError("trace capacity must be >= 1")
        self.capacity = capacity
        self.dropped = 0                 # evicted oldest-first, counted
        self._events: Deque[Tuple] = collections.deque()

    def __len__(self) -> int:
        return len(self._events)

    def _push(self, ev: Tuple) -> None:
        if len(self._events) >= self.capacity:
            self._events.popleft()
            self.dropped += 1
        self._events.append(ev)

    def instant(self, kind: str, rid: int = -1, slot: int = -1,
                wave: int = -1, args: Optional[Dict] = None) -> None:
        self._push((INSTANT, time.perf_counter(), 0.0, kind, rid, slot,
                    wave, args))

    def span(self, kind: str, t0: float, t1: float, rid: int = -1,
             slot: int = -1, wave: int = -1,
             args: Optional[Dict] = None) -> None:
        self._push((SPAN, t0, max(t1 - t0, 0.0), kind, rid, slot, wave,
                    args))

    def counter(self, name: str, value) -> None:
        self._push((COUNTER, time.perf_counter(), 0.0, name, -1, -1, -1,
                    {"value": value}))

    def events(self) -> List[Tuple]:
        return list(self._events)

    # -- Perfetto export ----------------------------------------------------

    def to_perfetto(self) -> Dict:
        """Chrome trace-event JSON (dict form): pid 1, tid 0 = scheduler
        waves, tid 1 = allocator counters, tid 100+slot = decode slots;
        plus one async ("b"/"e") span per request spanning submit to its
        terminal event."""
        evs: List[Dict] = []
        slots_seen = set()

        def tid_of(slot: int, kind: str) -> int:
            if slot >= 0:
                slots_seen.add(slot)
                return _SLOT_TID0 + slot
            return _ALLOC_TID if kind.startswith("pool.") else _SCHED_TID

        open_async: Dict[int, bool] = {}
        for ph, ts, dur, kind, rid, slot, wave, args in self._events:
            ts_us = ts * 1e6
            a = {k: v for k, v in (args or {}).items() if v is not None}
            if rid >= 0:
                a["rid"] = rid
            if wave >= 0:
                a["wave"] = wave
            if ph == COUNTER:
                evs.append({"name": kind, "ph": "C", "pid": 1,
                            "tid": _ALLOC_TID, "ts": ts_us,
                            "args": {"value": a.get("value", 0)}})
                continue
            base = {"name": kind, "ph": ph, "pid": 1,
                    "tid": tid_of(slot, kind), "ts": ts_us, "args": a}
            if ph == SPAN:
                base["dur"] = dur * 1e6
            else:
                base["s"] = "t"          # instant scope: thread
            evs.append(base)
            if rid >= 0 and ph == INSTANT:
                if kind == "submit":
                    open_async[rid] = True
                    evs.append({"name": f"req {rid}", "cat": "request",
                                "ph": "b", "id": rid, "pid": 1,
                                "tid": _SCHED_TID, "ts": ts_us,
                                "args": a})
                elif kind in TERMINAL_KINDS and open_async.pop(rid, False):
                    evs.append({"name": f"req {rid}", "cat": "request",
                                "ph": "e", "id": rid, "pid": 1,
                                "tid": _SCHED_TID, "ts": ts_us,
                                "args": {"outcome": kind, **a}})
        meta = [{"name": "process_name", "ph": "M", "pid": 1,
                 "args": {"name": "repro.serve"}},
                {"name": "thread_name", "ph": "M", "pid": 1,
                 "tid": _SCHED_TID, "args": {"name": "scheduler"}},
                {"name": "thread_name", "ph": "M", "pid": 1,
                 "tid": _ALLOC_TID, "args": {"name": "allocator"}}]
        for slot in sorted(slots_seen):
            meta.append({"name": "thread_name", "ph": "M", "pid": 1,
                         "tid": _SLOT_TID0 + slot,
                         "args": {"name": f"slot {slot}"}})
        return {"traceEvents": meta + evs, "displayTimeUnit": "ms"}

    def save(self, path: str) -> int:
        """Write the Perfetto JSON to ``path``; returns the number of
        trace events written."""
        doc = self.to_perfetto()
        with open(path, "w") as f:
            json.dump(doc, f, default=float)
        return len(doc["traceEvents"])


@dataclass
class RequestOutcome:
    """Per-request fold of the lifecycle events (``request_outcomes``)."""
    rid: int
    submitted: bool = False
    terminal: Optional[str] = None       # finish / fail / cancel
    terminals: int = 0                   # should be exactly 1
    n_out: int = 0
    ttft_s: Optional[float] = None
    tpot_s: Optional[float] = None
    latency_s: Optional[float] = None
    preemptions: int = 0
    rejected: bool = False               # failed at submit (unservable)
    ttft_target_s: Optional[float] = None
    tpot_target_s: Optional[float] = None

    @property
    def slo_met(self) -> bool:
        """SLO attainment recomputed purely from trace events — the
        cross-check ``bench_traffic`` runs against the scheduler's own
        ``slo_met`` accounting (same semantics as
        ``ScheduledRequest.slo_met``)."""
        if self.terminal != "finish":
            return False
        if self.ttft_target_s is not None and (
                self.ttft_s is None or self.ttft_s > self.ttft_target_s):
            return False
        if self.tpot_target_s is not None and (
                self.tpot_s is not None
                and self.tpot_s > self.tpot_target_s):
            return False
        return True


def request_outcomes(events) -> Dict[int, RequestOutcome]:
    """Fold a buffer's events into {rid: RequestOutcome}."""
    out: Dict[int, RequestOutcome] = {}
    for ph, _ts, _dur, kind, rid, _slot, _wave, args in events:
        if rid < 0 or ph != INSTANT:
            continue
        o = out.setdefault(rid, RequestOutcome(rid))
        a = args or {}
        if kind == "submit":
            o.submitted = True
            o.ttft_target_s = a.get("ttft_target_s")
            o.tpot_target_s = a.get("tpot_target_s")
        elif kind == "preempt":
            o.preemptions += 1
        elif kind in TERMINAL_KINDS:
            o.terminals += 1
            o.terminal = kind
            o.n_out = int(a.get("n_out", 0))
            o.ttft_s = a.get("ttft_s")
            o.tpot_s = a.get("tpot_s")
            o.latency_s = a.get("latency_s")
            if kind == "fail" and a.get("rejected"):
                o.rejected = True
    return out


def lifecycle_violations(events, rids=None) -> List[str]:
    """Messages for every submitted request violating the exactly-one-
    terminal-event invariant (empty list = invariant holds). ``rids``
    restricts the check to that id set (e.g. one benchmark leg — the
    same buffer may hold earlier warmup traffic)."""
    msgs = []
    for rid, o in sorted(request_outcomes(events).items()):
        if rids is not None and rid not in rids:
            continue
        if not o.submitted:
            msgs.append(f"rid {rid}: events without a submit")
        if o.terminals != 1:
            msgs.append(f"rid {rid}: {o.terminals} terminal events "
                        f"(want exactly 1; last={o.terminal})")
    return msgs
