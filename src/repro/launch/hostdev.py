"""Force the CPU host platform to expose N devices (single owner of the
``--xla_force_host_platform_device_count`` XLA_FLAGS dance used by
`launch/serve --mesh`, the bench_serve mesh row, and the mesh
conformance tests).

jax-free on purpose: the flag is only honoured if it is in the
environment before jax's backend initializes, so callers import this
module and call :func:`force_host_device_count` *before* ``import jax``
(or build a child-process env with ``env=``).
"""
from __future__ import annotations

import os
from typing import MutableMapping, Optional


def force_host_device_count(
        n: int, env: Optional[MutableMapping[str, str]] = None) -> None:
    """Append the force-device-count flag to XLA_FLAGS in ``env``
    (default ``os.environ``), preserving any operator-set flags. A
    pre-existing ``--xla_force_host_platform_device_count`` wins — the
    caller must then cope with whatever device count comes up (e.g.
    ``jax.make_mesh(..., devices=jax.devices()[:n])`` + an explicit
    count check)."""
    env = os.environ if env is None else env
    flags = env.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" in flags:
        return
    env["XLA_FLAGS"] = (
        flags + f" --xla_force_host_platform_device_count={n}").strip()
