"""Production meshes.

Single pod: (16, 16) = ("data", "model") — 256 chips (TPU v5e pod).
Multi-pod: (2, 16, 16) = ("pod", "data", "model") — 512 chips.

The 'model' axis carries the paper's layer-parallel (MGRIT chunk) dimension
during training and Megatron TP during serving; 'data'(+'pod') carry batch,
FSDP storage sharding and expert parallelism (DESIGN.md §4).

Functions, not module constants: importing this module must never touch
jax device state.
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)}. "
            "The dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax (launch/dryrun.py).")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh():
    """1x1 mesh on the single real device (tests, examples)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
