"""Jitted step builders: train_step / prefill_step / serve_step with
GSPMD shardings derived from the config's logical-axis rules."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import RunConfig
from repro.models import transformer
from repro.optim import optimizers
from repro.parallel import params as pshard
from repro.parallel.sharding import axis_rules


def make_train_fn(rcfg: RunConfig, mesh: Optional[Mesh]):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). Gradient accumulation over microbatches bounds the live MGRIT
    state memory (EXPERIMENTS.md §Dry-run)."""
    mode = "lp" if rcfg.mgrit.enabled else "serial"
    nmb = rcfg.microbatches

    def loss(p, b):
        l, diag = transformer.loss_fn(p, b, rcfg, mode=mode)
        return l, diag

    def train_step(params, opt_state, batch):
        ctx = axis_rules(mesh, rcfg.sharding) if mesh is not None else \
            _nullctx()
        with ctx:
            if nmb > 1:
                mb = jax.tree.map(
                    lambda a: a.reshape((nmb, a.shape[0] // nmb)
                                        + a.shape[1:]), batch)

                def acc(carry, b_i):
                    g_acc, l_acc = carry
                    (l, diag), g = jax.value_and_grad(loss, has_aux=True)(
                        params, b_i)
                    g_acc = jax.tree.map(
                        lambda a, g_: a + g_.astype(jnp.float32), g_acc, g)
                    return (g_acc, l_acc + l), diag

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, lsum), diags = jax.lax.scan(acc, (g0, 0.0), mb)
                grads = jax.tree.map(lambda g: g / nmb, grads)
                lval = lsum / nmb
                diag = jax.tree.map(lambda a: a[-1], diags)
            else:
                (lval, diag), grads = jax.value_and_grad(
                    loss, has_aux=True)(params, batch)
            params2, opt_state2, om = optimizers.apply_updates(
                rcfg.optimizer, params, grads, opt_state)
        metrics = {"loss": lval, "fwd_norms": diag["fwd_norms"], **om}
        return params2, opt_state2, metrics

    return train_step


def make_prefill_fn(rcfg: RunConfig, mesh: Optional[Mesh]):
    def prefill_step(params, batch):
        ctx = axis_rules(mesh, rcfg.sharding) if mesh is not None else \
            _nullctx()
        with ctx:
            logits = transformer.prefill(params, batch, rcfg)
            nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return nxt, logits

    return prefill_step


def make_serve_fn(rcfg: RunConfig, mesh: Optional[Mesh], paged: bool = False):
    """Greedy decode step builder.

    Dense (default): (params, cache, tokens (B,T)) -> (next (B,1), cache).
    T > 1 chunk-prefills the prompt into the cache in one call.
    ``paged=True``: decode against the shared page pool with explicit
    cache-page indices and an occupancy mask (n_new == 0 -> empty slot):
    (params, pages, tokens (B,S), lengths, n_new, page_table) ->
    (next (B,1), pages).
    """
    if paged:
        return make_paged_serve_fn(rcfg, mesh)
    encdec = rcfg.model.family == "encdec"

    def serve_step(params, cache, tokens, xa=None):
        ctx = axis_rules(mesh, rcfg.sharding) if mesh is not None else \
            _nullctx()
        with ctx:
            logits, cache2 = transformer.decode_step(params, cache, tokens,
                                                     rcfg, xa=xa)
            nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return nxt[:, None], cache2

    if not encdec:
        return lambda params, cache, tokens: serve_step(params, cache, tokens)
    return serve_step


def make_paged_serve_fn(rcfg: RunConfig, mesh: Optional[Mesh]):
    """Paged-cache step: one jitted function serves both chunked prefill
    (S = prompt bucket) and steady-state decode (S = 1); slot occupancy is
    the ``n_new`` mask, so admissions/evictions never retrace."""

    def paged_serve_step(params, pages, tokens, lengths, n_new, page_table):
        ctx = axis_rules(mesh, rcfg.sharding) if mesh is not None else \
            _nullctx()
        with ctx:
            logits, pages2 = transformer.paged_decode_step(
                params, pages, tokens, lengths, n_new, page_table, rcfg)
            nxt = jnp.argmax(logits.astype(jnp.float32), axis=-1)
        return nxt[:, None], pages2

    return paged_serve_step


class _nullctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


def shardings_for_train(rcfg: RunConfig, mesh: Mesh, params_sds,
                        opt_sds, batch_sds):
    ps = pshard.param_specs(params_sds, rcfg, mesh)
    os_ = {"step": NamedSharding(mesh, P())}
    for k in ("m", "v", "master"):
        if k in opt_sds:
            os_[k] = ps
    bs = pshard.batch_specs(batch_sds, rcfg, mesh)
    return ps, os_, bs


def shardings_for_decode(rcfg: RunConfig, mesh: Mesh, params_sds, cache_sds):
    ps = pshard.param_specs(params_sds, rcfg, mesh)
    cs = pshard.cache_specs(cache_sds, rcfg, mesh)
    ts = NamedSharding(mesh, P(None, None))
    return ps, cs, ts
