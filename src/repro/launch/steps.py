"""Jitted step builders: train_step / prefill_step / serve_step with
GSPMD shardings derived from the config's logical-axis rules."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import RunConfig
from repro.models import transformer
from repro.optim import optimizers
from repro.parallel import params as pshard
from repro.parallel.sharding import axis_rules


def make_train_fn(rcfg: RunConfig, mesh: Optional[Mesh]):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). Gradient accumulation over microbatches bounds the live MGRIT
    state memory (EXPERIMENTS.md §Dry-run)."""
    mode = "lp" if rcfg.mgrit.enabled else "serial"
    nmb = rcfg.microbatches

    def loss(p, b):
        l, diag = transformer.loss_fn(p, b, rcfg, mode=mode)
        return l, diag

    def train_step(params, opt_state, batch):
        ctx = axis_rules(mesh, rcfg.sharding) if mesh is not None else \
            _nullctx()
        with ctx:
            if nmb > 1:
                mb = jax.tree.map(
                    lambda a: a.reshape((nmb, a.shape[0] // nmb)
                                        + a.shape[1:]), batch)

                def acc(carry, b_i):
                    g_acc, l_acc = carry
                    (l, diag), g = jax.value_and_grad(loss, has_aux=True)(
                        params, b_i)
                    g_acc = jax.tree.map(
                        lambda a, g_: a + g_.astype(jnp.float32), g_acc, g)
                    return (g_acc, l_acc + l), diag

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, lsum), diags = jax.lax.scan(acc, (g0, 0.0), mb)
                grads = jax.tree.map(lambda g: g / nmb, grads)
                lval = lsum / nmb
                diag = jax.tree.map(lambda a: a[-1], diags)
            else:
                (lval, diag), grads = jax.value_and_grad(
                    loss, has_aux=True)(params, batch)
            params2, opt_state2, om = optimizers.apply_updates(
                rcfg.optimizer, params, grads, opt_state)
        metrics = {"loss": lval, "fwd_norms": diag["fwd_norms"], **om}
        return params2, opt_state2, metrics

    return train_step


def make_prefill_fn(rcfg: RunConfig, mesh: Optional[Mesh]):
    def prefill_step(params, batch):
        ctx = axis_rules(mesh, rcfg.sharding) if mesh is not None else \
            _nullctx()
        with ctx:
            logits = transformer.prefill(params, batch, rcfg)
            nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return nxt, logits

    return prefill_step


def make_serve_fn(rcfg: RunConfig, mesh: Optional[Mesh]):
    """Dense greedy decode step builder: (params, cache, tokens (B,T)) ->
    (next (B,1), cache). T > 1 chunk-prefills the prompt into the cache in
    one call (attention kinds). This is the serial-forward oracle the
    paged backends are conformance-tested against, and the engine's dense
    comparison probe; production decode goes through
    :func:`make_paged_serve_fn` + a ``repro.serve.cache`` backend.
    """
    encdec = rcfg.model.family == "encdec"

    def serve_step(params, cache, tokens, xa=None):
        ctx = axis_rules(mesh, rcfg.sharding) if mesh is not None else \
            _nullctx()
        with ctx:
            logits, cache2 = transformer.decode_step(params, cache, tokens,
                                                     rcfg, xa=xa)
            nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return nxt[:, None], cache2

    if not encdec:
        return lambda params, cache, tokens: serve_step(params, cache, tokens)
    return serve_step


_MASKED = -1e30          # matches the attention-mask convention


def apply_top_k(logits, k):
    """Mask all but each row's k highest logits to ``_MASKED``.

    logits: (B, V) float; k: (B,) int32, vectorized per row. ``k <= 0``
    (or ``k >= V``) disables the filter for that row. Ties at the k-th
    value are kept, so the surviving set can only be larger, never
    smaller, than k (irrelevant for real float logits).
    """
    V = logits.shape[-1]
    srt = jnp.sort(logits, axis=-1)                       # ascending
    k_eff = jnp.clip(jnp.where(k <= 0, V, k), 1, V)
    kth = jnp.take_along_axis(srt, (V - k_eff)[:, None], axis=-1)
    return jnp.where(logits < kth, _MASKED, logits)


def apply_top_p(logits, p):
    """Nucleus mask: keep each row's smallest descending-probability set
    whose cumulative mass reaches p (the argmax always survives), mask the
    rest to ``_MASKED``. logits: (B, V); p: (B,) in (0, 1], per row;
    ``p >= 1`` keeps every token with non-zero probability."""
    B, V = logits.shape
    idx = jnp.argsort(logits, axis=-1)[:, ::-1]           # descending
    srt = jnp.take_along_axis(logits, idx, axis=-1)
    probs = jax.nn.softmax(srt.astype(jnp.float32), axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep = (cum - probs) < p[:, None]     # mass before this token < p
    keep = keep.at[:, 0].set(True)
    masked_sorted = jnp.where(keep, srt, _MASKED)
    return jnp.zeros_like(logits).at[
        jnp.arange(B)[:, None], idx].set(masked_sorted)


def apply_top_k_top_p(logits, k, p):
    """Fused top-k + nucleus mask: one descending sort drives both
    filters (top-k masking preserves the survivors' order, so the
    separate argsort in :func:`apply_top_p` is redundant on the hot
    path). Semantically identical to ``apply_top_p(apply_top_k(x, k),
    p)`` for distinct logits."""
    B, V = logits.shape
    idx = jnp.argsort(logits, axis=-1)[:, ::-1]           # descending
    srt = jnp.take_along_axis(logits, idx, axis=-1)
    k_eff = jnp.clip(jnp.where(k <= 0, V, k), 1, V)
    keep = jnp.arange(V)[None, :] < k_eff[:, None]
    probs = jax.nn.softmax(
        jnp.where(keep, srt, _MASKED).astype(jnp.float32), axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep &= (cum - probs) < p[:, None]    # mass before this token < p
    keep = keep.at[:, 0].set(True)        # argmax always survives
    masked_sorted = jnp.where(keep, srt, _MASKED)
    return jnp.zeros_like(logits).at[
        jnp.arange(B)[:, None], idx].set(masked_sorted)


def sample_tokens(logits, temps, top_ks, top_ps, seeds, counters,
                  fused: bool = False):
    """Vectorized per-slot sampling: (B, V) logits -> (B,) int32 tokens.

    Slots with ``temps <= 0`` take the exact greedy argmax path (bitwise
    identical to the pre-sampling step). Others scale by temperature,
    apply top-k then top-p masks, and draw via the Gumbel-argmax trick
    with key ``fold_in(PRNGKey(seed), counter)`` — the key depends only on
    the request's own seed and how many tokens it has generated, so the
    same request reproduces the same stream in any slot and any batch
    composition.

    ``fused`` swaps the full-vocab sort in :func:`apply_top_k_top_p` for
    the sort-free threshold-search mask (``repro.kernels.ops.
    topk_topp_mask``). The key schedule and the greedy path are part of
    the sampling contract and never change; for distinct surviving
    logits the masks are identical, so the drawn tokens match too.
    """
    lf = logits.astype(jnp.float32)
    greedy = jnp.argmax(lf, axis=-1).astype(jnp.int32)

    def _sampled(_):
        scaled = lf / jnp.maximum(temps, 1e-6)[:, None]
        if fused:
            from repro.kernels import ops as kops
            scaled = kops.topk_topp_mask(scaled, top_ks, top_ps)
        else:
            scaled = apply_top_k_top_p(scaled, top_ks, top_ps)

        def draw(seed, counter):
            key = jax.random.fold_in(jax.random.PRNGKey(seed), counter)
            return jax.random.gumbel(key, (lf.shape[-1],), jnp.float32)

        gumbel = jax.vmap(draw)(seeds, counters)
        sampled = jnp.argmax(scaled + gumbel, axis=-1)
        return jnp.where(temps <= 0.0, greedy, sampled).astype(jnp.int32)

    # all-greedy batches skip the sort/top-p/gumbel work entirely (runtime
    # branch, same trace — mixed batches still decode lock-step)
    return jax.lax.cond(jnp.any(temps > 0.0), _sampled, lambda _: greedy,
                        None)


# ---------------------------------------------------------------------------
# Speculative decoding: draft sampling + acceptance (paper's coarse
# propagator as a self-speculative draft — see repro.serve.spec)
# ---------------------------------------------------------------------------


def draft_sample_tokens(logits, temps, top_ks, top_ps, seeds, counters):
    """Draft-side sampling: (B, V) logits -> (tokens (B,), probs (B, V)).

    ``probs`` is the draft's TRUE proposal distribution — the verifier's
    rejection sampling needs q(d) and the full q vector for the leftover
    distribution. Greedy slots (temps <= 0) propose the argmax with a
    one-hot q (verification then reduces to exact match). Sampled slots
    draw from the temperature-scaled top-k/top-p-masked distribution
    with the request's *draft* stream ``fold_in(fold_in(PRNGKey(seed),
    counter), 2)`` — disjoint from the canonical stream (fold 0 = accept
    u / bonus gumbel, fold 1 = leftover gumbel), so acceptance draws stay
    independent of the proposals. Distribution preservation holds for any
    proposal stream; only the acceptance rate depends on it.
    """
    lf = logits.astype(jnp.float32)
    V = lf.shape[-1]
    greedy = jnp.argmax(lf, axis=-1).astype(jnp.int32)
    g_probs = jax.nn.one_hot(greedy, V, dtype=jnp.float32)

    def _sampled(_):
        scaled = lf / jnp.maximum(temps, 1e-6)[:, None]
        masked = apply_top_k_top_p(scaled, top_ks, top_ps)
        probs = jax.nn.softmax(masked, axis=-1)

        def draw(seed, counter):
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(seed), counter), 2)
            return jax.random.gumbel(key, (V,), jnp.float32)

        gum = jax.vmap(draw)(seeds, counters)
        samp = jnp.argmax(masked + gum, axis=-1).astype(jnp.int32)
        tok = jnp.where(temps <= 0.0, greedy, samp)
        pr = jnp.where((temps <= 0.0)[:, None], g_probs, probs)
        return tok, pr

    return jax.lax.cond(jnp.any(temps > 0.0), _sampled,
                        lambda _: (greedy, g_probs), None)


def speculative_accept(logits, tokens, draft_probs, temps, top_ks, top_ps,
                       seeds, counters, n_new):
    """Accept a drafted prefix against the fine model's own targets.

    logits: (B, S, V) fine logits over the verify window; tokens: (B, S)
    = [pending, d_1..d_k]; draft_probs: (B, k, V) proposal distributions;
    n_new: (B,) = per-slot drafted count + 1 (0 = idle slot). Position i
    of the window is the request's emission index ``counters[b] + i``, so
    every draw is keyed exactly like plain decode.

    Greedy slots: accepted = longest prefix where d_{i+1} equals the fine
    argmax — emitted tokens are bitwise what plain decode would produce.
    Sampled slots: standard speculative rejection sampling — accept d
    with prob min(1, p(d)/q(d)); on first rejection draw from the
    normalized leftover max(p - q, 0); when every draft survives, draw
    the bonus token from p at the next position with the SAME key plain
    decode would use. Either way the emitted distribution is exactly the
    target p (Leviathan et al. 2023).

    Returns (accepted (B,) in [0, n_new-1], next_token (B,)).
    """
    B, S, V = logits.shape
    k = S - 1
    lf = logits.astype(jnp.float32)
    drafts = tokens[:, 1:]
    n_draft = jnp.maximum(n_new - 1, 0)
    pos_ok = jnp.arange(k)[None, :] < n_draft[:, None]
    greedy_t = jnp.argmax(lf, axis=-1).astype(jnp.int32)          # (B, S)
    g_match = (drafts == greedy_t[:, :k]) & pos_ok
    g_acc = jnp.sum(jnp.cumprod(g_match.astype(jnp.int32), axis=1), axis=1)
    g_next = jnp.take_along_axis(greedy_t, g_acc[:, None], axis=1)[:, 0]

    def _sampled(_):
        scaled = lf / jnp.maximum(temps, 1e-6)[:, None, None]
        masked = apply_top_k_top_p(
            scaled.reshape(B * S, V),
            jnp.repeat(top_ks, S), jnp.repeat(top_ps, S)).reshape(B, S, V)
        p = jax.nn.softmax(masked, axis=-1)
        q = draft_probs.astype(jnp.float32)                        # (B, k, V)

        def slot_keys(seed, counter):
            base = jax.random.PRNGKey(seed)
            return jax.vmap(
                lambda i: jax.random.fold_in(base, counter + i))(
                jnp.arange(S))
        keys = jax.vmap(slot_keys)(seeds, counters)                # (B, S, 2)
        u = jax.vmap(jax.vmap(
            lambda kk: jax.random.uniform(kk, ())))(keys[:, :k])
        p_d = jnp.take_along_axis(p[:, :k], drafts[..., None], -1)[..., 0]
        q_d = jnp.take_along_axis(q, drafts[..., None], -1)[..., 0]
        ok = (u < p_d / jnp.maximum(q_d, 1e-30)) & pos_ok
        s_acc = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)
        j = s_acc                          # first rejected index, or n_draft
        p_j = jnp.take_along_axis(p, j[:, None, None], axis=1)[:, 0]
        q_pad = jnp.concatenate([q, jnp.zeros((B, 1, V), jnp.float32)], 1)
        q_j = jnp.take_along_axis(q_pad, j[:, None, None], axis=1)[:, 0]
        rejected = j < n_draft
        res = jnp.clip(p_j - q_j, 0.0, None)
        rs = jnp.sum(res, axis=-1, keepdims=True)
        res = jnp.where(rs > 0, res / jnp.maximum(rs, 1e-30), p_j)
        dist = jnp.where(rejected[:, None], res, p_j)
        key_j = jnp.take_along_axis(
            keys, jnp.broadcast_to(j[:, None, None], (B, 1, 2)),
            axis=1)[:, 0]
        left_keys = jax.vmap(lambda kk: jax.random.fold_in(kk, 1))(key_j)
        gkey = jnp.where(rejected[:, None], left_keys, key_j)
        gum = jax.vmap(
            lambda kk: jax.random.gumbel(kk, (V,), jnp.float32))(gkey)
        s_next = jnp.argmax(jnp.log(jnp.maximum(dist, 1e-30)) + gum,
                            axis=-1).astype(jnp.int32)
        acc = jnp.where(temps > 0.0, s_acc, g_acc).astype(jnp.int32)
        nxt = jnp.where(temps > 0.0, s_next, g_next)
        return acc, nxt

    return jax.lax.cond(jnp.any(temps > 0.0), _sampled,
                        lambda _: (g_acc.astype(jnp.int32), g_next), None)


def make_paged_verify_fn(rcfg: RunConfig, mesh: Optional[Mesh], verify_fn,
                         commit_fn=None):
    """Speculative-verification step builder: ONE jitted occupancy-masked
    call runs the FULL model over each slot's pending token + k drafted
    tokens, samples the per-position targets, computes the accepted
    prefix (:func:`speculative_accept`), and commits decode state for
    exactly the accepted prefix.

    ``verify_fn`` is the family's paged verify forward
    (``transformer.{paged,ssm_paged,hybrid_paged}_verify_step``);
    ``commit_fn`` is its deferred snapshot commit, or None for backends
    whose rollback is host-side length truncation (attention KV). The
    returned callable maps (params, state, tokens (B, k+1), lengths,
    n_new, page_table, sampling params, counters, draft_probs (B, k, V))
    -> (accepted (B,), next_token (B,), new_state).
    """
    def paged_verify_step(params, state, tokens, lengths, n_new, page_table,
                          temps, top_ks, top_ps, seeds, counters,
                          draft_probs):
        ctx = axis_rules(mesh, rcfg.sharding) if mesh is not None else \
            _nullctx()
        with ctx:
            logits, state2, art = verify_fn(params, state, tokens, lengths,
                                            n_new, page_table, rcfg)
            acc, nxt = speculative_accept(logits, tokens, draft_probs,
                                          temps, top_ks, top_ps, seeds,
                                          counters, n_new)
            if commit_fn is not None:
                n_write = jnp.where(n_new > 0,
                                    jnp.minimum(acc + 1, n_new), 0)
                state2 = commit_fn(state2, art, page_table, lengths,
                                   n_write)
        return acc, nxt, state2

    return paged_verify_step


def make_draft_wave_fn(rcfg: RunConfig, mesh: Optional[Mesh], decode_fn,
                       *, k: int, page_size: int, snapshot_state: bool):
    """One fused jitted call for a whole draft wave of the coarse
    propagator: (1) the catch-up ingest — canonical tokens the draft has
    not yet cached plus the pending token, S = k+1 occupancy-masked —
    which commits TRUE state and proposes d_1; (2) k-1 in-call
    autoregressive speculative steps (a lax.scan feeding each sampled
    token back) proposing d_2..d_k. Slots stop advancing at their own
    ``n_draft``, so near-finished requests never write past capacity.

    On snapshot backends the partial state page holding the
    post-ingest committed state is saved before speculation and restored
    before returning — speculative writes to it are undone in-call, so
    the next wave's ingest resumes from true canonical state (KV drafts
    skip this: stale entries beyond the committed length are masked and
    later overwritten). Returns (drafted (B, k), draft_probs (B, k, V),
    new_state).
    """
    def draft_wave(params, state, tokens, lengths, n_in, page_table,
                   temps, top_ks, top_ps, seeds, counters, n_draft):
        ctx = axis_rules(mesh, rcfg.sharding) if mesh is not None else \
            _nullctx()
        with ctx:
            logits, state = decode_fn(params, state, tokens, lengths, n_in,
                                      page_table, rcfg)
            tok, probs = draft_sample_tokens(logits, temps, top_ks, top_ps,
                                             seeds, counters)
            committed = lengths + n_in
            if snapshot_state:
                P = page_table.shape[1]
                slot = jnp.clip((committed - 1) // page_size, 0, P - 1)
                part = jnp.take_along_axis(page_table, slot[:, None],
                                           axis=1)[:, 0]
                saved = jax.tree.map(lambda a: a[:, part], state)

            def body(carry, i):
                st, ln, tk = carry
                live = ((n_in > 0) & (n_draft >= i + 2)).astype(jnp.int32)
                lg, st = decode_fn(params, st, tk[:, None], ln, live,
                                   page_table, rcfg)
                t2, p2 = draft_sample_tokens(lg, temps, top_ks, top_ps,
                                             seeds, counters + i + 1)
                return (st, ln + live, t2), (t2, p2)

            if k > 1:
                (state, _, _), (ts, ps_) = jax.lax.scan(
                    body, (state, committed, tok), jnp.arange(k - 1))
                d = jnp.concatenate([tok[:, None], ts.T], axis=1)
                q = jnp.concatenate([probs[:, None],
                                     jnp.moveaxis(ps_, 0, 1)], axis=1)
            else:
                d, q = tok[:, None], probs[:, None]
            if snapshot_state:
                state = jax.tree.map(
                    lambda a, s: a.at[:, part].set(s), state, saved)
        return d, q, state

    return draft_wave


def make_paged_serve_fn(rcfg: RunConfig, mesh: Optional[Mesh],
                        decode_fn=None, fused: bool = False):
    """Paged-state step: one jitted function serves both chunked prefill
    (S = prompt bucket) and steady-state decode (S = 1); slot occupancy is
    the ``n_new`` mask, so admissions/evictions never retrace.

    ``decode_fn`` is the family's paged forward — any of
    ``transformer.{paged,ssm_paged,hybrid_paged}_decode_step`` (possibly
    with ``page_size`` pre-bound), called as ``decode_fn(params, state,
    tokens, lengths, n_new, page_table, rcfg)``. Defaults to the attention
    KV step. The ``repro.serve.cache`` backends pick the right one, so
    every family decodes through this single wrapper.

    Sampling is vectorized per slot inside the same trace: ``temps`` /
    ``top_ks`` / ``top_ps`` are (B,) request parameters (temperature 0 =
    greedy), ``seeds``/``counters`` derive each slot's PRNG key, so mixed
    greedy/sampled batches decode lock-step with no retrace. ``fused``
    selects the sort-free sampling epilogue (see :func:`sample_tokens`).
    """
    decode_fn = decode_fn or transformer.paged_decode_step

    def paged_serve_step(params, state, tokens, lengths, n_new, page_table,
                         temps, top_ks, top_ps, seeds, counters):
        ctx = axis_rules(mesh, rcfg.sharding) if mesh is not None else \
            _nullctx()
        with ctx:
            logits, state2 = decode_fn(params, state, tokens, lengths,
                                       n_new, page_table, rcfg)
            nxt = sample_tokens(logits, temps, top_ks, top_ps, seeds,
                                counters, fused=fused)
        return nxt[:, None], state2

    return paged_serve_step


class _nullctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


def shardings_for_train(rcfg: RunConfig, mesh: Mesh, params_sds,
                        opt_sds, batch_sds):
    ps = pshard.param_specs(params_sds, rcfg, mesh)
    os_ = {"step": NamedSharding(mesh, P())}
    for k in ("m", "v", "master"):
        if k in opt_sds:
            os_[k] = ps
    bs = pshard.batch_specs(batch_sds, rcfg, mesh)
    return ps, os_, bs


def shardings_for_decode(rcfg: RunConfig, mesh: Mesh, params_sds, cache_sds):
    ps = pshard.param_specs(params_sds, rcfg, mesh)
    cs = pshard.cache_specs(cache_sds, rcfg, mesh)
    ts = NamedSharding(mesh, P(None, None))
    return ps, cs, ts
