"""Jitted step builders: train_step / prefill_step / serve_step with
GSPMD shardings derived from the config's logical-axis rules."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import RunConfig
from repro.models import transformer
from repro.optim import optimizers
from repro.parallel import params as pshard
from repro.parallel.sharding import axis_rules


def make_train_fn(rcfg: RunConfig, mesh: Optional[Mesh]):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). Gradient accumulation over microbatches bounds the live MGRIT
    state memory (EXPERIMENTS.md §Dry-run)."""
    mode = "lp" if rcfg.mgrit.enabled else "serial"
    nmb = rcfg.microbatches

    def loss(p, b):
        l, diag = transformer.loss_fn(p, b, rcfg, mode=mode)
        return l, diag

    def train_step(params, opt_state, batch):
        ctx = axis_rules(mesh, rcfg.sharding) if mesh is not None else \
            _nullctx()
        with ctx:
            if nmb > 1:
                mb = jax.tree.map(
                    lambda a: a.reshape((nmb, a.shape[0] // nmb)
                                        + a.shape[1:]), batch)

                def acc(carry, b_i):
                    g_acc, l_acc = carry
                    (l, diag), g = jax.value_and_grad(loss, has_aux=True)(
                        params, b_i)
                    g_acc = jax.tree.map(
                        lambda a, g_: a + g_.astype(jnp.float32), g_acc, g)
                    return (g_acc, l_acc + l), diag

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, lsum), diags = jax.lax.scan(acc, (g0, 0.0), mb)
                grads = jax.tree.map(lambda g: g / nmb, grads)
                lval = lsum / nmb
                diag = jax.tree.map(lambda a: a[-1], diags)
            else:
                (lval, diag), grads = jax.value_and_grad(
                    loss, has_aux=True)(params, batch)
            params2, opt_state2, om = optimizers.apply_updates(
                rcfg.optimizer, params, grads, opt_state)
        metrics = {"loss": lval, "fwd_norms": diag["fwd_norms"], **om}
        return params2, opt_state2, metrics

    return train_step


def make_prefill_fn(rcfg: RunConfig, mesh: Optional[Mesh]):
    def prefill_step(params, batch):
        ctx = axis_rules(mesh, rcfg.sharding) if mesh is not None else \
            _nullctx()
        with ctx:
            logits = transformer.prefill(params, batch, rcfg)
            nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return nxt, logits

    return prefill_step


def make_serve_fn(rcfg: RunConfig, mesh: Optional[Mesh]):
    """Dense greedy decode step builder: (params, cache, tokens (B,T)) ->
    (next (B,1), cache). T > 1 chunk-prefills the prompt into the cache in
    one call (attention kinds). This is the serial-forward oracle the
    paged backends are conformance-tested against, and the engine's dense
    comparison probe; production decode goes through
    :func:`make_paged_serve_fn` + a ``repro.serve.cache`` backend.
    """
    encdec = rcfg.model.family == "encdec"

    def serve_step(params, cache, tokens, xa=None):
        ctx = axis_rules(mesh, rcfg.sharding) if mesh is not None else \
            _nullctx()
        with ctx:
            logits, cache2 = transformer.decode_step(params, cache, tokens,
                                                     rcfg, xa=xa)
            nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return nxt[:, None], cache2

    if not encdec:
        return lambda params, cache, tokens: serve_step(params, cache, tokens)
    return serve_step


_MASKED = -1e30          # matches the attention-mask convention


def apply_top_k(logits, k):
    """Mask all but each row's k highest logits to ``_MASKED``.

    logits: (B, V) float; k: (B,) int32, vectorized per row. ``k <= 0``
    (or ``k >= V``) disables the filter for that row. Ties at the k-th
    value are kept, so the surviving set can only be larger, never
    smaller, than k (irrelevant for real float logits).
    """
    V = logits.shape[-1]
    srt = jnp.sort(logits, axis=-1)                       # ascending
    k_eff = jnp.clip(jnp.where(k <= 0, V, k), 1, V)
    kth = jnp.take_along_axis(srt, (V - k_eff)[:, None], axis=-1)
    return jnp.where(logits < kth, _MASKED, logits)


def apply_top_p(logits, p):
    """Nucleus mask: keep each row's smallest descending-probability set
    whose cumulative mass reaches p (the argmax always survives), mask the
    rest to ``_MASKED``. logits: (B, V); p: (B,) in (0, 1], per row;
    ``p >= 1`` keeps every token with non-zero probability."""
    B, V = logits.shape
    idx = jnp.argsort(logits, axis=-1)[:, ::-1]           # descending
    srt = jnp.take_along_axis(logits, idx, axis=-1)
    probs = jax.nn.softmax(srt.astype(jnp.float32), axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep = (cum - probs) < p[:, None]     # mass before this token < p
    keep = keep.at[:, 0].set(True)
    masked_sorted = jnp.where(keep, srt, _MASKED)
    return jnp.zeros_like(logits).at[
        jnp.arange(B)[:, None], idx].set(masked_sorted)


def apply_top_k_top_p(logits, k, p):
    """Fused top-k + nucleus mask: one descending sort drives both
    filters (top-k masking preserves the survivors' order, so the
    separate argsort in :func:`apply_top_p` is redundant on the hot
    path). Semantically identical to ``apply_top_p(apply_top_k(x, k),
    p)`` for distinct logits."""
    B, V = logits.shape
    idx = jnp.argsort(logits, axis=-1)[:, ::-1]           # descending
    srt = jnp.take_along_axis(logits, idx, axis=-1)
    k_eff = jnp.clip(jnp.where(k <= 0, V, k), 1, V)
    keep = jnp.arange(V)[None, :] < k_eff[:, None]
    probs = jax.nn.softmax(
        jnp.where(keep, srt, _MASKED).astype(jnp.float32), axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep &= (cum - probs) < p[:, None]    # mass before this token < p
    keep = keep.at[:, 0].set(True)        # argmax always survives
    masked_sorted = jnp.where(keep, srt, _MASKED)
    return jnp.zeros_like(logits).at[
        jnp.arange(B)[:, None], idx].set(masked_sorted)


def sample_tokens(logits, temps, top_ks, top_ps, seeds, counters):
    """Vectorized per-slot sampling: (B, V) logits -> (B,) int32 tokens.

    Slots with ``temps <= 0`` take the exact greedy argmax path (bitwise
    identical to the pre-sampling step). Others scale by temperature,
    apply top-k then top-p masks, and draw via the Gumbel-argmax trick
    with key ``fold_in(PRNGKey(seed), counter)`` — the key depends only on
    the request's own seed and how many tokens it has generated, so the
    same request reproduces the same stream in any slot and any batch
    composition.
    """
    lf = logits.astype(jnp.float32)
    greedy = jnp.argmax(lf, axis=-1).astype(jnp.int32)

    def _sampled(_):
        scaled = lf / jnp.maximum(temps, 1e-6)[:, None]
        scaled = apply_top_k_top_p(scaled, top_ks, top_ps)

        def draw(seed, counter):
            key = jax.random.fold_in(jax.random.PRNGKey(seed), counter)
            return jax.random.gumbel(key, (lf.shape[-1],), jnp.float32)

        gumbel = jax.vmap(draw)(seeds, counters)
        sampled = jnp.argmax(scaled + gumbel, axis=-1)
        return jnp.where(temps <= 0.0, greedy, sampled).astype(jnp.int32)

    # all-greedy batches skip the sort/top-p/gumbel work entirely (runtime
    # branch, same trace — mixed batches still decode lock-step)
    return jax.lax.cond(jnp.any(temps > 0.0), _sampled, lambda _: greedy,
                        None)


def make_paged_serve_fn(rcfg: RunConfig, mesh: Optional[Mesh],
                        decode_fn=None):
    """Paged-state step: one jitted function serves both chunked prefill
    (S = prompt bucket) and steady-state decode (S = 1); slot occupancy is
    the ``n_new`` mask, so admissions/evictions never retrace.

    ``decode_fn`` is the family's paged forward — any of
    ``transformer.{paged,ssm_paged,hybrid_paged}_decode_step`` (possibly
    with ``page_size`` pre-bound), called as ``decode_fn(params, state,
    tokens, lengths, n_new, page_table, rcfg)``. Defaults to the attention
    KV step. The ``repro.serve.cache`` backends pick the right one, so
    every family decodes through this single wrapper.

    Sampling is vectorized per slot inside the same trace: ``temps`` /
    ``top_ks`` / ``top_ps`` are (B,) request parameters (temperature 0 =
    greedy), ``seeds``/``counters`` derive each slot's PRNG key, so mixed
    greedy/sampled batches decode lock-step with no retrace.
    """
    decode_fn = decode_fn or transformer.paged_decode_step

    def paged_serve_step(params, state, tokens, lengths, n_new, page_table,
                         temps, top_ks, top_ps, seeds, counters):
        ctx = axis_rules(mesh, rcfg.sharding) if mesh is not None else \
            _nullctx()
        with ctx:
            logits, state2 = decode_fn(params, state, tokens, lengths,
                                       n_new, page_table, rcfg)
            nxt = sample_tokens(logits, temps, top_ks, top_ps, seeds,
                                counters)
        return nxt[:, None], state2

    return paged_serve_step


class _nullctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


def shardings_for_train(rcfg: RunConfig, mesh: Mesh, params_sds,
                        opt_sds, batch_sds):
    ps = pshard.param_specs(params_sds, rcfg, mesh)
    os_ = {"step": NamedSharding(mesh, P())}
    for k in ("m", "v", "master"):
        if k in opt_sds:
            os_[k] = ps
    bs = pshard.batch_specs(batch_sds, rcfg, mesh)
    return ps, os_, bs


def shardings_for_decode(rcfg: RunConfig, mesh: Mesh, params_sds, cache_sds):
    ps = pshard.param_specs(params_sds, rcfg, mesh)
    cs = pshard.cache_specs(cache_sds, rcfg, mesh)
    ts = NamedSharding(mesh, P(None, None))
    return ps, cs, ts
