import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402

from repro.launch import dryrun  # noqa: E402

"""§Perf hillclimb driver: lower+compile one (arch, shape) cell under a
named optimization variant and record the roofline delta vs the
paper-faithful baseline. Variants compose via --variant a+b+c.

  baseline    the paper-faithful configuration (as in configs/<arch>.py)
  flashattn   flash-style chunked attention from 2k seq (kills the S^2
              logits materialization; models the Pallas kernel's tiling)
  bf16params  bf16 stored params + fp32 master in the optimizer (halves
              weight reads and FSDP all-gather bytes)
  moegroup    GShard dispatch groups of 512 tokens (shrinks dispatch/
              combine tensors ~8x for 4k sequences)
  shardl1     shard the first coarse MGRIT level's relaxation too
  cf<k>       override the MGRIT coarsening factor
  mb<k>       gradient-accumulation microbatches (memory bound)
"""

OUTDIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                      "experiments", "perf")


def apply_variant(rcfg, name: str):
    for part in name.split("+"):
        if part == "baseline":
            continue
        elif part == "flashattn":
            rcfg = dataclasses.replace(
                rcfg, model=dataclasses.replace(rcfg.model, attn_chunk=2048))
        elif part == "bf16params":
            rcfg = dataclasses.replace(
                rcfg, model=dataclasses.replace(rcfg.model,
                                                param_dtype="bfloat16"))
        elif part == "moegroup":
            assert rcfg.model.moe is not None
            rcfg = dataclasses.replace(
                rcfg, model=dataclasses.replace(
                    rcfg.model, moe=dataclasses.replace(
                        rcfg.model.moe, group_size=512)))
        elif part == "shardl1":
            rcfg = dataclasses.replace(
                rcfg, mgrit=dataclasses.replace(rcfg.mgrit, shard_levels=2))
        elif part.startswith("cf"):
            rcfg = dataclasses.replace(
                rcfg, mgrit=dataclasses.replace(rcfg.mgrit,
                                                cf=int(part[2:])))
        elif part.startswith("mb"):
            rcfg = dataclasses.replace(rcfg, microbatches=int(part[2:]))
        elif part.startswith("iters"):
            f, b = part[5:].split("x")
            rcfg = dataclasses.replace(
                rcfg, mgrit=dataclasses.replace(
                    rcfg.mgrit, fwd_iters=int(f), bwd_iters=int(b)))
        else:
            raise ValueError(f"unknown variant {part}")
    return rcfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--variant", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--outdir", default=OUTDIR)
    args = ap.parse_args(argv)

    rec = dryrun.run_cell(args.arch, args.shape, args.multi,
                          mutate=lambda r: apply_variant(r, args.variant))
    rec["variant"] = args.variant
    os.makedirs(args.outdir, exist_ok=True)
    tag = f"{args.arch}__{args.shape}__{args.variant.replace('+', '_')}"
    with open(os.path.join(args.outdir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    print("wrote", tag, rec["status"])


if __name__ == "__main__":
    main()
