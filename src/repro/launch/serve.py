"""Serving launcher: continuous-batching generation with the ServeEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1p7b \
      [--reduced] [--requests 12] [--new-tokens 8] \
      [--max-batch 4] [--page-size 16] [--max-len 256] [--n-pages 0] \
      [--temperature 0.8] [--top-k 40] [--top-p 0.95] \
      [--priority 0,1] [--ttft-slo 0.5] [--tpot-slo 0.1] \
      [--preempt-policy auto] \
      [--shared-prefix-len 0] [--no-share-prefix] [--stream] \
      [--no-partial-prefix] [--prefill-chunk-tokens 0] \
      [--spec-cf 4 --spec-k 4] [--stats] [--mesh 1,2] \
      [--metrics-json metrics.json] [--trace-out trace.json]

Every decode-capable family runs the same paged continuous-batching
engine (batched chunked prefill + refcounted paged state with prefix
sharing/copy-on-write + slot scheduler + per-request sampling): attention
decoders page their KV cache, SSM archs (falcon_mamba_7b) page
recurrent-state snapshots, hybrid (zamba2_1p2b) composes both — all
behind the CacheBackend protocol (repro.serve.cache). ``--spec-cf``
turns on coarse-propagator speculative decoding (repro.serve.spec): the
paper's coarse grid — every cf-th layer, ODE step rescaled — drafts
``--spec-k`` tokens per wave and the full model verifies them in one
call (greedy output is bitwise identical to plain decode). The
scheduler is overload-safe and SLO-aware (docs/scheduling.md):
``--priority`` cycles requests through a priority list (smaller = more
urgent; urgent requests skip ahead and may preempt under pool
pressure), ``--ttft-slo`` / ``--tpot-slo`` attach latency targets
(reported as SLO attainment, never enforced by dropping), and
``--n-pages`` shrinks the page pool to provoke the overload machinery —
an unservable request prints its ``error`` instead of crashing the
run. ``--mesh
dp,tp`` serves mesh-sharded (docs/sharding.md): weights Megatron-TP over
'model', page pools over 'data' (registry.serve_sharding), one jitted
SPMD call per wave — temp-0 output stays token-for-token identical to
single-device decode. On a CPU container the host platform is forced to
dp*tp devices automatically; use --reduced for the big archs.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8,
                    help="number of queued requests")
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="in-flight decode slots")
    ap.add_argument("--page-size", type=int, default=16,
                    help="state-page size (tokens)")
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--n-pages", type=int, default=0,
                    help="page-pool size incl. scratch (0 = every slot "
                         "fits max_len; small pools exercise rejection/"
                         "skip-ahead/preemption)")
    ap.add_argument("--priority", default="0",
                    help="comma list cycled over requests, smaller = more "
                         "urgent (e.g. 0,2 alternates urgent/background)")
    ap.add_argument("--ttft-slo", type=float, default=0.0,
                    help="> 0 attaches a time-to-first-token target (s) "
                         "to every request; reported, never enforced")
    ap.add_argument("--tpot-slo", type=float, default=0.0,
                    help="> 0 attaches a per-output-token target (s)")
    ap.add_argument("--preempt-policy", default="auto",
                    choices=["auto", "spill", "recompute", "off"],
                    help="how urgent requests take pages from running "
                         "ones under pressure (docs/scheduling.md)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="", help="restore params from here")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples (any backend)")
    ap.add_argument("--top-k", type=int, default=0, help="0 disables")
    ap.add_argument("--top-p", type=float, default=1.0, help="1 disables")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="prepend this many common tokens to every prompt "
                         "(demonstrates prefix sharing)")
    ap.add_argument("--no-share-prefix", action="store_true",
                    help="disable the prefix cache / copy-on-write pages")
    ap.add_argument("--no-partial-prefix", action="store_true",
                    help="disable token-granular partial-page prefix "
                         "sharing (whole-page trie matching only; "
                         "docs/cache-backends.md)")
    ap.add_argument("--prefill-chunk-tokens", type=int, default=0,
                    help="> 0 interleaves chunked prefill with decode: "
                         "at most this many prompt tokens ingest per "
                         "scheduler wave, so long prompts never stall "
                         "in-flight decode (docs/scheduling.md); output "
                         "is bitwise identical either way")
    ap.add_argument("--stream", action="store_true",
                    help="stream the first request token-by-token")
    ap.add_argument("--spec-cf", type=int, default=0,
                    help="> 0 enables coarse-propagator speculative "
                         "decoding with this layer-coarsening factor")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="drafted tokens per verify wave")
    ap.add_argument("--stats", action="store_true",
                    help="print the engine's full counter dict (spec "
                         "decode + prefix cache included)")
    ap.add_argument("--metrics-json", default="",
                    help="write the metrics-registry snapshot (counters, "
                         "gauges, histogram p50/p95/p99) as JSON here")
    ap.add_argument("--trace-out", default="",
                    help="write the request-lifecycle trace as Chrome/"
                         "Perfetto trace-event JSON here (open at "
                         "https://ui.perfetto.dev)")
    ap.add_argument("--mesh", default="",
                    help="dp,tp — serve mesh-sharded on a (data, model) "
                         "mesh (e.g. --mesh 1,2 for 2-way tensor "
                         "parallelism); forces dp*tp host devices when "
                         "the platform has fewer")
    args = ap.parse_args(argv)

    mesh_shape = None
    if args.mesh:
        from repro.launch.hostdev import force_host_device_count
        dp, tp = (int(x) for x in args.mesh.split(","))
        mesh_shape = (dp, tp)
        # must land before the jax import below touches the backend
        force_host_device_count(dp * tp)

    import jax
    from repro.configs import registry
    from repro.configs.reduce import reduce_config
    from repro.models import transformer
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.spec import SpecConfig

    mesh = None
    if mesh_shape is not None:
        n = mesh_shape[0] * mesh_shape[1]
        if jax.device_count() < n:
            raise SystemExit(
                f"--mesh {args.mesh} needs {n} devices, have "
                f"{jax.device_count()} (XLA_FLAGS was set too late?)")
        mesh = jax.make_mesh(mesh_shape, ("data", "model"),
                             devices=jax.devices()[:n])

    rcfg = registry.get_config(args.arch, "decode_32k")
    if args.reduced:
        rcfg = reduce_config(rcfg)
    params = transformer.init_model(jax.random.PRNGKey(args.seed), rcfg)
    if args.ckpt:
        from repro.train import checkpoint as ckpt_mod
        restored = ckpt_mod.restore(args.ckpt, params, {"step": 0})
        if restored:
            params = restored[0]
            print(f"restored params from step {restored[2]}")

    spec = SpecConfig(cf=args.spec_cf, k=args.spec_k) \
        if args.spec_cf > 0 else None
    engine = ServeEngine(rcfg, params, mesh=mesh, max_len=args.max_len,
                         max_batch=args.max_batch,
                         page_size=args.page_size, n_pages=args.n_pages,
                         share_prefix=not args.no_share_prefix,
                         partial_prefix=not args.no_partial_prefix,
                         prefill_chunk_tokens=args.prefill_chunk_tokens,
                         spec=spec, preempt_policy=args.preempt_policy)
    print(f"engine: paged continuous-batching via "
          f"{type(engine.backend).__name__}"
          + (f" + spec decode (cf={spec.cf}, k={spec.k}, "
             f"{engine.scheduler.spec.n_coarse} coarse layers)"
             if spec else "")
          + (f" on mesh dp{mesh_shape[0]}xtp{mesh_shape[1]} "
             f"({dp * tp} devices)" if mesh is not None else ""))
    rng = np.random.default_rng(args.seed)
    common = rng.integers(0, rcfg.model.vocab_size,
                          size=args.shared_prefix_len).astype(np.int32)
    priorities = [int(p) for p in args.priority.split(",")]
    reqs = [Request(prompt=np.concatenate([common, rng.integers(
                0, rcfg.model.vocab_size,
                size=int(rng.integers(4, 12))).astype(np.int32)]),
                    max_new_tokens=args.new_tokens,
                    temperature=args.temperature, top_k=args.top_k,
                    top_p=args.top_p, seed=int(rng.integers(0, 2**31)),
                    priority=priorities[i % len(priorities)],
                    ttft_target_s=args.ttft_slo or None,
                    tpot_target_s=args.tpot_slo or None)
            for i in range(args.requests)]
    if args.stream:
        first, rest = reqs[0], reqs[1:]
        stream = engine.submit(first, stream=True)
        rest_rids = [engine.submit(r) for r in rest]
        print("request 0 (streamed): ", end="", flush=True)
        for _tok, piece in stream:
            print(piece, end="", flush=True)
        print()
        done = engine.scheduler.run()
        for r, rid in zip(rest, rest_rids, strict=True):
            ServeEngine._finalize(r, done.pop(rid))
        out = [first] + rest
    else:
        out = engine.generate(reqs)
    for i, r in enumerate(out):
        if r.error is not None:
            print(f"request {i}: prompt[{len(r.prompt)}] FAILED: {r.error}")
            continue
        lat = f" ttft={r.ttft_s*1e3:.0f}ms lat={r.latency_s*1e3:.0f}ms" \
            if r.ttft_s is not None else ""
        prio = f" prio={r.priority}" if len(priorities) > 1 else ""
        print(f"request {i}: prompt[{len(r.prompt)}] -> "
              f"{list(map(int, r.output))}{lat}{prio}")
    thr = engine.scheduler.throughput()
    st = engine.scheduler.stats
    print(f"aggregate: prefill {thr['prefill_tok_s']:.1f} tok/s, "
          f"decode {thr['decode_tok_s']:.1f} tok/s "
          f"({thr['decode_steps']:.0f} decode steps, "
          f"{thr['prefill_calls']:.0f} prefill calls)")
    print(f"prefix sharing: {st['shared_tokens']} prompt tokens "
          f"reused, {st['pages_shared']} pages shared, "
          f"{st['pages_allocated']} pages allocated")
    if st["prefix_partial_hits"]:
        print(f"  token-granular: {st['prefix_partial_hits']} partial-"
              f"page hits, {st['prefix_partial_tokens_shared']} tokens "
              f"reused via fork_partial")
    if st["prefill_chunks"]:
        print(f"chunked prefill: {st['prefill_chunks']} ingest waves at "
              f"budget {args.prefill_chunk_tokens} tokens")
    if st["requests_failed"] or st["preemptions"]:
        print(f"overload: {st['requests_rejected']} rejected, "
              f"{st['requests_failed']} failed, "
              f"{st['preemptions']} preemptions "
              f"({st['pages_spilled']} pages spilled, "
              f"{st['pages_restored']} restored, "
              f"{st['preempt_recomputes']} recompute resumes)")
    def _pcts(hist_name):
        h = engine.obs.metrics.histogram(hist_name)
        if h is None or h.count == 0:
            return None
        p = h.percentiles()
        return (f"p50/p95/p99 = {p['p50']*1e3:.0f}/{p['p95']*1e3:.0f}/"
                f"{p['p99']*1e3:.0f} ms")
    ttft_p, tpot_p = _pcts("request.ttft_s"), _pcts("request.tpot_s")
    if ttft_p or tpot_p:
        print("latency percentiles (registry): "
              + " ".join(f"{k} {v}" for k, v in
                         (("ttft", ttft_p), ("tpot", tpot_p)) if v))
    if args.ttft_slo or args.tpot_slo:
        ok = sum(r.slo_met for r in out)
        print(f"SLO attainment: {ok}/{len(out)} requests met "
              f"ttft<={args.ttft_slo or float('inf'):g}s "
              f"tpot<={args.tpot_slo or float('inf'):g}s")
    if spec:
        es = engine.stats
        print(f"spec decode: {es['tokens_accepted']}/"
              f"{es['tokens_drafted']} drafted tokens accepted "
              f"({100 * es['accept_rate']:.0f}%), "
              f"{es['draft_calls']} draft calls, "
              f"{es['verify_calls']} verify waves")
    if args.stats:
        print("engine stats:")
        for key, val in sorted(engine.stats.items()):
            print(f"  {key} = {val:.4f}" if isinstance(val, float)
                  else f"  {key} = {val}")
    if args.metrics_json:
        import json
        with open(args.metrics_json, "w") as f:
            json.dump(engine.metrics_snapshot(), f, indent=2,
                      default=float)
        print(f"metrics snapshot -> {args.metrics_json}")
    if args.trace_out:
        n = engine.save_trace(args.trace_out)
        print(f"lifecycle trace -> {args.trace_out} ({n} events; open "
              f"at https://ui.perfetto.dev)")
    print(f"steady-state decode probe: "
          f"{engine.throughput_probe(args.max_batch):.1f} tok/s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
