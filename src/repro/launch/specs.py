"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(rcfg)`` returns the abstract inputs for the shape kind:
  train   -> batch dict for train_step
  prefill -> batch dict for prefill_step
  decode  -> (cache, tokens) for serve_step  (one new token against a
             KV/SSM cache of seq_len)

Modality frontends are STUBS per the assignment: vlm gets precomputed patch
embeddings, audio enc-dec gets precomputed frame embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.configs.qwen2_vl_7b import MM_TOKENS
from repro.models import transformer

I32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(rcfg: RunConfig) -> Dict[str, Any]:
    cfg, shp = rcfg.model, rcfg.shape
    B, S = shp.global_batch, shp.seq_len
    dt = jnp.dtype(cfg.dtype)
    batch = {}
    if cfg.family == "encdec":
        batch["src_embeds"] = _sds((B, S, cfg.d_model), dt)
        batch["tokens"] = _sds((B, S), I32)
        batch["labels"] = _sds((B, S), I32)
    elif cfg.frontend == "vision":
        batch["mm_embeds"] = _sds((B, MM_TOKENS, cfg.d_model), dt)
        batch["tokens"] = _sds((B, S - MM_TOKENS), I32)
        batch["labels"] = _sds((B, S - MM_TOKENS), I32)
    else:
        batch["tokens"] = _sds((B, S), I32)
        batch["labels"] = _sds((B, S), I32)
    return batch


def prefill_batch_specs(rcfg: RunConfig) -> Dict[str, Any]:
    b = train_batch_specs(rcfg)
    b.pop("labels", None)
    return b


def decode_specs(rcfg: RunConfig) -> Tuple[Any, Any]:
    cfg, shp = rcfg.model, rcfg.shape
    B, S = shp.global_batch, shp.seq_len
    cache = jax.eval_shape(
        lambda: transformer.init_cache(rcfg, B, S))
    tokens = _sds((B, 1), I32)
    if cfg.family == "encdec":
        # cross-attention context from the encoder (bounded length)
        xa = _sds((B, min(S, 4096), cfg.d_model), jnp.dtype(cfg.dtype))
        return (cache, tokens, xa)
    return (cache, tokens)


def params_specs(rcfg: RunConfig):
    """Abstract model params + optimizer state (eval_shape: no allocation)."""
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda: transformer.init_model(key, rcfg))
    return params


def input_specs(rcfg: RunConfig):
    kind = rcfg.shape.kind
    if kind == "train":
        return train_batch_specs(rcfg)
    if kind == "prefill":
        return prefill_batch_specs(rcfg)
    return decode_specs(rcfg)
