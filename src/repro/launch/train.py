"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch deepseek_7b \
      --shape train_4k --steps 100 [--mesh host|single|multi] \
      [--ckpt DIR] [--serial] [--reduced]

``--mesh host`` (default) runs on the real local device(s) — use
``--reduced`` with it on CPU. ``single``/``multi`` build the production
meshes (requires the 512-device XLA flag; intended for real pods — on this
container use launch/dryrun.py instead, which only lowers).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--mesh", choices=["host", "single", "multi"],
                    default="host")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--serial", action="store_true",
                    help="disable layer-parallel (exact serial baseline)")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-friendly)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data", default="", help="memmap token file")
    args = ap.parse_args(argv)

    if args.mesh == "multi":
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=512")

    from repro.configs import registry
    from repro.configs.reduce import reduce_config
    from repro.launch.mesh import make_production_mesh
    from repro.train.trainer import Trainer

    rcfg = registry.get_config(args.arch, args.shape)
    if args.reduced:
        rcfg = reduce_config(rcfg)
    if args.serial:
        rcfg = dataclasses.replace(
            rcfg, mgrit=dataclasses.replace(rcfg.mgrit, enabled=False))

    mesh = None
    if args.mesh in ("single", "multi"):
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    trainer = Trainer(rcfg, mesh=mesh, ckpt_dir=args.ckpt, seed=args.seed,
                      data_path=args.data)
    report = trainer.train(args.steps, ckpt_every=args.ckpt_every,
                           log_every=10)
    print(f"done: {len(report.losses)} steps, "
          f"final loss {report.losses[-1]:.4f}, "
          f"{report.steps_per_sec:.2f} steps/s, "
          f"switched_at={report.switched_at}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
