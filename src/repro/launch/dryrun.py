import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.analysis import roofline as rl                     # noqa: E402
from repro.configs import registry                            # noqa: E402
from repro.configs.base import SHAPES                         # noqa: E402
from repro.launch import specs as specs_mod                   # noqa: E402
from repro.launch import steps as steps_mod                   # noqa: E402
from repro.launch.mesh import make_production_mesh            # noqa: E402
from repro.optim import optimizers                            # noqa: E402
from repro.parallel.params import batch_specs, param_specs    # noqa: E402

"""Multi-pod dry-run: .lower().compile() of every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOM and unsupported collectives all fail here.
Writes one JSON record per cell under experiments/dryrun/.
"""

OUTDIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                      "experiments", "dryrun")

ASSIGNED = ("zamba2_1p2b", "deepseek_7b", "phi4_mini_3p8b", "qwen3_1p7b",
            "granite_34b", "qwen2_vl_7b", "grok1_314b", "qwen3_moe_235b",
            "seamless_m4t_v2", "falcon_mamba_7b")


def run_cell(arch: str, shape: str, multi_pod: bool, verbose: bool = True,
             mutate=None):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    skip = registry.shape_supported(arch, shape)
    if skip:
        return {"arch": arch, "shape": shape, "mesh": mesh_name,
                "status": "skip", "reason": skip}
    rcfg = registry.get_config(arch, shape)
    if mutate is not None:
        rcfg = mutate(rcfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()

    params_sds = specs_mod.params_specs(rcfg)
    kind = rcfg.shape.kind
    if kind == "train":
        batch_sds = specs_mod.input_specs(rcfg)
        opt_sds = jax.eval_shape(
            lambda p: optimizers.init_opt_state(rcfg.optimizer, p),
            params_sds)
        ps, os_, bs = steps_mod.shardings_for_train(
            rcfg, mesh, params_sds, opt_sds, batch_sds)
        fn = steps_mod.make_train_fn(rcfg, mesh)
        with mesh:
            lowered = jax.jit(fn, in_shardings=(ps, os_, bs)).lower(
                params_sds, opt_sds, batch_sds)
            compiled = lowered.compile()
        tokens = rcfg.shape.global_batch * rcfg.shape.seq_len
    elif kind == "prefill":
        batch_sds = specs_mod.input_specs(rcfg)
        ps = param_specs(params_sds, rcfg, mesh)
        bs = batch_specs(batch_sds, rcfg, mesh)
        fn = steps_mod.make_prefill_fn(rcfg, mesh)
        with mesh:
            lowered = jax.jit(fn, in_shardings=(ps, bs)).lower(
                params_sds, batch_sds)
            compiled = lowered.compile()
        tokens = rcfg.shape.global_batch * rcfg.shape.seq_len
    else:  # decode
        dec = specs_mod.input_specs(rcfg)
        cache_sds, tok_sds = dec[0], dec[1]
        ps, cs, ts = steps_mod.shardings_for_decode(
            rcfg, mesh, params_sds, cache_sds)
        fn = steps_mod.make_serve_fn(rcfg, mesh)
        with mesh:
            if len(dec) == 3:
                xa_sh = batch_specs({"src_embeds": dec[2]}, rcfg, mesh)[
                    "src_embeds"]
                lowered = jax.jit(fn, in_shardings=(ps, cs, ts, xa_sh)) \
                    .lower(params_sds, cache_sds, tok_sds, dec[2])
            else:
                lowered = jax.jit(fn, in_shardings=(ps, cs, ts)).lower(
                    params_sds, cache_sds, tok_sds)
            compiled = lowered.compile()
        tokens = rcfg.shape.global_batch  # one new token per sequence

    mem = compiled.memory_analysis()
    hlo_dir = os.environ.get("REPRO_HLO_DIR", "")
    if hlo_dir:
        import gzip
        os.makedirs(hlo_dir, exist_ok=True)
        tag = f"{arch}__{shape}__{mesh_name}"
        with gzip.open(os.path.join(hlo_dir, tag + ".hlo.gz"), "wt") as f:
            f.write(compiled.as_text())
    roof = rl.from_compiled(arch, shape, mesh_name, chips, compiled, rcfg,
                            tokens)
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "status": "ok",
        "chips": chips,
        "compile_s": round(time.time() - t0, 1),
        "memory_analysis": {
            k: float(getattr(mem, k, 0) or 0) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes")},
        "roofline": json.loads(roof.to_json()),
    }
    if verbose:
        print(f"[{arch} x {shape} x {mesh_name}] compiled in "
              f"{rec['compile_s']}s")
        print("  memory_analysis:", rec["memory_analysis"])
        print("  flops/chip = %.3e  bytes/chip = %.3e  coll/chip = %.3e"
              % (roof.hlo_flops, roof.hlo_bytes, roof.coll_bytes))
        print("  terms (ms): compute=%.2f memory=%.2f collective=%.2f -> %s"
              % (roof.t_compute * 1e3, roof.t_memory * 1e3,
                 roof.t_collective * 1e3, roof.bottleneck))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--outdir", default=OUTDIR)
    args = ap.parse_args(argv)

    archs = ASSIGNED if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.outdir, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                try:
                    rec = run_cell(arch, shape, mp)
                except Exception as e:  # a failure here is a bug in our system
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "FAIL", "error": repr(e)}
                    failures.append(tag)
                with open(os.path.join(args.outdir, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("dry-run: all requested cells compiled")


if __name__ == "__main__":
    main()
