"""Optimizers (AdamW / SGD+momentum) with schedules and global-norm clipping.

Pure pytree implementation (no optax dependency). Optimizer state inherits
the parameter sharding, so FSDP-sharded params get ZeRO-sharded moments for
free. ``moment_dtype`` lets very large models (grok-1) keep m/v in bf16 to
fit the single-pod HBM budget (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


def lr_at(cfg: OptimizerConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "cosine":
        t = jnp.clip((step - cfg.warmup_steps)
                     / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                     0.0, 1.0)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        decay = 0.1 + 0.9 * decay
    elif cfg.schedule == "linear":
        t = jnp.clip((step - cfg.warmup_steps)
                     / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                     0.0, 1.0)
        decay = 1.0 - 0.9 * t
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(
        g.dtype), grads), gn


def init_opt_state(cfg: OptimizerConfig, params, moment_dtype=None):
    if moment_dtype is None:
        moment_dtype = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    state = {"step": jnp.zeros((), jnp.int32)}
    if cfg.name == "adamw" or cfg.name == "adam":
        state["m"] = jax.tree.map(zeros, params)
        state["v"] = jax.tree.map(zeros, params)
    elif cfg.name == "sgd":
        state["m"] = jax.tree.map(zeros, params)
    else:
        raise ValueError(cfg.name)
    # mixed precision: bf16 stored params keep an fp32 master copy here
    if any(p.dtype == jnp.bfloat16 for p in jax.tree.leaves(params)):
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def _freeze_structural(params, grads):
    """Zero the gradients of structural (non-trainable) leaves: the 0/1
    layer gates of the padded ParallelNet. They must neither update nor
    weight-decay."""
    def one(path, g):
        keys = [getattr(k, "key", None) for k in path]
        if keys and keys[-1] == "gate":
            return jnp.zeros_like(g)
        return g
    frozen = jax.tree_util.tree_map_with_path(one, grads)

    def mask(path, p, new_p):
        keys = [getattr(k, "key", None) for k in path]
        return p if (keys and keys[-1] == "gate") else new_p
    return frozen, mask


def apply_updates(cfg: OptimizerConfig, params, grads, state
                  ) -> Tuple[Any, Any, Any]:
    """Returns (new_params, new_state, metrics)."""
    grads, _mask = _freeze_structural(params, grads)
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    mdt = jax.tree.leaves(state["m"])[0].dtype
    stored = params
    if "master" in state:
        params = state["master"]     # update in fp32, cast back at the end

    if cfg.name in ("adamw", "adam"):
        b1, b2 = cfg.beta1, cfg.beta2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v2 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            u = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
            if cfg.name == "adamw":
                u = u + cfg.weight_decay * p.astype(jnp.float32)
            p2 = p.astype(jnp.float32) - lr * u
            return p2.astype(p.dtype), m2.astype(mdt), v2.astype(mdt)

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"step": step, "m": new_m, "v": new_v}
    else:  # sgd + momentum
        def upd(p, g, m):
            m2 = 0.9 * m.astype(jnp.float32) + g.astype(jnp.float32)
            p2 = p.astype(jnp.float32) - lr * m2
            return p2.astype(p.dtype), m2.astype(mdt)

        out = jax.tree.map(upd, params, grads, state["m"])
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"step": step, "m": new_m}

    # structural leaves (layer gates) pass through untouched (no decay)
    new_params = jax.tree_util.tree_map_with_path(
        lambda path, p, np_: _mask(path, p, np_), params, new_params)

    if "master" in state:
        new_state["master"] = new_params
        new_params = jax.tree.map(
            lambda p, s: p.astype(s.dtype), new_params, stored)

    return new_params, new_state, {"grad_norm": gn, "lr": lr}
