"""Pre-LN transformer blocks in neural-ODE form (paper Eq. 1-2).

Each block defines F such that one layer is the forward-Euler step
``Z_{n+1} = Z_n + h * F(t_n, Z_n)``:

  encoder/decoder (Eq. 1):  F = phi1(X) + phi2(X + phi1(X)),
                            phi1 = SA o LN, phi2 = MLP o LN
  enc-dec decoder (Eq. 2):  Ybar = phi1(Y) + phi3(Y + phi1(Y), X_enc)
                            F = Ybar + phi2(Y + Ybar)
  moe:                      phi2 = MoE o LN
  mamba1/mamba2:            F = Mixer o LN  (standard residual SSM block)

Block params are homogeneous within a kind, so they stack over the layer
(time) axis for the MGRIT solver.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.attention import (attention_apply, init_attention,
                                    paged_attention_apply,
                                    paged_view_attention_apply)
from repro.models.layers import init_norm, norm_apply
from repro.models.mlp import init_mlp, mlp_apply
from repro.models.moe import init_moe, moe_apply


def block_kind(cfg: ModelConfig) -> str:
    if cfg.family == "ssm":
        return "mamba1" if cfg.ssm.version == 1 else "mamba2"
    if cfg.family == "hybrid":
        return "mamba2"
    if cfg.moe is not None:
        return "attn_moe"
    return "attn_mlp"


def init_block(key, cfg: ModelConfig, kind: Optional[str] = None):
    kind = kind or block_kind(cfg)
    ks = jax.random.split(key, 8)
    if kind == "mamba1":
        return {"norm": init_norm(cfg), "mixer": ssm_mod.init_mamba1(ks[0], cfg)}
    if kind == "mamba2":
        return {"norm": init_norm(cfg), "mixer": ssm_mod.init_mamba2(ks[0], cfg)}
    p = {
        "ln1": init_norm(cfg),
        "attn": init_attention(ks[0], cfg),
        "ln2": init_norm(cfg),
    }
    if kind == "attn_moe":
        p["moe"] = init_moe(ks[1], cfg)
    elif kind == "encdec_dec":
        p["mlp"] = init_mlp(ks[1], cfg)
        p["ln3"] = init_norm(cfg)
        p["xattn"] = init_attention(ks[2], cfg, cross=True)
    else:
        p["mlp"] = init_mlp(ks[1], cfg)
    return p


def block_F(params, z, cfg: ModelConfig, *, kind: str, causal: bool,
            rope=None, positions=None, xa=None, cache=None,
            use_pallas: bool = False):
    """Evaluate F(t, z). Returns (F_value, new_cache)."""
    if kind in ("mamba1", "mamba2"):
        zn = norm_apply(params["norm"], z, cfg)
        fn = ssm_mod.mamba1_apply if kind == "mamba1" else ssm_mod.mamba2_apply
        f, new_cache = fn(params["mixer"], zn, cfg, cache=cache)
        return f, new_cache

    # phi1 = SA o LN
    a, new_cache = attention_apply(
        params["attn"], norm_apply(params["ln1"], z, cfg), cfg,
        causal=causal, rope=rope, positions=positions, cache=cache,
        use_pallas=use_pallas)
    if kind == "encdec_dec":
        # Ybar = phi1(Y) + phi3(Y + phi1(Y), X)
        ca, _ = attention_apply(
            params["xattn"], norm_apply(params["ln3"], z + a, cfg), cfg,
            causal=False, xa=xa)
        ybar = a + ca
        mlp_in = norm_apply(params["ln2"], z + ybar, cfg)
        f = ybar + mlp_apply(params["mlp"], mlp_in, cfg)
        return f, new_cache

    return attn_block_F(params, z, a, cfg, kind=kind), new_cache


def attn_block_F(params, z, a, cfg: ModelConfig, *, kind: str):
    """F = phi1 + phi2(z + phi1) given the attention output ``a`` = phi1(z).
    Single owner of the attn_mlp/attn_moe block formula — also used by the
    paged serving path (transformer.paged_decode_step), which computes the
    attention differently but must keep the same block form."""
    h_in = norm_apply(params["ln2"], z + a, cfg)
    if kind == "attn_moe":
        return a + moe_apply(params["moe"], h_in, cfg)
    return a + mlp_apply(params["mlp"], h_in, cfg)


def paged_attn_block(params, z, cfg: ModelConfig, *, kind: str, rope,
                     pk, pv, page_table, lengths, n_new, gate=None,
                     fused: bool = False):
    """One attention block step against a layer's KV page pool: the paged
    twin of ``block_step`` for attn_mlp/attn_moe kinds. Single owner of
    the "paged attention + block formula + residual" composition, shared
    by the decoder paged step (transformer.paged_decode_step) and the
    hybrid backbone's interleaved shared-attention block. ``fused``
    selects the flash-decode paged kernel core. Returns
    (z_next, new_pk, new_pv)."""
    a, npk, npv = paged_attention_apply(
        params["attn"], norm_apply(params["ln1"], z, cfg), cfg, rope=rope,
        pk=pk, pv=pv, page_table=page_table, lengths=lengths, n_new=n_new,
        fused=fused)
    f = attn_block_F(params, z, a, cfg, kind=kind)
    scale = jnp.asarray(1.0, z.dtype) if gate is None else gate.astype(z.dtype)
    return z + scale * f, npk, npv


def paged_attn_view_block(params, z, cfg: ModelConfig, *, kind: str, rope,
                          kd, vd, lengths, n_new, gate=None):
    """The deferred-write twin of :func:`paged_attn_block` for the fused
    ref decode path: attention runs over pre-gathered K/V views
    (``attention.paged_view_gather``) and the new K/V rows are returned
    for a single post-scan pool commit (``attention.paged_kv_commit``)
    instead of being scattered into the pool per layer. Same block
    formula, bitwise-equal activations. Returns (z_next, k_new, v_new)."""
    a, k_new, v_new = paged_view_attention_apply(
        params["attn"], norm_apply(params["ln1"], z, cfg), cfg, rope=rope,
        kd=kd, vd=vd, lengths=lengths, n_new=n_new)
    f = attn_block_F(params, z, a, cfg, kind=kind)
    scale = jnp.asarray(1.0, z.dtype) if gate is None else gate.astype(z.dtype)
    return z + scale * f, k_new, v_new


def block_step(params, z, cfg: ModelConfig, *, kind: str, causal: bool,
               h: float = 1.0, gate=None, rope=None, positions=None, xa=None,
               cache=None, use_pallas: bool = False):
    """One Euler step Phi(z) = z + h*gate*F(z). ``gate`` (0/1) marks padded
    identity layers used for layer-parallel divisibility padding."""
    f, new_cache = block_F(params, z, cfg, kind=kind, causal=causal,
                           rope=rope, positions=positions, xa=xa, cache=cache,
                           use_pallas=use_pallas)
    scale = jnp.asarray(h, z.dtype)
    if gate is not None:
        scale = scale * gate.astype(z.dtype)
    return z + scale * f, new_cache
