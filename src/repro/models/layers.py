"""Common neural layers: norms, rotary embeddings, token embeddings.

Everything is purely functional: ``init_*`` builds a params pytree (dict of
jnp arrays), ``*_apply``-style functions consume it. No framework dependency;
pytrees compose with vmap for stacked-layer (neural ODE time grid) weights.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, dim: Optional[int] = None):
    d = dim or cfg.d_model
    p = {"scale": jnp.ones((d,), dtype=jnp.dtype(cfg.param_dtype))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype=jnp.dtype(cfg.param_dtype))
    return p


def norm_apply(params, x, cfg: ModelConfig, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    return y.astype(dtype)


def rms_norm(x, scale, eps: float = 1e-6):
    """Bare RMSNorm used for qk-norm (per-head)."""
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float, positions: jnp.ndarray):
    """positions: int32 (..., S). Returns cos/sin of shape (..., S, head_dim//2)."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D). cos/sin: (S, D/2) or (B, S, D/2), broadcast over H."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:       # (S, D/2)
        c, s = cos[None, :, None, :], sin[None, :, None, :]
    else:                   # (B, S, D/2)
        c, s = cos[:, :, None, :], sin[:, :, None, :]
    c, s = c.astype(x.dtype), s.astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def init_embedding(key, cfg: ModelConfig):
    pdt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    p = {"tok": jax.random.normal(k1, (cfg.vocab_size, cfg.d_model), pdt) * 0.02}
    if not cfg.tie_embeddings:
        p["out"] = jax.random.normal(k2, (cfg.vocab_size, cfg.d_model), pdt) * 0.02
    return p


def embed_tokens(params, tokens, cfg: ModelConfig):
    emb = params["tok"].astype(jnp.dtype(cfg.dtype))
    return jnp.take(emb, tokens, axis=0)


def unembed(params, x, cfg: ModelConfig):
    w = params.get("out", params["tok"]).astype(jnp.dtype(cfg.dtype))
    return jnp.einsum("...d,vd->...v", x, w)


# ---------------------------------------------------------------------------
# Linear init helpers (pre-LN scaled init, Wang et al. 2024 / paper App. C)
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: float = 0.02):
    return jax.random.normal(key, shape, jnp.dtype(dtype)) * scale


def preln_output_scale(n_layers: int) -> float:
    """Paper App. C: scale MLP/value/output projections by sqrt(log 2L)
    (DeepNet-style stabilization for very deep pre-LN nets). Used as a
    *divisor* on init std to keep the residual stream bounded."""
    import math
    return 1.0 / max(1.0, math.sqrt(math.log(2 * max(n_layers, 1))))
