"""Top-k Mixture-of-Experts FFN (GShard-style capacity dispatch).

The dispatch/combine are expressed as dense einsums over an ``experts``
logical axis so GSPMD inserts the expert-parallel all_to_all when the axis is
sharded (qwen3-moe: 128 experts over the 16-way data axis). FLOPs scale with
capacity (≈ top_k/num_experts of dense-all-experts), matching the paper's
6·N_active·D accounting.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, preln_output_scale
from repro.parallel.sharding import logical_constraint

CAPACITY_FACTOR = 1.25


def init_moe(key, cfg: ModelConfig):
    assert cfg.moe is not None
    d = cfg.d_model
    e = cfg.moe.num_experts
    ff = cfg.moe.d_ff or cfg.d_ff
    ks = jax.random.split(key, 4)
    oscale = 0.02 * preln_output_scale(cfg.n_layers)
    return {
        "router": dense_init(ks[0], (d, e), cfg.param_dtype),
        "w_in": dense_init(ks[1], (e, d, ff), cfg.param_dtype),
        "w_gate": dense_init(ks[2], (e, d, ff), cfg.param_dtype),
        "w_out": dense_init(ks[3], (e, ff, d), cfg.param_dtype, scale=oscale),
    }


def capacity(seq: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(math.ceil(seq * m.top_k / m.num_experts * CAPACITY_FACTOR))
    return max(4, min(seq, c))


def moe_apply(params, x, cfg: ModelConfig):
    """x: (B, S, D) -> (B, S, D). With ``group_size`` set, the sequence is
    split into GShard-style groups so the (B,S,E,C) dispatch/combine
    tensors shrink by S/group_size (capacity is per-group) — a §Perf
    optimization; routing quality is per-group instead of per-sequence."""
    m = cfg.moe
    g = m.group_size
    if g and x.shape[1] > g and x.shape[1] % g == 0:
        B0, S0, D0 = x.shape
        xg = x.reshape(B0 * (S0 // g), g, D0)
        y = _moe_dense(params, xg, cfg)
        return y.reshape(B0, S0, D0)
    return _moe_dense(params, x, cfg)


def _moe_dense(params, x, cfg: ModelConfig):
    with jax.named_scope("moe_core"):
        return _moe_dense_inner(params, x, cfg)


def _moe_dense_inner(params, x, cfg: ModelConfig):
    m = cfg.moe
    dt = jnp.dtype(cfg.dtype)
    x = x.astype(dt)
    B, S, D = x.shape
    E, K, C = m.num_experts, m.top_k, capacity(S, cfg)

    logits = jnp.einsum("bsd,de->bse", x, params["router"].astype(dt))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)           # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # Build dispatch/combine tensors (B,S,E,C).
    dispatch = jnp.zeros((B, S, E, C), dtype=jnp.bool_)
    combine = jnp.zeros((B, S, E, C), dtype=jnp.float32)
    # per-(expert) running position counters, choice-major like GShard
    onehot_k = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)  # (B,S,K,E)
    prio = onehot_k.transpose(0, 2, 1, 3).reshape(B, K * S, E)  # choice-major
    pos_in_e = jnp.cumsum(prio, axis=1) - prio                  # (B,K*S,E)
    pos_in_e = pos_in_e.reshape(B, K, S, E).transpose(0, 2, 1, 3)  # (B,S,K,E)
    for k in range(K):
        oh = onehot_k[:, :, k, :]                               # (B,S,E)
        pos = jnp.sum(pos_in_e[:, :, k, :] * oh, axis=-1)       # (B,S)
        keep = (jnp.sum(pos_in_e[:, :, k, :] * oh, -1) < C) & (
            jnp.sum(oh, -1) > 0)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                                dtype=jnp.float32)[..., :C]     # (B,S,C)
        d_k = oh.astype(jnp.float32)[..., None] * pos_oh[:, :, None, :]
        dispatch = dispatch | (d_k > 0)
        combine = combine + d_k * gate_vals[:, :, k, None, None]

    xe = jnp.einsum("bsec,bsd->ebcd", dispatch.astype(dt), x)   # (E,B,C,D)
    xe = logical_constraint(xe, ("experts", "batch", None, "embed"))
    h = jnp.einsum("ebcd,edf->ebcf", xe, params["w_in"].astype(dt))
    g = jnp.einsum("ebcd,edf->ebcf", xe, params["w_gate"].astype(dt))
    h = jax.nn.silu(g) * h
    h = logical_constraint(h, ("experts", "batch", None, "mlp"))
    ye = jnp.einsum("ebcf,efd->ebcd", h, params["w_out"].astype(dt))
    y = jnp.einsum("bsec,ebcd->bsd", combine.astype(dt), ye)
    return logical_constraint(y, ("batch", "seq", "embed"))


def load_balance_loss(logits, gate_idx, cfg: ModelConfig):
    """Switch-style auxiliary loss (used by the serial training path)."""
    m = cfg.moe
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    me = jnp.mean(probs, axis=(0, 1))
    oh = jax.nn.one_hot(gate_idx[..., 0], m.num_experts)
    ce = jnp.mean(oh, axis=(0, 1))
    return m.num_experts * jnp.sum(me * ce) * m.aux_loss_weight
