"""Mamba1 (falcon-mamba) and Mamba2 (zamba2 backbone) state-space blocks.

The reference sequence mixer is a ``lax.scan`` over time (memory-light,
exactly the recurrence); the perf-critical chunked scan for TPU lives in
:mod:`repro.kernels.ssm_scan`. Decode carries an O(1) cache
(conv window + SSM state) — this is why the ssm/hybrid archs run the
``long_500k`` shape.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.parallel.sharding import logical_constraint


def _dt_rank(cfg: ModelConfig) -> int:
    return cfg.ssm.dt_rank or int(math.ceil(cfg.d_model / 16))


# ---------------------------------------------------------------------------
# Mamba 1
# ---------------------------------------------------------------------------


def init_mamba1(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    dtr = _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    pdt = cfg.param_dtype
    A = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32), (di, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), pdt),
        "conv_w": dense_init(ks[1], (s.d_conv, di), pdt, scale=0.1),
        "conv_b": jnp.zeros((di,), jnp.dtype(pdt)),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * s.d_state), pdt),
        "dt_proj": dense_init(ks[3], (dtr, di), pdt, scale=dtr ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (di,)) * 0.1 + 1e-3, 1e-4))).astype(jnp.dtype(pdt)),
        "A_log": jnp.log(A).astype(jnp.dtype(pdt)),
        "D": jnp.ones((di,), jnp.dtype(pdt)),
        "out_proj": dense_init(ks[5], (di, d), pdt),
    }


def _causal_conv(x, w, b, cache=None):
    """Depthwise causal conv. x: (B,S,di), w: (K,di). cache: (B,K-1,di)."""
    K = w.shape[0]
    if cache is not None:
        xp = jnp.concatenate([cache, x], axis=1)
        new_cache = xp[:, -(K - 1):, :] if K > 1 else cache
    else:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
        new_cache = None
    S = x.shape[1]
    out = sum(xp[:, i:i + S, :] * w[i][None, None, :] for i in range(K))
    return out + b[None, None, :], new_cache


def mamba1_apply(params, x, cfg: ModelConfig, cache: Optional[dict] = None):
    """x: (B,S,D) -> (B,S,D). cache: {"conv": (B,K-1,di), "h": (B,di,ds)}."""
    with jax.named_scope("ssm_core"):
        return _mamba1_apply(params, x, cfg, cache)


def _mamba1_apply(params, x, cfg: ModelConfig, cache: Optional[dict] = None):
    s = cfg.ssm
    dt_ = jnp.dtype(cfg.dtype)
    x = x.astype(dt_)
    B, S, D = x.shape
    di = s.expand * D
    dtr = _dt_rank(cfg)

    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt_))
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = logical_constraint(xin, ("batch", "seq", "mlp"))
    conv_cache = cache["conv"] if cache is not None else None
    xc, new_conv = _causal_conv(xin, params["conv_w"].astype(dt_),
                                params["conv_b"].astype(dt_), conv_cache)
    xc = jax.nn.silu(xc)

    dbc = jnp.einsum("bse,ef->bsf", xc, params["x_proj"].astype(dt_))
    dtr_v, Bm, Cm = jnp.split(dbc, [dtr, dtr + s.d_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dtr_v, params["dt_proj"].astype(dt_))
        + params["dt_bias"].astype(dt_))                       # (B,S,di)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))          # (di,ds)

    dt32, xc32 = dt.astype(jnp.float32), xc.astype(jnp.float32)
    B32, C32 = Bm.astype(jnp.float32), Cm.astype(jnp.float32)

    def step(h, inp):
        dt_t, x_t, b_t, c_t = inp                              # (B,di),(B,di),(B,ds),(B,ds)
        dA = jnp.exp(dt_t[:, :, None] * A[None])               # (B,di,ds)
        h = dA * h + dt_t[:, :, None] * b_t[:, None, :] * x_t[:, :, None]
        y = jnp.einsum("bes,bs->be", h, c_t)
        return h, y

    h0 = cache["h"] if cache is not None else jnp.zeros(
        (B, di, s.d_state), jnp.float32)
    xs = (dt32.transpose(1, 0, 2), xc32.transpose(1, 0, 2),
          B32.transpose(1, 0, 2), C32.transpose(1, 0, 2))
    hN, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2).astype(dt_)                      # (B,S,di)
    y = y + params["D"].astype(dt_)[None, None, :] * xc
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt_))
    out = logical_constraint(out, ("batch", "seq", "embed"))
    new_cache = {"conv": new_conv, "h": hN} if cache is not None else None
    return out, new_cache


def init_mamba1_cache(cfg: ModelConfig, batch: int, n_layers: int):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    return {
        "conv": jnp.zeros((n_layers, batch, s.d_conv - 1, di), jnp.dtype(cfg.dtype)),
        "h": jnp.zeros((n_layers, batch, di, s.d_state), jnp.float32),
    }


# ---------------------------------------------------------------------------
# Paged recurrent state (serving)
# ---------------------------------------------------------------------------
#
# The serve engine treats SSM decode state the same way it treats paged KV:
# a pool of fixed-size pages managed by the refcounted PageAllocator
# (repro.serve.kv_pages). A "page" here is not page_size tokens of KV but a
# full per-slot state *snapshot* — page p of a slot holds the (conv window,
# h) state after exactly (p+1)*page_size tokens. Decode reads the state of
# position ``lengths`` from page (lengths-1)//page_size (the page whose
# last write was position lengths-1) and writes the advanced state into
# page lengths//page_size, so crossing a page boundary leaves the completed
# page holding its boundary snapshot — exactly what the prefix trie
# publishes, and what a later request with the same prompt prefix resumes
# from (after a copy-on-write fork if it must write into it). Page 0 is
# the scratch page: writes from idle slots and padded prompt positions
# land there, and reads at position 0 are masked to the zero state.
#
# Two contract notes for the PR-10 serve features (no model change was
# needed for either):
# - Chunked prefill resumes exactly: the state after `lengths` tokens is
#   always readable from page (lengths-1)//page_size even mid-page (the
#   in-progress page holds the running snapshot), so splitting a prompt
#   into budget-bounded chunks replays the identical recurrence.
# - Token-granular partial sharing (`CacheBackend.fork_partial`) does
#   NOT apply here: a snapshot page has no "first n tokens" to reuse —
#   it is only meaningful as the state after the full page — so snapshot
#   backends raise and the scheduler falls back to whole-page matching
#   (docs/cache-backends.md).


def constrain_pools(conv_pool, h_pool, *, stacked: bool = False):
    """Pin snapshot pools to their logical mesh axes (pages over the
    serving DP axis, inner/head dims over TP) so jitted steps keep the
    pools sharded instead of decaying to replicated. ``stacked=True``
    for (L, n_pages, ...) trees (a leading layer axis); mamba1 h pools
    are rank 3 per layer, mamba2 rank 4. No-op without active rules."""
    pre = (None,) if stacked else ()
    conv_pool = logical_constraint(conv_pool, pre + ("pages", None, "mlp"))
    h_axes = ("pages", "mlp", None) if h_pool.ndim == len(pre) + 3 \
        else ("pages", "heads", None, None)
    return conv_pool, logical_constraint(h_pool, pre + h_axes)


def paged_state_read(pool, page_table, lengths, page_size: int):
    """Per-slot incoming state: pool page holding the snapshot after
    ``lengths`` tokens (zeros for slots at position 0). pool: (n_pages,
    ...); page_table: (B, P); lengths: (B,). Returns (B, ...)."""
    P = page_table.shape[1]
    slot = jnp.clip((lengths - 1) // page_size, 0, P - 1)
    prev = jnp.take_along_axis(page_table, slot[:, None], axis=1)[:, 0]
    init = pool[prev]
    live = (lengths > 0).reshape((-1,) + (1,) * (init.ndim - 1))
    return jnp.where(live, init, jnp.zeros_like(init))


def snapshot_steps(page_table, lengths, n_new, page_size: int):
    """Which pages this call finalizes, and at which local step.

    For slot b processing positions lengths[b] .. lengths[b]+n_new[b]-1,
    page-slot p receives its final write at local step
    ``min((p+1)*page_size-1, last_pos) - lengths`` iff p overlaps the
    written range. Returns (t (B, P) local step indices, phys (B, P)
    physical page ids with unwritten entries routed to scratch page 0).
    """
    B, P = page_table.shape
    last = lengths + n_new - 1
    p = jnp.arange(P)[None, :]
    t = jnp.minimum((p + 1) * page_size - 1, last[:, None]) - lengths[:, None]
    written = (n_new[:, None] > 0) & (p >= (lengths // page_size)[:, None]) \
        & (p <= (last // page_size)[:, None])
    phys = jnp.where(written, page_table, 0)
    return jnp.clip(t, 0, None), phys


def compact_snapshot_steps(page_table, lengths, n_new, page_size: int,
                           seq_len: int):
    """Compact twin of :func:`snapshot_steps` for the fused decode path.

    A call processing ``seq_len`` tokens can finalize at most
    W = ``max_write_pages(seq_len, page_size)`` snapshot pages per slot —
    the contiguous page-table slots ``lengths//page_size ..
    last//page_size`` — yet the full plan scatters all (B, P) pairs,
    burying the handful of real writes under B*(P-W) rewrites of scratch
    page 0. This returns the same (t, phys) contract restricted to those
    W slots: (t (B, W) local snapshot steps, phys (B, W) physical pages,
    unwritten entries routed to scratch page 0). Every real page the full
    plan writes is covered with an identical snapshot step, so pools
    committed through either plan agree everywhere except page 0.
    """
    from repro.kernels.paged_ssm import max_write_pages
    W = max_write_pages(seq_len, page_size)
    B, P = page_table.shape
    last = lengths + n_new - 1
    wslot = (lengths // page_size)[:, None] + jnp.arange(W)[None, :]
    written = (n_new[:, None] > 0) & (wslot <= (last // page_size)[:, None]) \
        & (wslot < P)
    phys = jnp.where(written, jnp.take_along_axis(
        page_table, jnp.clip(wslot, 0, P - 1), axis=1), 0)
    t = jnp.minimum((wslot + 1) * page_size - 1, last[:, None]) \
        - lengths[:, None]
    return jnp.clip(t, 0, None), phys


def paged_read_plan(page_table, lengths, page_size: int):
    """The (read_page, live) pair :func:`paged_state_read` resolves —
    exposed separately so the fused kernel can do the page read itself."""
    P = page_table.shape[1]
    slot = jnp.clip((lengths - 1) // page_size, 0, P - 1)
    prev = jnp.take_along_axis(page_table, slot[:, None], axis=1)[:, 0]
    return prev, lengths > 0


def paged_state_read_stacked(pool, page_table, lengths, page_size: int):
    """Every layer's incoming state in ONE gather: (L, n_pages, ...) ->
    (L, B, ...). The fused ref decode path reads the whole stack up front
    so the layer scan never carries the pools (see
    :func:`paged_pools_commit_compact` for why); same masking contract as
    :func:`paged_state_read`."""
    prev, live = paged_read_plan(page_table, lengths, page_size)
    init = pool[:, prev]
    mask = live.reshape((1, -1) + (1,) * (init.ndim - 2))
    return jnp.where(mask, init, jnp.zeros_like(init))


def paged_pools_commit_compact(pools, xp_all, hs_all, *, page_table,
                               lengths, n_new, page_size: int):
    """Deferred compact commit for the whole layer stack: one scatter per
    pool (in-place when the state is donated) publishes every layer's
    boundary snapshots into the W compact write slots.

    Shipping the stacked (L, n_pages, ...) pools through the layer scan
    as xs/ys costs two full-pool copies per step no matter how few pages
    change; the fused ref path instead runs the mixers with
    ``state_in`` from :func:`paged_state_read_stacked`, collects the
    per-layer artifacts (xp_all (L, B, S+K-1, C), hs_all (L, B, S, ...))
    as scan outputs, and commits here. Snapshot extraction matches
    :func:`paged_pool_commit` with the compact plan, so committed pages
    are bitwise those of the in-scan path everywhere except scratch
    page 0. Returns {"conv", "h"}."""
    conv_pool, h_pool = pools["conv"], pools["h"]
    L = conv_pool.shape[0]
    K = conv_pool.shape[-2] + 1
    S = hs_all.shape[2]
    t_w, phys_w = compact_snapshot_steps(page_table, lengths, n_new,
                                         page_size, S)
    B, W = phys_w.shape
    h_snap = hs_all[:, jnp.arange(B)[:, None], t_w]           # (L, B, W, ..)
    widx = t_w[:, :, None] + jnp.arange(1, K)[None, None, :]  # (B, W, K-1)
    conv_snap = xp_all[:, jnp.arange(B)[:, None, None], widx]
    flat = phys_w.reshape(-1)
    new_h = h_pool.at[:, flat].set(
        h_snap.astype(h_pool.dtype).reshape((L, B * W) + h_pool.shape[2:]))
    new_conv = conv_pool.at[:, flat].set(
        conv_snap.astype(conv_pool.dtype).reshape(
            (L, B * W) + conv_pool.shape[2:]))
    new_conv, new_h = constrain_pools(new_conv, new_h, stacked=True)
    return {"conv": new_conv, "h": new_h}


def paged_state_write(pool, snaps, phys):
    """Scatter per-(slot, page) snapshots into the pool. snaps: (B, P, ...)
    aligned with phys from :func:`snapshot_steps`; duplicate scratch-page
    writes are harmless (scratch is never read as real state)."""
    B, P = phys.shape
    flat = snaps.reshape((B * P,) + snaps.shape[2:]).astype(pool.dtype)
    return pool.at[phys.reshape(-1)].set(flat)


def _gather_windows(xp, t, K: int):
    """Conv-window snapshots: window after local step t = inputs at
    xp[:, t+1 : t+K] (xp = [init window | new inputs], length K-1+S).
    xp: (B, S+K-1, C); t: (B, P). Returns (B, P, K-1, C)."""
    B = xp.shape[0]
    idx = t[:, :, None] + jnp.arange(1, K)[None, None, :]
    return xp[jnp.arange(B)[:, None, None], idx]


def paged_pool_commit(conv_pool, h_pool, xp, hs_b, *, page_table, lengths,
                      n_new, page_size: int):
    """Publish one layer's state snapshots for the first ``n_new[b]`` of
    the tokens a paged apply just processed. ``xp`` is the padded conv
    input ([init window | new inputs], (B, S+K-1, C)) and ``hs_b`` the
    per-step recurrent states ((B, S, ...)) that
    ``mamba{1,2}_paged_apply(..., commit=False)`` returns — every local
    step's state is a candidate snapshot, so the caller may commit any
    prefix of the processed tokens. Speculative decoding uses exactly
    this: verification runs the recurrence over all k+1 drafted tokens,
    then commits only the accepted prefix (``n_new = accepted + 1``) —
    the snapshot-page twin of "truncate lengths" KV rollback. Returns
    (new_conv_pool, new_h_pool).
    """
    K = conv_pool.shape[-2] + 1
    t, phys = snapshot_steps(page_table, lengths, n_new, page_size)
    B = phys.shape[0]
    h_snap = hs_b[jnp.arange(B)[:, None], t]
    new_h = paged_state_write(h_pool, h_snap, phys)
    new_conv = paged_state_write(conv_pool, _gather_windows(xp, t, K), phys)
    return new_conv, new_h


def init_paged_ssm_pool(cfg: ModelConfig, n_layers: int, n_pages: int,
                        version: int):
    """State-snapshot page pool stacked over layers (page axis 1, matching
    the paged KV layout so one COW copy covers every backend)."""
    s = cfg.ssm
    di = s.expand * cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    if version == 1:
        return {
            "conv": jnp.zeros((n_layers, n_pages, s.d_conv - 1, di), dt),
            "h": jnp.zeros((n_layers, n_pages, di, s.d_state), jnp.float32),
        }
    nh = di // s.headdim
    ci = di + 2 * s.d_state
    return {
        "conv": jnp.zeros((n_layers, n_pages, s.d_conv - 1, ci), dt),
        "h": jnp.zeros((n_layers, n_pages, nh, s.headdim, s.d_state),
                       jnp.float32),
    }


def mamba1_paged_apply(params, x, cfg: ModelConfig, *, conv_pool, h_pool,
                       page_table, lengths, n_new, page_size: int,
                       commit: bool = True, fused: bool = False,
                       state_in=None):
    """One layer's mamba1 mixer against the paged state pool.

    x: (B, S, D) normed block input; slot b contributes ``n_new[b] <= S``
    real tokens starting at absolute position ``lengths[b]`` (``n_new == 0``
    marks an idle slot — its state is untouched). conv_pool: (n_pages,
    K-1, di); h_pool: (n_pages, di, d_state). Returns (mixer output
    (B, S, D), new_conv_pool, new_h_pool). Outputs at padded positions are
    garbage; the caller reads position n_new-1 only.

    ``commit=False`` defers the state-page writes: returns (out, xp,
    hs_b) — the per-step snapshot candidates — and leaves the pools
    untouched; the caller publishes an accepted prefix later via
    :func:`paged_pool_commit` (speculative-decode verification).

    ``fused=True`` (commit path only) runs the recurrence and the
    snapshot commit through the paged SSM kernel
    (:func:`repro.kernels.ops.paged_ssm_update`): the initial state is
    read and the boundary snapshots written in-kernel from the *compact*
    plan (W pages per slot instead of P), with identical product order —
    outputs and non-scratch pool pages stay bitwise-equal to this
    gathered path.

    ``state_in=(win0, h0)`` supplies the incoming conv window / SSM state
    directly (pre-gathered across layers via
    :func:`paged_state_read_stacked`) so the pools are never touched here
    — pass ``conv_pool=h_pool=None`` with ``commit=False`` and publish
    the returned artifacts through :func:`paged_pools_commit_compact`.
    """
    s = cfg.ssm
    dt_ = jnp.dtype(cfg.dtype)
    x = x.astype(dt_)
    B, S, D = x.shape
    dtr = _dt_rank(cfg)

    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt_))
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = logical_constraint(xin, ("batch", "seq", "mlp"))
    K = params["conv_w"].shape[0]
    win0 = state_in[0] if state_in is not None else \
        paged_state_read(conv_pool, page_table, lengths, page_size)
    xp = jnp.concatenate([win0.astype(dt_), xin], axis=1)
    w, b = params["conv_w"].astype(dt_), params["conv_b"].astype(dt_)
    xc = sum(xp[:, i:i + S, :] * w[i][None, None, :] for i in range(K))
    xc = jax.nn.silu(xc + b[None, None, :])

    dbc = jnp.einsum("bse,ef->bsf", xc, params["x_proj"].astype(dt_))
    dtr_v, Bm, Cm = jnp.split(dbc, [dtr, dtr + s.d_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dtr_v, params["dt_proj"].astype(dt_))
        + params["dt_bias"].astype(dt_))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    dt32, xc32 = dt.astype(jnp.float32), xc.astype(jnp.float32)
    B32, C32 = Bm.astype(jnp.float32), Cm.astype(jnp.float32)
    valid = jnp.arange(S)[None, :] < n_new[:, None]            # (B, S)

    if fused and commit:
        from repro.kernels import ops as kops
        t_w, phys_w = compact_snapshot_steps(page_table, lengths, n_new,
                                             page_size, S)
        read_page, live = paged_read_plan(page_table, lengths, page_size)
        ys_b, new_h = kops.paged_ssm_update(
            dt32, xc32, B32, C32, A, h_pool, read_page, live, phys_w, t_w,
            n_new, order="dbx")
        y = ys_b.astype(dt_)
    else:
        def step(h, inp):
            dt_t, x_t, b_t, c_t, v_t = inp
            dA = jnp.exp(dt_t[:, :, None] * A[None])
            h2 = dA * h + dt_t[:, :, None] * b_t[:, None, :] * x_t[:, :, None]
            h = jnp.where(v_t[:, None, None], h2, h)  # padding: state frozen
            y = jnp.einsum("bes,bs->be", h, c_t)
            return h, (h, y)

        h0 = state_in[1] if state_in is not None else \
            paged_state_read(h_pool, page_table, lengths, page_size)
        xs = (dt32.transpose(1, 0, 2), xc32.transpose(1, 0, 2),
              B32.transpose(1, 0, 2), C32.transpose(1, 0, 2), valid.T)
        _, (hs, ys) = jax.lax.scan(step, h0, xs)
        y = ys.transpose(1, 0, 2).astype(dt_)
    y = y + params["D"].astype(dt_)[None, None, :] * xc
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt_))
    out = logical_constraint(out, ("batch", "seq", "embed"))

    if not commit:
        return out, xp, jnp.swapaxes(hs, 0, 1)                 # (B, S, ...)
    if fused:
        K = conv_pool.shape[-2] + 1
        new_conv = paged_state_write(conv_pool,
                                     _gather_windows(xp, t_w, K), phys_w)
    else:
        new_conv, new_h = paged_pool_commit(
            conv_pool, h_pool, xp, jnp.swapaxes(hs, 0, 1),
            page_table=page_table, lengths=lengths, n_new=n_new,
            page_size=page_size)
    new_conv, new_h = constrain_pools(new_conv, new_h)
    return out, new_conv, new_h


def mamba2_paged_apply(params, x, cfg: ModelConfig, *, conv_pool, h_pool,
                       page_table, lengths, n_new, page_size: int,
                       commit: bool = True, fused: bool = False,
                       state_in=None):
    """Mamba2 twin of :func:`mamba1_paged_apply` (same pool contract —
    including ``state_in`` deferred I/O; conv runs over the concatenated
    x/B/C channels, h is per-head).

    The fused path flattens (heads, headdim) to the kernel's rows axis —
    per-head dt and A tile across headdim (identical elementwise bits)
    and the (n_pages, nh, headdim, ds) h pool reshapes to rows and back,
    with the mamba2 product order ``"dxb"``.
    """
    s = cfg.ssm
    dt_ = jnp.dtype(cfg.dtype)
    x = x.astype(dt_)
    B, S, D = x.shape
    di = s.expand * D
    nh = di // s.headdim

    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt_))
    z, xbc, dt = jnp.split(proj, [di, 2 * di + 2 * s.d_state], axis=-1)
    K = params["conv_w"].shape[0]
    win0 = state_in[0] if state_in is not None else \
        paged_state_read(conv_pool, page_table, lengths, page_size)
    xp = jnp.concatenate([win0.astype(dt_), xbc], axis=1)
    w, b = params["conv_w"].astype(dt_), params["conv_b"].astype(dt_)
    xbc = sum(xp[:, i:i + S, :] * w[i][None, None, :] for i in range(K))
    xbc = jax.nn.silu(xbc + b[None, None, :])
    xin, Bm, Cm = jnp.split(xbc, [di, di + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    xh = xin.reshape(B, S, nh, s.headdim).astype(jnp.float32)
    B32, C32 = Bm.astype(jnp.float32), Cm.astype(jnp.float32)
    valid = jnp.arange(S)[None, :] < n_new[:, None]

    if fused and commit:
        from repro.kernels import ops as kops
        R = nh * s.headdim
        t_w, phys_w = compact_snapshot_steps(page_table, lengths, n_new,
                                             page_size, S)
        read_page, live = paged_read_plan(page_table, lengths, page_size)
        A_rows = jnp.broadcast_to(
            jnp.repeat(A, s.headdim)[:, None], (R, s.d_state))
        ys_r, new_h_rows = kops.paged_ssm_update(
            jnp.repeat(dt, s.headdim, axis=-1), xh.reshape(B, S, R),
            B32, C32, A_rows, h_pool.reshape(-1, R, s.d_state),
            read_page, live, phys_w, t_w, n_new, order="dxb")
        new_h = new_h_rows.reshape(h_pool.shape)
        y = ys_r.reshape(B, S, nh, s.headdim)
    else:
        def step(h, inp):
            dt_t, x_t, b_t, c_t, v_t = inp
            dA = jnp.exp(dt_t * A[None])
            h2 = dA[:, :, None, None] * h \
                + (dt_t[:, :, None] * x_t)[..., None] * b_t[:, None, None, :]
            h = jnp.where(v_t[:, None, None, None], h2, h)
            y = jnp.einsum("bhes,bs->bhe", h, c_t)
            return h, (h, y)

        h0 = state_in[1] if state_in is not None else \
            paged_state_read(h_pool, page_table, lengths, page_size)
        xs = (dt.transpose(1, 0, 2), xh.transpose(1, 0, 2, 3),
              B32.transpose(1, 0, 2), C32.transpose(1, 0, 2), valid.T)
        _, (hs, ys) = jax.lax.scan(step, h0, xs)
        y = ys.transpose(1, 0, 2, 3)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(B, S, di).astype(dt_)
    y = y * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6)
         * params["norm_scale"].astype(jnp.float32)).astype(dt_)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt_))
    out = logical_constraint(out, ("batch", "seq", "embed"))

    if not commit:
        return out, xp, jnp.swapaxes(hs, 0, 1)
    if fused:
        K = conv_pool.shape[-2] + 1
        new_conv = paged_state_write(conv_pool,
                                     _gather_windows(xp, t_w, K), phys_w)
    else:
        new_conv, new_h = paged_pool_commit(
            conv_pool, h_pool, xp, jnp.swapaxes(hs, 0, 1),
            page_table=page_table, lengths=lengths, n_new=n_new,
            page_size=page_size)
    new_conv, new_h = constrain_pools(new_conv, new_h)
    return out, new_conv, new_h


# ---------------------------------------------------------------------------
# Mamba 2 (SSD, scalar per-head decay, single B/C group)
# ---------------------------------------------------------------------------


def init_mamba2(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    nh = di // s.headdim
    ks = jax.random.split(key, 4)
    pdt = cfg.param_dtype
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * s.d_state + nh), pdt),
        "conv_w": dense_init(ks[1], (s.d_conv, di + 2 * s.d_state), pdt, scale=0.1),
        "conv_b": jnp.zeros((di + 2 * s.d_state,), jnp.dtype(pdt)),
        "dt_bias": jnp.zeros((nh,), jnp.dtype(pdt)),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)).astype(jnp.dtype(pdt)),
        "D": jnp.ones((nh,), jnp.dtype(pdt)),
        "norm_scale": jnp.ones((di,), jnp.dtype(pdt)),
        "out_proj": dense_init(ks[2], (di, d), pdt),
    }


def mamba2_apply(params, x, cfg: ModelConfig, cache: Optional[dict] = None):
    """Mamba2 SSD mixer. cache: {"conv": (B,K-1,ci), "h": (B,nh,hd,ds)}."""
    with jax.named_scope("ssm_core"):
        return _mamba2_apply(params, x, cfg, cache)


def _mamba2_apply(params, x, cfg: ModelConfig, cache: Optional[dict] = None):
    s = cfg.ssm
    dt_ = jnp.dtype(cfg.dtype)
    x = x.astype(dt_)
    B, S, D = x.shape
    di = s.expand * D
    nh = di // s.headdim

    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt_))
    z, xbc, dt = jnp.split(proj, [di, 2 * di + 2 * s.d_state], axis=-1)
    conv_cache = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"].astype(dt_),
                                 params["conv_b"].astype(dt_), conv_cache)
    xbc = jax.nn.silu(xbc)
    xin, Bm, Cm = jnp.split(xbc, [di, di + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,S,nh)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))              # (nh,)

    xh = xin.reshape(B, S, nh, s.headdim).astype(jnp.float32)
    B32, C32 = Bm.astype(jnp.float32), Cm.astype(jnp.float32)

    def step(h, inp):
        dt_t, x_t, b_t, c_t = inp            # (B,nh),(B,nh,hd),(B,ds),(B,ds)
        dA = jnp.exp(dt_t * A[None])         # (B,nh)
        h = dA[:, :, None, None] * h + (dt_t[:, :, None] * x_t)[..., None] \
            * b_t[:, None, None, :]
        y = jnp.einsum("bhes,bs->bhe", h, c_t)
        return h, y

    h0 = cache["h"] if cache is not None else jnp.zeros(
        (B, nh, s.headdim, s.d_state), jnp.float32)
    xs = (dt.transpose(1, 0, 2), xh.transpose(1, 0, 2, 3),
          B32.transpose(1, 0, 2), C32.transpose(1, 0, 2))
    hN, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3)                                   # (B,S,nh,hd)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(B, S, di).astype(dt_)
    # gated RMSNorm (mamba2)
    y = y * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6)
         * params["norm_scale"].astype(jnp.float32)).astype(dt_)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt_))
    out = logical_constraint(out, ("batch", "seq", "embed"))
    new_cache = {"conv": new_conv, "h": hN} if cache is not None else None
    return out, new_cache


def init_mamba2_cache(cfg: ModelConfig, batch: int, n_layers: int):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    nh = di // s.headdim
    ci = di + 2 * s.d_state
    return {
        "conv": jnp.zeros((n_layers, batch, s.d_conv - 1, ci), jnp.dtype(cfg.dtype)),
        "h": jnp.zeros((n_layers, batch, nh, s.headdim, s.d_state), jnp.float32),
    }
