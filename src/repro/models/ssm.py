"""Mamba1 (falcon-mamba) and Mamba2 (zamba2 backbone) state-space blocks.

The reference sequence mixer is a ``lax.scan`` over time (memory-light,
exactly the recurrence); the perf-critical chunked scan for TPU lives in
:mod:`repro.kernels.ssm_scan`. Decode carries an O(1) cache
(conv window + SSM state) — this is why the ssm/hybrid archs run the
``long_500k`` shape.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.parallel.sharding import logical_constraint


def _dt_rank(cfg: ModelConfig) -> int:
    return cfg.ssm.dt_rank or int(math.ceil(cfg.d_model / 16))


# ---------------------------------------------------------------------------
# Mamba 1
# ---------------------------------------------------------------------------


def init_mamba1(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    dtr = _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    pdt = cfg.param_dtype
    A = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32), (di, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), pdt),
        "conv_w": dense_init(ks[1], (s.d_conv, di), pdt, scale=0.1),
        "conv_b": jnp.zeros((di,), jnp.dtype(pdt)),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * s.d_state), pdt),
        "dt_proj": dense_init(ks[3], (dtr, di), pdt, scale=dtr ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (di,)) * 0.1 + 1e-3, 1e-4))).astype(jnp.dtype(pdt)),
        "A_log": jnp.log(A).astype(jnp.dtype(pdt)),
        "D": jnp.ones((di,), jnp.dtype(pdt)),
        "out_proj": dense_init(ks[5], (di, d), pdt),
    }


def _causal_conv(x, w, b, cache=None):
    """Depthwise causal conv. x: (B,S,di), w: (K,di). cache: (B,K-1,di)."""
    K = w.shape[0]
    if cache is not None:
        xp = jnp.concatenate([cache, x], axis=1)
        new_cache = xp[:, -(K - 1):, :] if K > 1 else cache
    else:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
        new_cache = None
    S = x.shape[1]
    out = sum(xp[:, i:i + S, :] * w[i][None, None, :] for i in range(K))
    return out + b[None, None, :], new_cache


def mamba1_apply(params, x, cfg: ModelConfig, cache: Optional[dict] = None):
    """x: (B,S,D) -> (B,S,D). cache: {"conv": (B,K-1,di), "h": (B,di,ds)}."""
    with jax.named_scope("ssm_core"):
        return _mamba1_apply(params, x, cfg, cache)


def _mamba1_apply(params, x, cfg: ModelConfig, cache: Optional[dict] = None):
    s = cfg.ssm
    dt_ = jnp.dtype(cfg.dtype)
    x = x.astype(dt_)
    B, S, D = x.shape
    di = s.expand * D
    dtr = _dt_rank(cfg)

    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt_))
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = logical_constraint(xin, ("batch", "seq", "mlp"))
    conv_cache = cache["conv"] if cache is not None else None
    xc, new_conv = _causal_conv(xin, params["conv_w"].astype(dt_),
                                params["conv_b"].astype(dt_), conv_cache)
    xc = jax.nn.silu(xc)

    dbc = jnp.einsum("bse,ef->bsf", xc, params["x_proj"].astype(dt_))
    dtr_v, Bm, Cm = jnp.split(dbc, [dtr, dtr + s.d_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dtr_v, params["dt_proj"].astype(dt_))
        + params["dt_bias"].astype(dt_))                       # (B,S,di)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))          # (di,ds)

    dt32, xc32 = dt.astype(jnp.float32), xc.astype(jnp.float32)
    B32, C32 = Bm.astype(jnp.float32), Cm.astype(jnp.float32)

    def step(h, inp):
        dt_t, x_t, b_t, c_t = inp                              # (B,di),(B,di),(B,ds),(B,ds)
        dA = jnp.exp(dt_t[:, :, None] * A[None])               # (B,di,ds)
        h = dA * h + dt_t[:, :, None] * b_t[:, None, :] * x_t[:, :, None]
        y = jnp.einsum("bes,bs->be", h, c_t)
        return h, y

    h0 = cache["h"] if cache is not None else jnp.zeros(
        (B, di, s.d_state), jnp.float32)
    xs = (dt32.transpose(1, 0, 2), xc32.transpose(1, 0, 2),
          B32.transpose(1, 0, 2), C32.transpose(1, 0, 2))
    hN, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2).astype(dt_)                      # (B,S,di)
    y = y + params["D"].astype(dt_)[None, None, :] * xc
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt_))
    out = logical_constraint(out, ("batch", "seq", "embed"))
    new_cache = {"conv": new_conv, "h": hN} if cache is not None else None
    return out, new_cache


def init_mamba1_cache(cfg: ModelConfig, batch: int, n_layers: int):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    return {
        "conv": jnp.zeros((n_layers, batch, s.d_conv - 1, di), jnp.dtype(cfg.dtype)),
        "h": jnp.zeros((n_layers, batch, di, s.d_state), jnp.float32),
    }


# ---------------------------------------------------------------------------
# Mamba 2 (SSD, scalar per-head decay, single B/C group)
# ---------------------------------------------------------------------------


def init_mamba2(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    nh = di // s.headdim
    ks = jax.random.split(key, 4)
    pdt = cfg.param_dtype
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * s.d_state + nh), pdt),
        "conv_w": dense_init(ks[1], (s.d_conv, di + 2 * s.d_state), pdt, scale=0.1),
        "conv_b": jnp.zeros((di + 2 * s.d_state,), jnp.dtype(pdt)),
        "dt_bias": jnp.zeros((nh,), jnp.dtype(pdt)),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)).astype(jnp.dtype(pdt)),
        "D": jnp.ones((nh,), jnp.dtype(pdt)),
        "norm_scale": jnp.ones((di,), jnp.dtype(pdt)),
        "out_proj": dense_init(ks[2], (di, d), pdt),
    }


def mamba2_apply(params, x, cfg: ModelConfig, cache: Optional[dict] = None):
    """Mamba2 SSD mixer. cache: {"conv": (B,K-1,ci), "h": (B,nh,hd,ds)}."""
    with jax.named_scope("ssm_core"):
        return _mamba2_apply(params, x, cfg, cache)


def _mamba2_apply(params, x, cfg: ModelConfig, cache: Optional[dict] = None):
    s = cfg.ssm
    dt_ = jnp.dtype(cfg.dtype)
    x = x.astype(dt_)
    B, S, D = x.shape
    di = s.expand * D
    nh = di // s.headdim

    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt_))
    z, xbc, dt = jnp.split(proj, [di, 2 * di + 2 * s.d_state], axis=-1)
    conv_cache = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"].astype(dt_),
                                 params["conv_b"].astype(dt_), conv_cache)
    xbc = jax.nn.silu(xbc)
    xin, Bm, Cm = jnp.split(xbc, [di, di + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,S,nh)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))              # (nh,)

    xh = xin.reshape(B, S, nh, s.headdim).astype(jnp.float32)
    B32, C32 = Bm.astype(jnp.float32), Cm.astype(jnp.float32)

    def step(h, inp):
        dt_t, x_t, b_t, c_t = inp            # (B,nh),(B,nh,hd),(B,ds),(B,ds)
        dA = jnp.exp(dt_t * A[None])         # (B,nh)
        h = dA[:, :, None, None] * h + (dt_t[:, :, None] * x_t)[..., None] \
            * b_t[:, None, None, :]
        y = jnp.einsum("bhes,bs->bhe", h, c_t)
        return h, y

    h0 = cache["h"] if cache is not None else jnp.zeros(
        (B, nh, s.headdim, s.d_state), jnp.float32)
    xs = (dt.transpose(1, 0, 2), xh.transpose(1, 0, 2, 3),
          B32.transpose(1, 0, 2), C32.transpose(1, 0, 2))
    hN, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3)                                   # (B,S,nh,hd)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(B, S, di).astype(dt_)
    # gated RMSNorm (mamba2)
    y = y * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6)
         * params["norm_scale"].astype(jnp.float32)).astype(dt_)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt_))
    out = logical_constraint(out, ("batch", "seq", "embed"))
    new_cache = {"conv": new_conv, "h": hN} if cache is not None else None
    return out, new_cache


def init_mamba2_cache(cfg: ModelConfig, batch: int, n_layers: int):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    nh = di // s.headdim
    ci = di + 2 * s.d_state
    return {
        "conv": jnp.zeros((n_layers, batch, s.d_conv - 1, ci), jnp.dtype(cfg.dtype)),
        "h": jnp.zeros((n_layers, batch, nh, s.headdim, s.d_state), jnp.float32),
    }
