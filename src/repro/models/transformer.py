"""Full models assembled around the layer-parallel trunk.

Families:
  decoder  — decoder-only LM (deepseek, phi4, qwen3, granite, grok,
             qwen3-moe) and the vlm backbone (qwen2-vl, frontend stubbed)
  encoder  — encoder-only (paper's BERT/MC/ViT configs)
  encdec   — encoder-decoder (seamless-m4t; the paper's novel Eq. 3
             formulation, implemented as two chained MGRIT grids)
  ssm      — attention-free mamba1 trunk (falcon-mamba)
  hybrid   — zamba2: mamba2 backbone + shared attention block every k layers
             (heterogeneous -> serial trunk + TP; see DESIGN.md §6)

Structure of params:
  embed / [frontend] / open (serial buffer) / mid (ParallelNet) /
  close (serial buffer) / final_norm / [enc_*, dec_* for encdec]
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import MGRITConfig, ModelConfig, RunConfig
from repro.core import lp, mgrit
from repro.core.lp import LPStatic, lp_forward, pad_depth
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models.blocks import (block_kind, block_step, init_block,
                                 paged_attn_block, paged_attn_view_block)
from repro.models.layers import (embed_tokens, init_embedding, init_norm,
                                 norm_apply, rope_freqs, unembed)
from repro.parallel.sharding import logical_constraint


# ---------------------------------------------------------------------------
# Depth bookkeeping
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DepthPlan:
    n_open: int
    n_close: int
    n_mid_real: int
    n_mid_padded: int

    @property
    def n_total_real(self):
        return self.n_open + self.n_close + self.n_mid_real


def depth_plan(n_layers: int, mg: MGRITConfig) -> DepthPlan:
    n_open, n_close = mg.n_open, mg.n_close
    n_mid = n_layers - n_open - n_close
    assert n_mid > 0, "buffers consume all layers"
    return DepthPlan(n_open, n_close, n_mid, pad_depth(n_mid, mg.pad_to))


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _stack_blocks(key, cfg: ModelConfig, n: int, kind: str):
    if n == 0:
        return None
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_block(k, cfg, kind))(keys)


def init_model(key, rcfg: RunConfig):
    cfg, mg = rcfg.model, rcfg.mgrit
    kind = block_kind(cfg)
    ks = jax.random.split(key, 10)
    params: Dict[str, Any] = {"embed": init_embedding(ks[0], cfg),
                              "final_norm": init_norm(cfg)}

    if cfg.family == "encdec":
        ep = depth_plan(cfg.n_layers, mg)
        dp = depth_plan(cfg.n_dec_layers, mg)
        params["enc_mid"] = {
            "params": _stack_blocks(ks[1], cfg, ep.n_mid_padded, "attn_mlp"),
            "gate": lp.make_gates(ep.n_mid_real, ep.n_mid_padded)}
        params["dec_mid"] = {
            "params": _stack_blocks(ks[2], cfg, dp.n_mid_padded, "encdec_dec"),
            "gate": lp.make_gates(dp.n_mid_real, dp.n_mid_padded)}
        return params

    if cfg.family == "hybrid":
        params["backbone"] = _stack_blocks(ks[1], cfg, cfg.n_layers, "mamba2")
        params["shared_attn"] = init_block(ks[2], cfg, "attn_mlp")
        return params

    plan = depth_plan(cfg.n_layers, mg)
    params["open"] = _stack_blocks(ks[1], cfg, plan.n_open, kind)
    params["close"] = _stack_blocks(ks[2], cfg, plan.n_close, kind)
    params["mid"] = {
        "params": _stack_blocks(ks[3], cfg, plan.n_mid_padded, kind),
        "gate": lp.make_gates(plan.n_mid_real, plan.n_mid_padded)}
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _rope_for(cfg: ModelConfig, seq: int):
    pos = jnp.arange(seq, dtype=jnp.int32)
    return rope_freqs(cfg.resolved_head_dim, cfg.rope_theta, pos)


def _serial_buffer(stacked, z, cfg, *, kind, causal, rope, xa=None,
                   use_pallas=False):
    """Exact serial buffer layers (paper App. B, Delta-t = 1), normal AD."""
    if stacked is None:
        return z

    def step(z, p):
        z2, _ = block_step(p, z, cfg, kind=kind, causal=causal, h=1.0,
                           rope=rope, xa=xa, use_pallas=use_pallas)
        return z2, None

    z, _ = jax.lax.scan(step, z, stacked)
    return z


def _trunk(params_mid, z, rcfg: RunConfig, *, kind, causal, rope, xa=None,
           mode: str):
    """The ParallelNet: MGRIT layer-parallel or exact serial trunk."""
    cfg, mg = rcfg.model, rcfg.mgrit
    if mode == "serial" or not mg.enabled:
        mg = dataclasses.replace(mg, fwd_iters=0, bwd_iters=0)
    static = LPStatic(cfg=cfg, mgrit=mg, kind=kind, causal=causal,
                      use_pallas=rcfg.use_pallas)
    extra = {"rope": rope}
    if xa is not None:
        extra["xa"] = xa
    zT, norms = lp_forward(static, params_mid, z, extra)
    return zT, norms


def _embed_inputs(params, batch, cfg: ModelConfig):
    """Token embeddings, with modality frontend stubs prepended."""
    x = embed_tokens(params["embed"], batch["tokens"], cfg)
    if cfg.frontend == "vision" and "mm_embeds" in batch:
        x = jnp.concatenate(
            [batch["mm_embeds"].astype(x.dtype), x], axis=1)
    return x


def forward(params, batch, rcfg: RunConfig, mode: str = "lp"):
    """Returns (logits, diagnostics). batch: tokens (B,S) [+ mm_embeds /
    src_embeds for stubbed modalities, + tgt tokens for encdec]."""
    cfg = rcfg.model
    kind = block_kind(cfg)
    diagnostics = {}

    if cfg.family == "encdec":
        # --- encoder grid (Eq. 3: t < T_enc) ---
        if cfg.frontend == "audio" and "src_embeds" in batch:
            xe = batch["src_embeds"].astype(jnp.dtype(cfg.dtype))
        else:
            xe = _embed_inputs(params, {"tokens": batch["src_tokens"]}, cfg)
        rope_e = _rope_for(cfg, xe.shape[1])
        xe = logical_constraint(xe, ("batch", "seq", "embed"))
        xN, n1 = _trunk(params["enc_mid"], xe, rcfg, kind="attn_mlp",
                        causal=False, rope=rope_e, mode=mode)
        # --- decoder grid (t >= T_enc), cross-attending to X_{N_enc} ---
        y = embed_tokens(params["embed"], batch["tokens"], cfg)
        rope_d = _rope_for(cfg, y.shape[1])
        y = logical_constraint(y, ("batch", "seq", "embed"))
        yN, n2 = _trunk(params["dec_mid"], y, rcfg, kind="encdec_dec",
                        causal=True, rope=rope_d, xa=xN, mode=mode)
        diagnostics["fwd_norms"] = jnp.concatenate([n1, n2])
        z = yN
    elif cfg.family == "hybrid":
        z = _embed_inputs(params, batch, cfg)
        z = logical_constraint(z, ("batch", "seq", "embed"))
        rope = _rope_for(cfg, z.shape[1])
        k = cfg.hybrid_attn_every
        n_seg, rem = divmod(cfg.n_layers, k)
        for s in range(n_seg):
            seg = jax.tree.map(lambda a, s=s: a[s * k:(s + 1) * k],
                               params["backbone"])
            z = _serial_buffer(seg, z, cfg, kind="mamba2", causal=True,
                               rope=None)
            z, _ = block_step(params["shared_attn"], z, cfg, kind="attn_mlp",
                              causal=True, rope=rope,
                              use_pallas=rcfg.use_pallas)
        if rem:
            tail = jax.tree.map(lambda a: a[n_seg * k:], params["backbone"])
            z = _serial_buffer(tail, z, cfg, kind="mamba2", causal=True,
                               rope=None)
        diagnostics["fwd_norms"] = jnp.zeros((1,), jnp.float32)
    else:
        causal = cfg.family != "encoder"
        z = _embed_inputs(params, batch, cfg)
        z = logical_constraint(z, ("batch", "seq", "embed"))
        rope = None if kind in ("mamba1", "mamba2") else _rope_for(
            cfg, z.shape[1])
        z = _serial_buffer(params.get("open"), z, cfg, kind=kind,
                           causal=causal, rope=rope,
                           use_pallas=rcfg.use_pallas)
        z, norms = _trunk(params["mid"], z, rcfg, kind=kind, causal=causal,
                          rope=rope, mode=mode)
        z = _serial_buffer(params.get("close"), z, cfg, kind=kind,
                           causal=causal, rope=rope,
                           use_pallas=rcfg.use_pallas)
        diagnostics["fwd_norms"] = norms

    z = norm_apply(params["final_norm"], z, cfg)
    logits = unembed(params["embed"], z, cfg)
    logits = logical_constraint(logits, ("batch", "seq", "vocab"))
    return logits, diagnostics


def lm_loss(logits, labels):
    """Mean token cross-entropy in fp32 over a sharded vocab axis."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def loss_fn(params, batch, rcfg: RunConfig, mode: str = "lp"):
    logits, diagnostics = forward(params, batch, rcfg, mode=mode)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:  # vlm: mm positions carry no loss
        logits = logits[:, -labels.shape[1]:]
    loss = lm_loss(logits, labels)
    return loss, diagnostics


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with caches
# ---------------------------------------------------------------------------


def _all_layers_stacked(params, cfg: ModelConfig):
    """Concatenate open/mid/close stacks (+gates) for cache-based decode."""
    parts, gates = [], []
    for name in ("open", "mid", "close"):
        p = params.get(name)
        if p is None:
            continue
        if name == "mid":
            parts.append(p["params"])
            gates.append(p["gate"])
        else:
            parts.append(p)
            gates.append(jnp.ones((jax.tree.leaves(p)[0].shape[0],),
                                  jnp.float32))
    stacked = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *parts)
    return stacked, jnp.concatenate(gates)


def init_cache(rcfg: RunConfig, batch: int, max_len: int):
    cfg = rcfg.model
    kind = block_kind(cfg)
    if cfg.family == "encdec":
        plan = depth_plan(cfg.n_dec_layers, rcfg.mgrit)
        return attn_mod.init_kv_cache(cfg, batch, max_len, plan.n_mid_padded)
    if cfg.family == "hybrid":
        return {"mamba": ssm_mod.init_mamba2_cache(cfg, batch, cfg.n_layers),
                "attn": attn_mod.init_kv_cache(
                    cfg, batch, max_len,
                    cfg.n_layers // cfg.hybrid_attn_every)}
    plan = depth_plan(cfg.n_layers, rcfg.mgrit)
    n = plan.n_open + plan.n_mid_padded + plan.n_close
    if kind == "mamba1":
        return ssm_mod.init_mamba1_cache(cfg, batch, n)
    if kind == "mamba2":
        return ssm_mod.init_mamba2_cache(cfg, batch, n)
    return attn_mod.init_kv_cache(cfg, batch, max_len, n)


def decode_step(params, cache, tokens, rcfg: RunConfig, xa=None):
    """Cached decode: tokens (B, T). Returns (logits, new_cache).

    T == 1 is the steady-state decode step. T > 1 is **chunked prefill**:
    the whole prompt chunk is written into the KV cache by one jitted call
    (attention kinds only — SSM caches advance one token at a time).
    Serial layer scan with per-layer cache slices (serving uses TP; the
    paper's LP targets training — DESIGN.md §6)."""
    cfg = rcfg.model
    kind = block_kind(cfg)
    if tokens.shape[1] != 1 and (cfg.family == "hybrid"
                                 or kind in ("mamba1", "mamba2")):
        raise NotImplementedError(
            "chunked prefill requires attention blocks; SSM/hybrid caches "
            "advance token-by-token")
    z = embed_tokens(params["embed"], tokens, cfg)
    z = logical_constraint(z, ("batch", "seq", "embed"))

    if cfg.family == "hybrid":
        return _decode_hybrid(params, cache, z, rcfg)

    if cfg.family == "encdec":
        stacked = params["dec_mid"]["params"]
        gates = params["dec_mid"]["gate"]
        dkind = "encdec_dec"
    else:
        stacked, gates = _all_layers_stacked(params, cfg)
        dkind = kind

    if dkind in ("mamba1", "mamba2"):
        rope = None
    else:
        pos = cache["index"] + jnp.arange(tokens.shape[1])
        rope = rope_freqs(cfg.resolved_head_dim, cfg.rope_theta, pos)

    def step(z, xs):
        p, gate, layer_cache = xs
        if dkind in ("mamba1", "mamba2"):
            lc = layer_cache
        else:
            lc = {"k": layer_cache["k"], "v": layer_cache["v"],
                  "index": cache["index"]}
        z2, new_lc = block_step(p, z, cfg, kind=dkind, causal=True, h=1.0,
                                gate=gate, rope=rope, xa=xa, cache=lc)
        if dkind not in ("mamba1", "mamba2"):
            new_lc = {"k": new_lc["k"], "v": new_lc["v"]}
        return z2, new_lc

    layer_caches = {k: v for k, v in cache.items() if k != "index"}
    z, new_layer_caches = jax.lax.scan(step, z, (stacked, gates, layer_caches))
    new_cache = dict(new_layer_caches)
    if "index" in cache:
        new_cache["index"] = cache["index"] + tokens.shape[1]
    z = norm_apply(params["final_norm"], z, cfg)
    logits = unembed(params["embed"], z, cfg)
    return logits, new_cache


def _decode_hybrid(params, cache, z, rcfg: RunConfig):
    cfg = rcfg.model
    k = cfg.hybrid_attn_every
    n_seg, rem = divmod(cfg.n_layers, k)
    idx = cache["attn"]["index"]
    rope = rope_freqs(cfg.resolved_head_dim, cfg.rope_theta,
                      jnp.atleast_1d(idx))
    new_mamba = {"conv": [], "h": []}
    new_attn = {"k": [], "v": []}
    li = 0
    for s in range(n_seg + (1 if rem else 0)):
        span = k if s < n_seg else rem
        for _ in range(span):
            p = jax.tree.map(lambda a, li=li: a[li], params["backbone"])
            lc = {"conv": cache["mamba"]["conv"][li],
                  "h": cache["mamba"]["h"][li]}
            z, nlc = block_step(p, z, cfg, kind="mamba2", causal=True,
                                cache=lc)
            new_mamba["conv"].append(nlc["conv"])
            new_mamba["h"].append(nlc["h"])
            li += 1
        if s < n_seg:
            lc = {"k": cache["attn"]["k"][s], "v": cache["attn"]["v"][s],
                  "index": idx}
            z, nlc = block_step(params["shared_attn"], z, cfg,
                                kind="attn_mlp", causal=True, rope=rope,
                                cache=lc)
            new_attn["k"].append(nlc["k"])
            new_attn["v"].append(nlc["v"])
    new_cache = {
        "mamba": {kk: jnp.stack(vv) for kk, vv in new_mamba.items()},
        "attn": {"k": jnp.stack(new_attn["k"]), "v": jnp.stack(new_attn["v"]),
                 "index": idx + 1},
    }
    z = norm_apply(params["final_norm"], z, cfg)
    logits = unembed(params["embed"], z, cfg)
    return logits, new_cache


def prefill(params, batch, rcfg: RunConfig):
    """Prefill forward (no loss): returns logits. KV-cache population for
    the chained decode is handled by the serving engine (repro.serve)."""
    logits, _ = forward(params, batch, rcfg, mode="serial")
    return logits


# ---------------------------------------------------------------------------
# Paged serving: one occupancy-masked step per block family
# ---------------------------------------------------------------------------
#
# Every family exposes the same step signature
#   (params, state, tokens, lengths, n_new, page_table, rcfg, *, page_size)
#     -> (last_logits (B, V), new_state)
# so the serve engine's CacheBackend protocol (repro.serve.cache) can wrap
# any of them behind one jitted call. ``state`` is a pytree of page pools
# with page axis 1: KV pages for attention, state-snapshot pages for
# SSM (see repro.models.ssm "Paged recurrent state"), both for hybrid.


def _stacked_layer_depth(rcfg: RunConfig) -> int:
    plan = depth_plan(rcfg.model.n_layers, rcfg.mgrit)
    return plan.n_open + plan.n_mid_padded + plan.n_close


def init_paged_cache(rcfg: RunConfig, n_pages: int, page_size: int,
                     n_layers: int = 0):
    """Attention KV page pool sized for the full serial layer stack
    (open+mid+close), or an explicit ``n_layers`` (the coarse-propagator
    draft model pools a restricted stack)."""
    return attn_mod.init_paged_kv_cache(
        rcfg.model, n_layers or _stacked_layer_depth(rcfg), n_pages,
        page_size)


def init_paged_ssm_cache(rcfg: RunConfig, n_pages: int, n_layers: int = 0):
    """State-snapshot page pool for the ssm family's full layer stack
    (or an explicit coarse ``n_layers``)."""
    cfg = rcfg.model
    return ssm_mod.init_paged_ssm_pool(
        cfg, n_layers or _stacked_layer_depth(rcfg), n_pages,
        cfg.ssm.version)


def init_paged_hybrid_cache(rcfg: RunConfig, n_pages: int, page_size: int):
    """Hybrid (zamba2) pools: mamba2 state snapshots for every backbone
    layer + KV pages for each interleaved shared-attention position, all
    addressed by the same physical page ids."""
    cfg = rcfg.model
    n_attn = cfg.n_layers // cfg.hybrid_attn_every
    return {
        "mamba": ssm_mod.init_paged_ssm_pool(cfg, cfg.n_layers, n_pages, 2),
        "attn": attn_mod.init_paged_kv_cache(cfg, n_attn, n_pages, page_size),
    }


def _paged_last_logits(params, z, n_new, cfg: ModelConfig):
    z = norm_apply(params["final_norm"], z, cfg)
    last = jnp.maximum(n_new - 1, 0)
    z_last = jnp.take_along_axis(z, last[:, None, None], axis=1)
    logits = unembed(params["embed"], z_last, cfg)
    return logical_constraint(logits, ("batch", "seq", "vocab"))[:, 0]


def _paged_all_logits(params, z, cfg: ModelConfig):
    """Logits at every position of the step window (B, S, V) — the
    speculative-decode verifier needs per-drafted-token targets, not just
    the last one. Positions >= n_new carry garbage; callers mask them."""
    z = norm_apply(params["final_norm"], z, cfg)
    logits = unembed(params["embed"], z, cfg)
    return logical_constraint(logits, ("batch", "seq", "vocab"))


def _paged_attn_forward(params, pages, tokens, lengths, n_new, page_table,
                        rcfg: RunConfig, *, fused: bool = False):
    """Shared trunk of the attention paged step/verify: embeds, runs the
    full stacked layer scan against the KV page pool, returns (z (B,S,D),
    new_pages). ``fused`` routes each layer's attention core through the
    flash-decode paged kernel; in ref mode (CPU) it additionally keeps the
    pools OUT of the layer scan — pre-gathered per-slot views go in, only
    the new K/V rows come out, and one donated scatter commits them
    (see ``attention.paged_kv_commit``) — instead of paying two full-pool
    copies per step to scan input slicing / output stacking."""
    cfg = rcfg.model
    kind = block_kind(cfg)
    if kind not in ("attn_mlp", "attn_moe"):
        raise NotImplementedError("paged KV decode requires attention blocks")
    stacked, gates = _all_layers_stacked(params, cfg)
    S = tokens.shape[1]
    pos = lengths[:, None] + jnp.arange(S)[None, :]
    rope = rope_freqs(cfg.resolved_head_dim, cfg.rope_theta, pos)
    z = embed_tokens(params["embed"], tokens, cfg)
    z = logical_constraint(z, ("batch", "seq", "embed"))

    if fused:
        from repro.kernels import ops as kops
        if kops.kernel_mode() == "ref":
            kd_all = attn_mod.paged_view_gather(pages["k"], page_table)
            vd_all = attn_mod.paged_view_gather(pages["v"], page_table)

            def vstep(z, xs):
                p, gate, (kd, vd) = xs
                z2, k_new, v_new = paged_attn_view_block(
                    p, z, cfg, kind=kind, rope=rope, kd=kd, vd=vd,
                    lengths=lengths, n_new=n_new, gate=gate)
                return z2, (k_new, v_new)

            z, (k_rows, v_rows) = jax.lax.scan(
                vstep, z, (stacked, gates, (kd_all, vd_all)))
            return z, attn_mod.paged_kv_commit(pages, k_rows, v_rows,
                                               page_table, lengths, n_new)

    def step(z, xs):
        p, gate, (pk, pv) = xs
        z2, npk, npv = paged_attn_block(
            p, z, cfg, kind=kind, rope=rope, pk=pk, pv=pv,
            page_table=page_table, lengths=lengths, n_new=n_new, gate=gate,
            fused=fused)
        return z2, (npk, npv)

    z, (nk, nv) = jax.lax.scan(step, z, (stacked, gates,
                                         (pages["k"], pages["v"])))
    return z, {"k": nk, "v": nv}


def paged_decode_step(params, pages, tokens, lengths, n_new, page_table,
                      rcfg: RunConfig, *, page_size: int = 0,
                      fused: bool = False):
    """Batched step against the shared KV page pool — static shapes,
    dynamic occupancy.

    tokens: (B, S). S == 1 in steady-state decode; S == the prompt bucket
    during chunked prefill (one call writes the whole chunk). Slot b holds
    ``lengths[b]`` cached tokens and contributes ``n_new[b] <= S`` new ones;
    ``n_new[b] == 0`` marks an empty slot, so the same compiled step serves
    any occupancy without retracing. Returns (last_logits (B, V) at each
    slot's final real token, new_pages).
    """
    z, new_pages = _paged_attn_forward(params, pages, tokens, lengths,
                                       n_new, page_table, rcfg, fused=fused)
    return _paged_last_logits(params, z, n_new, rcfg.model), new_pages


def paged_verify_step(params, pages, tokens, lengths, n_new, page_table,
                      rcfg: RunConfig, *, page_size: int = 0,
                      fused: bool = False):
    """Speculative-verify forward for the attention family: one call over
    the pending token + k drafted tokens, logits at EVERY position.
    Returns (logits (B, S, V), new_pages, None).

    KV rollback is free: the k+1 K/V entries are written positionally, and
    anything beyond the accepted length is masked out of future attention
    (``kpos > qpos``) until the next wave overwrites it — so the host
    rolls back by truncating ``lengths``. The trailing ``None`` mirrors
    the deferred-commit artifact slot the snapshot families return.
    ``fused`` enables the same kernel/view-path restructuring as decode —
    the k+1-wide verify wave is just a small prefill chunk to it.
    """
    z, new_pages = _paged_attn_forward(params, pages, tokens, lengths,
                                       n_new, page_table, rcfg, fused=fused)
    return _paged_all_logits(params, z, rcfg.model), new_pages, None


def _ssm_paged_forward(params, pools, tokens, lengths, n_new, page_table,
                       rcfg: RunConfig, *, page_size: int, commit: bool,
                       fused: bool = False):
    """Shared trunk of the SSM paged step/verify. ``commit=True`` writes
    the state-snapshot pages in-line (normal decode/prefill) and returns
    (z, new_pools, None); ``commit=False`` leaves the pools untouched and
    returns (z, pools, artifacts) where artifacts hold every layer's
    per-step snapshot candidates for a later
    :func:`ssm_paged_commit_step` (speculative verification commits only
    the accepted prefix).

    With ``fused=True`` under ref kernel mode the pools stay OUT of the
    layer scan entirely: incoming state for every layer is pre-gathered
    once (``paged_state_read_stacked``), the mixers run in deferred mode
    (``state_in`` + ``commit=False``), and one compact scatter per pool
    publishes all layers' snapshots after the scan
    (``paged_pools_commit_compact``). Scan xs/ys slicing copies the full
    pool per layer step otherwise — the dominant decode cost on CPU —
    while outputs and non-scratch pages stay bitwise identical."""
    cfg = rcfg.model
    kind = block_kind(cfg)
    if kind not in ("mamba1", "mamba2"):
        raise NotImplementedError("ssm paged decode requires mamba blocks")
    mixer = ssm_mod.mamba1_paged_apply if kind == "mamba1" \
        else ssm_mod.mamba2_paged_apply
    stacked, gates = _all_layers_stacked(params, cfg)
    z = embed_tokens(params["embed"], tokens, cfg)
    z = logical_constraint(z, ("batch", "seq", "embed"))

    if fused:
        from repro.kernels import ops as kops
        if kops.kernel_mode() == "ref":
            win0_all = ssm_mod.paged_state_read_stacked(
                pools["conv"], page_table, lengths, page_size)
            h0_all = ssm_mod.paged_state_read_stacked(
                pools["h"], page_table, lengths, page_size)

            def vstep(z, xs):
                p, gate, (w0, h0) = xs
                f, xp, hs_b = mixer(
                    p["mixer"], norm_apply(p["norm"], z, cfg), cfg,
                    conv_pool=None, h_pool=None, page_table=page_table,
                    lengths=lengths, n_new=n_new, page_size=page_size,
                    commit=False, state_in=(w0, h0))
                return z + gate.astype(z.dtype) * f, (xp, hs_b)

            z, (xp_all, hs_all) = jax.lax.scan(
                vstep, z, (stacked, gates, (win0_all, h0_all)))
            if not commit:
                # verify wave: same deferred mixers, but the snapshot
                # candidates go back to the caller instead of the pools
                # (ssm_paged_commit_step publishes the accepted prefix)
                return z, pools, {"xp": xp_all, "hs": hs_all}
            new_pools = ssm_mod.paged_pools_commit_compact(
                pools, xp_all, hs_all, page_table=page_table,
                lengths=lengths, n_new=n_new, page_size=page_size)
            return z, new_pools, None

    def step(z, xs):
        p, gate, (cpool, hpool) = xs
        f, a, b = mixer(p["mixer"], norm_apply(p["norm"], z, cfg), cfg,
                        conv_pool=cpool, h_pool=hpool,
                        page_table=page_table, lengths=lengths,
                        n_new=n_new, page_size=page_size, commit=commit,
                        fused=fused)
        return z + gate.astype(z.dtype) * f, (a, b)

    z, (a, b) = jax.lax.scan(step, z, (stacked, gates,
                                       (pools["conv"], pools["h"])))
    if commit:
        return z, {"conv": a, "h": b}, None
    return z, pools, {"xp": a, "hs": b}


def ssm_paged_decode_step(params, pools, tokens, lengths, n_new, page_table,
                          rcfg: RunConfig, *, page_size: int,
                          fused: bool = False):
    """Paged twin of the dense SSM decode: same step contract as
    :func:`paged_decode_step`, with KV pages replaced by state-snapshot
    pages. Unlike the dense cache, chunked prefill works here: padded
    positions (>= n_new) freeze the recurrent state, so one call advances
    a whole prompt chunk."""
    z, new_pools, _ = _ssm_paged_forward(
        params, pools, tokens, lengths, n_new, page_table, rcfg,
        page_size=page_size, commit=True, fused=fused)
    return _paged_last_logits(params, z, n_new, rcfg.model), new_pools


def ssm_paged_verify_step(params, pools, tokens, lengths, n_new, page_table,
                          rcfg: RunConfig, *, page_size: int,
                          fused: bool = False):
    """Speculative-verify forward for the SSM family: advances the masked
    recurrence over the pending + k drafted tokens WITHOUT touching the
    snapshot pools; returns (logits (B, S, V), pools, artifacts). After
    acceptance is known, :func:`ssm_paged_commit_step` publishes only the
    accepted prefix's snapshots — the recurrent-state analogue of
    truncating KV lengths (PR-3's snapshot-page design is what makes the
    rollback exact: every local step's state is a snapshot candidate).
    ``fused`` pre-gathers every layer's incoming state outside the scan
    (the scan-carry pool copies dominate the verify wave exactly as they
    did decode); the artifacts are bitwise those of the gathered path."""
    z, pools, art = _ssm_paged_forward(
        params, pools, tokens, lengths, n_new, page_table, rcfg,
        page_size=page_size, commit=False, fused=fused)
    return _paged_all_logits(params, z, rcfg.model), pools, art


def ssm_paged_commit_step(pools, art, page_table, lengths, n_write,
                          *, page_size: int):
    """Deferred snapshot-page commit for every layer of the SSM stack:
    writes the state after exactly ``n_write[b]`` of the verified tokens
    (``accepted + 1``; 0 skips the slot) into the pools."""
    def one(cpool, hpool, xp, hs):
        return ssm_mod.paged_pool_commit(
            cpool, hpool, xp, hs, page_table=page_table, lengths=lengths,
            n_new=n_write, page_size=page_size)

    nc, nh = jax.vmap(one)(pools["conv"], pools["h"], art["xp"], art["hs"])
    nc, nh = ssm_mod.constrain_pools(nc, nh, stacked=True)
    return {"conv": nc, "h": nh}


def _hybrid_paged_forward(params, state, tokens, lengths, n_new, page_table,
                          rcfg: RunConfig, *, page_size: int, commit: bool,
                          fused: bool = False):
    """Shared trunk of the hybrid paged step/verify. The interleaved
    shared-attention block always writes its KV pages in-line (truncation
    rollback, like the attention family); ``commit=False`` defers only
    the mamba2 backbone's snapshot-page writes, returning (z, state',
    artifacts) with the backbone pools untouched."""
    cfg = rcfg.model
    k = cfg.hybrid_attn_every
    n_seg, rem = divmod(cfg.n_layers, k)
    S = tokens.shape[1]
    pos = lengths[:, None] + jnp.arange(S)[None, :]
    rope = rope_freqs(cfg.resolved_head_dim, cfg.rope_theta, pos)
    z = embed_tokens(params["embed"], tokens, cfg)
    z = logical_constraint(z, ("batch", "seq", "embed"))
    new_conv, new_h, new_k, new_v = [], [], [], []
    li = 0
    for s_i in range(n_seg + (1 if rem else 0)):
        span = k if s_i < n_seg else rem
        for _ in range(span):
            p = jax.tree.map(lambda a: a[li], params["backbone"])
            f, a, b = ssm_mod.mamba2_paged_apply(
                p["mixer"], norm_apply(p["norm"], z, cfg), cfg,
                conv_pool=state["mamba"]["conv"][li],
                h_pool=state["mamba"]["h"][li], page_table=page_table,
                lengths=lengths, n_new=n_new, page_size=page_size,
                commit=commit, fused=fused)
            z = z + f
            new_conv.append(a)
            new_h.append(b)
            li += 1
        if s_i < n_seg:
            z, npk, npv = paged_attn_block(
                params["shared_attn"], z, cfg, kind="attn_mlp", rope=rope,
                pk=state["attn"]["k"][s_i], pv=state["attn"]["v"][s_i],
                page_table=page_table, lengths=lengths, n_new=n_new,
                fused=fused)
            new_k.append(npk)
            new_v.append(npv)
    attn = {"k": jnp.stack(new_k), "v": jnp.stack(new_v)}
    if commit:
        state2 = {"mamba": {"conv": jnp.stack(new_conv),
                            "h": jnp.stack(new_h)}, "attn": attn}
        return z, state2, None
    state2 = {"mamba": state["mamba"], "attn": attn}
    art = {"xp": jnp.stack(new_conv), "hs": jnp.stack(new_h)}
    return z, state2, art


def hybrid_paged_decode_step(params, state, tokens, lengths, n_new,
                             page_table, rcfg: RunConfig, *, page_size: int,
                             fused: bool = False):
    """Paged decode for the hybrid family: per-block composition keyed by
    block kind — mamba2 backbone layers advance state-snapshot pages,
    the interleaved shared-attention block reads/writes its KV pages —
    all against one page table / one physical page id space."""
    z, state2, _ = _hybrid_paged_forward(
        params, state, tokens, lengths, n_new, page_table, rcfg,
        page_size=page_size, commit=True, fused=fused)
    return _paged_last_logits(params, z, n_new, rcfg.model), state2


def hybrid_paged_verify_step(params, state, tokens, lengths, n_new,
                             page_table, rcfg: RunConfig, *, page_size: int,
                             fused: bool = False):
    """Speculative-verify forward for the hybrid family: shared-attention
    KV is written in-line (length-truncation rollback), backbone
    snapshot-page writes are deferred to
    :func:`hybrid_paged_commit_step`. Returns (logits (B,S,V), state',
    artifacts). ``fused`` routes the shared-attention segments through
    the paged kernels (the backbone's Python loop has no scan-carry cost
    to defer; its verify mixers already run commit-free)."""
    z, state2, art = _hybrid_paged_forward(
        params, state, tokens, lengths, n_new, page_table, rcfg,
        page_size=page_size, commit=False, fused=fused)
    return _paged_all_logits(params, z, rcfg.model), state2, art


def hybrid_paged_commit_step(state, art, page_table, lengths, n_write,
                             *, page_size: int):
    """Deferred backbone snapshot commit for the hybrid family (the attn
    half of ``state`` was already written by the verify forward)."""
    new_mamba = ssm_paged_commit_step(
        state["mamba"], art, page_table, lengths, n_write,
        page_size=page_size)
    return {"mamba": new_mamba, "attn": state["attn"]}


# ---------------------------------------------------------------------------
# Coarse-propagator draft model (speculative decoding)
# ---------------------------------------------------------------------------


def coarse_draft_params(params, rcfg: RunConfig, cf: int):
    """The paper's coarse propagator as a zero-parameter draft model.

    The multilevel hierarchy approximates the fine network with every
    ``cf``-th layer and the ODE step rescaled by ``cf``
    (:func:`repro.core.mgrit.coarse_restrict`) — exactly a weight-sharing
    draft for self-speculative decoding. Returns ``(draft_params,
    draft_rcfg, n_coarse)``:

    - decoder / ssm families: the full serial stack (open+mid+close with
      gates) is restricted to every ``cf``-th layer; the coarse gate is
      the SUM of the chunk's fine gates, so Phi_c(z) = z + (#real layers
      in chunk) * F(z) — the forward-Euler step over the chunk's time
      span — and fully-padded chunks stay identity. ``draft_rcfg`` is the
      fine rcfg (the paged steps read depth from the params).
    - hybrid: the mamba2 backbone is restricted and the chunk span is
      baked into each coarse layer's ``out_proj`` (the mixer is linear in
      it); the shared attention block is kept at a proportionally
      coarsened cadence. ``draft_rcfg`` carries the coarse ``n_layers`` /
      ``hybrid_attn_every``.

    Embeddings and final norm are shared by reference: the draft adds
    zero parameters and zero training.
    """
    cfg = rcfg.model
    if cf < 1:
        raise ValueError("cf must be >= 1")
    if cfg.family == "hybrid":
        N = cfg.n_layers
        n_coarse = -(-N // cf)
        sizes = jnp.minimum(cf, N - cf * jnp.arange(n_coarse))
        bb = mgrit.coarse_restrict(params["backbone"], cf)
        bb = dict(bb)
        bb["mixer"] = dict(bb["mixer"])
        op = bb["mixer"]["out_proj"]
        bb["mixer"]["out_proj"] = op * sizes.astype(op.dtype)[:, None, None]
        hae = min(max(1, cfg.hybrid_attn_every // cf), n_coarse)
        cfg_c = dataclasses.replace(cfg, n_layers=n_coarse,
                                    hybrid_attn_every=hae)
        draft = {"embed": params["embed"],
                 "final_norm": params["final_norm"],
                 "backbone": bb,
                 "shared_attn": params["shared_attn"]}
        return draft, dataclasses.replace(rcfg, model=cfg_c), n_coarse

    stacked, gates = _all_layers_stacked(params, cfg)
    N = jax.tree.leaves(stacked)[0].shape[0]
    n_coarse = -(-N // cf)
    gpad = jnp.pad(gates, (0, n_coarse * cf - N))
    cgate = gpad.reshape(n_coarse, cf).sum(axis=1)
    draft = {"embed": params["embed"],
             "final_norm": params["final_norm"],
             "mid": {"params": mgrit.coarse_restrict(stacked, cf),
                     "gate": cgate}}
    return draft, rcfg, n_coarse
