"""Feed-forward sublayers: SwiGLU / GELU MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, preln_output_scale
from repro.parallel.sharding import logical_constraint


def init_mlp(key, cfg: ModelConfig, d_ff: int = 0):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    oscale = 0.02 * preln_output_scale(cfg.n_layers)
    p = {
        "w_in": dense_init(ks[0], (d, ff), cfg.param_dtype),
        "w_out": dense_init(ks[1], (ff, d), cfg.param_dtype, scale=oscale),
    }
    if cfg.act == "silu":
        p["w_gate"] = dense_init(ks[2], (d, ff), cfg.param_dtype)
    return p


def mlp_apply(params, x, cfg: ModelConfig):
    with jax.named_scope("mlp_core"):
        dt = jnp.dtype(cfg.dtype)
        x = x.astype(dt)
        h = jnp.einsum("bsd,df->bsf", x, params["w_in"].astype(dt))
        if cfg.act == "silu":
            g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(dt))
            h = jax.nn.silu(g) * h
        else:
            h = jax.nn.gelu(h)
        h = logical_constraint(h, ("batch", "seq", "mlp"))
        y = jnp.einsum("bsf,fd->bsd", h, params["w_out"].astype(dt))
        return logical_constraint(y, ("batch", "seq", "embed"))
