"""Grouped-query attention with RoPE, qk-norm, KV cache, and cross-attention.

Layout conventions:
  activations        (B, S, D)          logical ("batch","seq","embed")
  q after projection (B, S, H, hd)      logical ("batch","seq","heads","head_dim")
  kv cache           (B, S_max, Hkv, hd) logical ("batch","kv_seq","kv_heads","head_dim")

The dense attention math lives in ``dot_attention``; when
``use_pallas=True`` the fused Pallas flash-attention kernel
(:mod:`repro.kernels.ops`) is used instead for the self-attention hot spot.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init, preln_output_scale, rms_norm, rope_freqs
from repro.parallel.sharding import logical_constraint


def init_attention(key, cfg: ModelConfig, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    pdt = cfg.param_dtype
    ks = jax.random.split(key, 6)
    oscale = 0.02 * preln_output_scale(cfg.n_layers)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), pdt),
        "wk": dense_init(ks[1], (d, hkv, hd), pdt),
        "wv": dense_init(ks[2], (d, hkv, hd), pdt, scale=oscale / 0.02 * 0.02),
        "wo": dense_init(ks[3], (h, hd, d), pdt, scale=oscale),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.dtype(pdt))
        p["k_norm"] = jnp.ones((hd,), jnp.dtype(pdt))
    return p


def _project_qkv(params, x, xa, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    src = x if xa is None else xa
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", src, params["wv"].astype(dt))
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    return q, k, v


def dot_attention(q, k, v, *, causal: bool, q_offset=0,
                  scale: Optional[float] = None):
    """Reference dense GQA attention.

    q: (B, Sq, H, hd); k, v: (B, Sk, Hkv, hd). ``q_offset`` is the absolute
    position of q[.., 0] for causal masking against a longer k (KV cache) —
    a scalar, or a (B,) array when each batch slot has its own position
    (paged serving, no left-padding).
    """
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    scale = scale if scale is not None else hd ** -0.5
    qg = q.reshape(B, Sq, Hkv, g, hd)
    logits = jnp.einsum("bqhgk,bshk->bhgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        Sk = k.shape[1]
        qoff = jnp.asarray(q_offset)
        qpos = qoff[..., None] + jnp.arange(Sq)       # (Sq,) or (B, Sq)
        kpos = jnp.arange(Sk)
        mask = qpos[..., :, None] >= kpos             # (.., Sq, Sk)
        mask = mask[:, None, None] if mask.ndim == 3 else mask[None, None, None]
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqs,bshk->bqhgk", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, hd)


def chunked_attention(q, k, v, *, causal: bool, q_block: int = 512,
                      k_block: int = 512):
    """Flash-style online-softmax attention in pure jnp (double blocked
    scan). Memory is O(Sq*Ck + Sk) per head instead of O(Sq*Sk) — this is
    the lowering-safe path for 32k prefill and the oracle for the Pallas
    kernel."""
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    scale = hd ** -0.5
    q_block = min(q_block, Sq)
    k_block = min(k_block, Sk)
    nq, nk = Sq // q_block, Sk // k_block
    # fold gqa groups: (B, Hkv, g, Sq, hd)
    qh = q.reshape(B, Sq, Hkv, g, hd).transpose(0, 2, 3, 1, 4) * scale
    kh = k.transpose(0, 2, 1, 3)                     # (B, Hkv, Sk, hd)
    vh = v.transpose(0, 2, 1, 3)

    qs = qh.reshape(B, Hkv, g, nq, q_block, hd).transpose(3, 0, 1, 2, 4, 5)
    ks = kh.reshape(B, Hkv, nk, k_block, hd).transpose(2, 0, 1, 3, 4)
    vs = vh.reshape(B, Hkv, nk, k_block, hd).transpose(2, 0, 1, 3, 4)

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx                          # (B,Hkv,g,qb,hd)
        qpos = iq * q_block + jnp.arange(q_block)

        def k_step(carry, kv_idx):
            m, l, acc = carry
            kj, vj, jk = kv_idx
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qi.astype(jnp.float32),
                           kj.astype(jnp.float32))
            if causal:
                kpos = jk * k_block + jnp.arange(k_block)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vj.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hkv, g, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, q_block), jnp.float32)
        a0 = jnp.zeros((B, Hkv, g, q_block, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            k_step, (m0, l0, a0), (ks, vs, jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(v.dtype)

    _, outs = jax.lax.scan(q_step, None, (qs, jnp.arange(nq)))
    # outs: (nq, B, Hkv, g, qb, hd) -> (B, Sq, H, hd)
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hkv, g, Sq, hd)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)


ATTN_CHUNK_THRESHOLD = 8192


def attention_apply(params, x, cfg: ModelConfig, *, causal: bool,
                    rope=None, positions=None, xa=None, cache=None,
                    use_pallas: bool = False):
    """Self/cross attention.

    x: (B, S, D). rope: precomputed (cos, sin) — shared across layers and a
    differentiable "extra" input for the layer-parallel custom VJP. xa:
    encoder output for cross-attention (no rope, no cache rotation). cache:
    dict(k, v, index) for autoregressive decode — the new k/v are scattered
    at ``index`` and attention runs over the full cache.
    Returns (out, new_cache).
    """
    dt = jnp.dtype(cfg.dtype)
    x = x.astype(dt)
    q, k, v = _project_qkv(params, x, xa, cfg)
    q = logical_constraint(q, ("batch", "seq", "heads", "head_dim"))
    k = logical_constraint(k, ("batch", "seq", "kv_heads", "head_dim"))

    if xa is None and rope is None and positions is not None:
        rope = rope_freqs(cfg.resolved_head_dim, cfg.rope_theta, positions)
    if xa is None and rope is not None:
        cos, sin = rope
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    new_cache = None
    q_offset = 0
    if cache is not None:
        idx = cache["index"]
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), idx, axis=1)
        ck = logical_constraint(ck, ("batch", "kv_seq", "kv_heads", "head_dim"))
        cv = logical_constraint(cv, ("batch", "kv_seq", "kv_heads", "head_dim"))
        new_cache = {"k": ck, "v": cv, "index": idx + x.shape[1]}
        k, v = ck, cv
        q_offset = idx

    with jax.named_scope("attn_core"):
        if use_pallas and cache is None and xa is None and q.shape[1] > 1:
            from repro.kernels import ops as kops
            out = kops.flash_attention(q, k, v, causal=causal)
        elif (cache is None and q.shape[1] >= (cfg.attn_chunk
                                               or ATTN_CHUNK_THRESHOLD)
              and q.shape[1] == k.shape[1] and q.shape[1] % 512 == 0):
            out = chunked_attention(q, k, v, causal=causal and xa is None)
        else:
            out = dot_attention(q, k, v, causal=causal and xa is None,
                                q_offset=q_offset)
    out = logical_constraint(out, ("batch", "seq", "heads", "head_dim"))
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    y = logical_constraint(y, ("batch", "seq", "embed"))
    return y, new_cache


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int):
    """Stacked-over-layers KV cache: (L, B, S, Hkv, hd)."""
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    return {
        "k": jnp.zeros((n_layers, batch, max_len, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((n_layers, batch, max_len, cfg.n_kv_heads, hd), dt),
        "index": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Paged KV cache (serving)
# ---------------------------------------------------------------------------
#
# Instead of one dense (B, max_len) cache per batch slot, K/V live in a pool
# of fixed-size pages shared by all sequences. A per-slot page table maps
# logical page p of slot b to a physical page id; finished sequences return
# their pages to the free list immediately (repro.serve.kv_pages). Page 0 is
# a scratch page that absorbs writes from padded prompt positions and
# unoccupied slots, so the jitted step needs no data-dependent shapes.


def init_paged_kv_cache(cfg: ModelConfig, n_layers: int, n_pages: int,
                        page_size: int):
    """Page pool stacked over layers: (L, n_pages, page_size, Hkv, hd)."""
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    shape = (n_layers, n_pages, page_size, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def paged_attention_apply(params, x, cfg: ModelConfig, *, rope, pk, pv,
                          page_table, lengths, n_new, fused: bool = False):
    """Self-attention reading/writing one layer's page pool.

    x: (B, S, D) new-token activations. Slot b contributes ``n_new[b] <= S``
    real tokens at absolute positions ``lengths[b] .. lengths[b]+n_new[b]-1``
    — every slot has its own coordinate system starting at 0, so there is no
    left-padding and ``n_new == 0`` marks an unoccupied slot (occupancy
    mask). rope: (cos, sin) of shape (B, S, hd/2) for those positions.
    pk/pv: (n_pages, page_size, Hkv, hd). page_table: (B, P) int32.
    Returns (y, new_pk, new_pv).

    Prefix-sharing contract: several slots may map the same physical page
    (read-only). The caller must guarantee every page overlapping a slot's
    write range [lengths[b], lengths[b]+n_new[b]) is private to that slot
    (allocator refcount 1) — copy-on-write forks
    (``repro.serve.cache.copy_state_page``) happen host-side before the
    step is launched.

    Partial-page ingest safety (token-granular sharing,
    ``CacheBackend.fork_partial``): a slot may start with ``lengths[b]``
    mid-page, its current page a whole-page copy of a donor whose rows
    past ``lengths[b] % page_size`` are stale. That is safe here by
    construction — K/V rows at positions ``>= lengths[b]`` are
    scatter-written before any read of them, and the causal window
    ``pos < lengths[b] + n_new[b]`` (masked per query) never exposes a
    row this call did not either inherit as valid or just write.

    ``fused=True`` routes the attention core through the flash-decode
    paged kernel (:func:`repro.kernels.ops.paged_attention`) — the page
    table is walked in-kernel (or, in ref mode on CPU, gathered at
    whatever width the caller sliced the table to) instead of always
    materializing the full (B, P*page_size, Hkv, hd) dense view. The
    scatter-write of new K/V and all mesh constraints are identical in
    both branches, and unpadded outputs are bitwise-equal.
    """
    dt = jnp.dtype(cfg.dtype)
    x = x.astype(dt)
    q, k, v = _project_qkv(params, x, None, cfg)
    cos, sin = rope
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = logical_constraint(q, ("batch", "seq", "heads", "head_dim"))
    k = logical_constraint(k, ("batch", "seq", "kv_heads", "head_dim"))

    B, S = x.shape[:2]
    n_pages, page_size = pk.shape[0], pk.shape[1]
    P = page_table.shape[1]
    pos = lengths[:, None] + jnp.arange(S)[None, :]               # (B, S)
    valid = jnp.arange(S)[None, :] < n_new[:, None]               # (B, S)
    slot = jnp.clip(pos // page_size, 0, P - 1)
    phys = jnp.take_along_axis(page_table, slot, axis=1)          # (B, S)
    # invalid writes (prompt padding / idle slots) all land in scratch page 0
    flat = jnp.where(valid, phys * page_size + pos % page_size, 0)
    flat = flat.reshape(-1)
    pk_flat = pk.reshape(n_pages * page_size, *pk.shape[2:])
    pv_flat = pv.reshape(n_pages * page_size, *pv.shape[2:])
    pk_flat = pk_flat.at[flat].set(k.astype(pk.dtype).reshape(
        B * S, *k.shape[2:]))
    pv_flat = pv_flat.at[flat].set(v.astype(pv.dtype).reshape(
        B * S, *v.shape[2:]))

    if fused:
        from repro.kernels import ops as kops
        with jax.named_scope("paged_attn_core_fused"):
            out = kops.paged_attention(q, pk_flat.reshape(pk.shape),
                                       pv_flat.reshape(pv.shape),
                                       page_table, lengths)
    else:
        # per-slot dense view in logical order: (B, P*page_size, Hkv, hd)
        gather = (page_table[:, :, None] * page_size
                  + jnp.arange(page_size)[None, None, :]).reshape(B, -1)
        kd = logical_constraint(pk_flat[gather],
                                ("batch", "kv_seq", "kv_heads", "head_dim"))
        vd = logical_constraint(pv_flat[gather],
                                ("batch", "kv_seq", "kv_heads", "head_dim"))

        # keys gathered in logical order sit at absolute positions
        # 0..cap-1; garbage beyond a slot's written length always has
        # kpos > qpos and masks out under the per-slot causal offset
        with jax.named_scope("paged_attn_core"):
            out = dot_attention(q, kd, vd, causal=True, q_offset=lengths)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    y = logical_constraint(y, ("batch", "seq", "embed"))
    # pools keep their mesh placement across steps (pages over serving
    # DP, kv heads over TP) instead of decaying to replicated
    new_pk = logical_constraint(pk_flat.reshape(pk.shape),
                                ("pages", None, "kv_heads", "head_dim"))
    new_pv = logical_constraint(pv_flat.reshape(pv.shape),
                                ("pages", None, "kv_heads", "head_dim"))
    return y, new_pk, new_pv


# -- fused ref-mode decode: pre-gathered views + deferred pool commit -------
#
# Shipping the stacked (L, N, page_size, Hkv, hd) pools through the layer
# scan as xs/ys costs two full-pool copies per step (scan input slicing +
# output stacking), no matter how few pages a step touches — on CPU that
# dominates steady-state decode. The fused ref path therefore never moves
# the pools through the scan: it gathers each slot's live pages ONCE into
# per-layer dense views (small: the caller's sliced table width), scans the
# layers over those views carrying only the (B, S) new K/V rows out, and
# publishes every layer's rows with ONE donated in-place scatter afterwards
# (paged_kv_commit). Consumed outputs and committed pages are bitwise-equal
# to the in-scan write path: the views hold exactly what a post-write
# gather would (write pages are private by the prefix-sharing contract, and
# scratch-page rows only surface at masked positions), and the commit uses
# the same flat-index formula as the per-layer writes.


def paged_view_gather(pool, page_table):
    """Per-slot dense views of a stacked page pool: (L, N, page_size, H,
    hd) + (B, P) -> (L, B, P*page_size, H, hd), rows in logical order."""
    L, n_pages, page_size = pool.shape[:3]
    B = page_table.shape[0]
    idx = (page_table[:, :, None] * page_size
           + jnp.arange(page_size)[None, None, :]).reshape(B, -1)
    return pool.reshape(L, n_pages * page_size, *pool.shape[3:])[:, idx]


def paged_view_attention_apply(params, x, cfg: ModelConfig, *, rope, kd, vd,
                               lengths, n_new):
    """One layer's self-attention over pre-gathered K/V views — the
    deferred-write twin of :func:`paged_attention_apply`'s fused branch.
    kd/vd: (B, cap, Hkv, hd) views from :func:`paged_view_gather`. The new
    tokens' K/V are inserted at their logical rows (writes beyond ``cap``
    or ``n_new`` drop), the attention core is the same causal-offset dot
    as the gathered path, and the pool write is left to
    :func:`paged_kv_commit`. Returns (y, k_new, v_new)."""
    dt = jnp.dtype(cfg.dtype)
    x = x.astype(dt)
    q, k, v = _project_qkv(params, x, None, cfg)
    cos, sin = rope
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = logical_constraint(q, ("batch", "seq", "heads", "head_dim"))
    k = logical_constraint(k, ("batch", "seq", "kv_heads", "head_dim"))
    B, S = x.shape[:2]
    cap = kd.shape[1]
    pos = lengths[:, None] + jnp.arange(S)[None, :]
    valid = jnp.arange(S)[None, :] < n_new[:, None]
    row = jnp.where(valid, pos, cap)          # out-of-bounds rows drop
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S))
    kd = kd.at[bidx, row].set(k.astype(kd.dtype))
    vd = vd.at[bidx, row].set(v.astype(vd.dtype))
    kd = logical_constraint(kd, ("batch", "kv_seq", "kv_heads", "head_dim"))
    vd = logical_constraint(vd, ("batch", "kv_seq", "kv_heads", "head_dim"))
    with jax.named_scope("paged_attn_core_fused_view"):
        out = dot_attention(q, kd, vd, causal=True, q_offset=lengths)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    y = logical_constraint(y, ("batch", "seq", "embed"))
    return y, k, v


def paged_kv_commit(pages, k_rows, v_rows, page_table, lengths, n_new):
    """Publish every layer's new K/V rows into the stacked page pools with
    one scatter per pool (in-place when the state is donated). k_rows /
    v_rows: (L, B, S, Hkv, hd) from the layer scan. Uses the same
    flat-index formula as :func:`paged_attention_apply` — invalid rows
    (padding / idle slots) land in scratch page 0."""
    pk, pv = pages["k"], pages["v"]
    L, n_pages, page_size = pk.shape[:3]
    B, S = k_rows.shape[1:3]
    P = page_table.shape[1]
    pos = lengths[:, None] + jnp.arange(S)[None, :]
    valid = jnp.arange(S)[None, :] < n_new[:, None]
    slot = jnp.clip(pos // page_size, 0, P - 1)
    phys = jnp.take_along_axis(page_table, slot, axis=1)
    flat = jnp.where(valid, phys * page_size + pos % page_size, 0)
    flat = flat.reshape(-1)
    rows = n_pages * page_size
    axes = (None, "pages", None, "kv_heads", "head_dim")

    def commit(pool, vals):
        new = pool.reshape(L, rows, *pool.shape[3:]).at[:, flat].set(
            vals.astype(pool.dtype).reshape(L, B * S, *pool.shape[3:]))
        return logical_constraint(new.reshape(pool.shape), axes)

    return {"k": commit(pk, k_rows), "v": commit(pv, v_rows)}
