"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64 — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

LP applicability (DESIGN.md §6): the *shared-weight* attention block
interleaved every 6 Mamba2 blocks makes the time grid heterogeneous and the
shared block is not an Euler step of a single F — MGRIT layer-parallelism is
inapplicable to the interleave. The trunk runs serially with Megatron TP;
the Mamba2 segments remain ODE-form so buffer-layer style serial execution
is exact.
"""
from repro.configs.base import (MGRITConfig, ModelConfig, RunConfig,
                                SSMConfig)
from repro.configs import registry

MODEL = ModelConfig(
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=32000,
    ssm=SSMConfig(version=2, d_state=64, d_conv=4, expand=2, headdim=64),
    hybrid_attn_every=6, norm="rmsnorm")

MGRIT = MGRITConfig(enabled=False)

CONFIG = RunConfig(model=MODEL, mgrit=MGRIT,
                   sharding=registry.tp_sharding())


def sharding_for(shape):
    if shape.kind == "train":
        return registry.tp_sharding()
    return registry.decode_sharding(long_context=shape.name == "long_500k")
