"""Paper BERT pre-training config (Table 2): 128 encoder layers, d=768,
12H, d_ff=3072, MLM on C4 (synthetic substitute here). MGRIT per Table 3:
cf=4, L=2, 1 fwd / 1 bwd iteration."""
from repro.configs.base import MGRITConfig, ModelConfig, RunConfig
from repro.configs import registry

MODEL = ModelConfig(
    name="bert128", family="encoder", n_layers=128, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=30522,
    act="gelu", norm="layernorm", max_seq_len=224, dropout=0.1)

MGRIT = MGRITConfig(cf=4, levels=2, fwd_iters=1, bwd_iters=1, pad_to=128)

CONFIG = RunConfig(model=MODEL, mgrit=MGRIT,
                   sharding=registry.train_sharding())
