"""Architecture registry: ``get_config(arch, shape)`` -> RunConfig.

Each assigned architecture lives in its own module (``configs/<id>.py``)
exporting ``CONFIG`` (a RunConfig factory). Paper architectures
(bert128/gpt2/vit/mc/mt) are included for the reproduction benchmarks.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

from repro.configs.base import (RunConfig, SHAPE_BY_NAME,
                                ShardingConfig)

ARCH_IDS = (
    "zamba2_1p2b",
    "deepseek_7b",
    "phi4_mini_3p8b",
    "qwen3_1p7b",
    "granite_34b",
    "qwen2_vl_7b",
    "grok1_314b",
    "qwen3_moe_235b",
    "seamless_m4t_v2",
    "falcon_mamba_7b",
    # the paper's own experiment architectures
    "bert128",
    "gpt2_nanogpt",
    "vit32",
    "mc_tiny",
    "mt_marian",
)

# canonical <id> spellings from the assignment table
ALIASES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "deepseek-7b": "deepseek_7b",
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "qwen3-1.7b": "qwen3_1p7b",
    "granite-34b": "granite_34b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "grok-1-314b": "grok1_314b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "seamless-m4t-large-v2": "seamless_m4t_v2",
    "falcon-mamba-7b": "falcon_mamba_7b",
}


def get_config(arch: str, shape: str = "train_4k") -> RunConfig:
    arch = ALIASES.get(arch, arch).replace("-", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    rcfg: RunConfig = mod.CONFIG
    shp = SHAPE_BY_NAME[shape]
    sharding = mod.sharding_for(shp) if hasattr(mod, "sharding_for") \
        else rcfg.sharding
    mb = TRAIN_MICROBATCHES.get(arch, 1) if shp.kind == "train" else 1
    return dataclasses.replace(rcfg, shape=shp, sharding=sharding,
                               microbatches=mb)


def shape_supported(arch: str, shape: str) -> Optional[str]:
    """None if supported, else a skip reason (recorded in EXPERIMENTS.md)."""
    arch = ALIASES.get(arch, arch).replace("-", "_")
    cfg = get_config(arch, "train_4k").model
    if shape == "long_500k":
        subq = cfg.family in ("ssm", "hybrid")
        if not subq:
            return ("full quadratic attention: 512k KV-cache decode is "
                    "excluded per assignment (sub-quadratic archs only)")
    if shape.startswith("decode") or shape == "long_500k":
        if cfg.family == "encoder":
            return "encoder-only: no autoregressive decode step"
    return None


def train_sharding() -> ShardingConfig:
    """Paper regime: layer-parallel over 'model', batch over data(+pod)."""
    return ShardingConfig(batch="data+pod", layers="model", vocab="model",
                          fsdp=None)


def tp_sharding() -> ShardingConfig:
    """Megatron TP over 'model' (serving, and zamba2 training)."""
    return ShardingConfig(batch="data+pod", heads="model", mlp="model",
                          vocab="model", layers=None)


def decode_sharding(long_context: bool = False) -> ShardingConfig:
    """Serving: Megatron TP + flash-decoding style KV-seq sharding over
    'model' (partial softmax + combine inserted by GSPMD), FSDP storage
    sharding of big weights over 'data'."""
    s = dataclasses.replace(tp_sharding(), kv_seq="model", fsdp="data")
    if long_context:
        # batch=1: the data axis moves onto the cache sequence dim too
        s = dataclasses.replace(s, kv_seq="data+model", batch=None)
    return s


def serve_sharding() -> ShardingConfig:
    """Mesh-sharded paged serving (the ServeEngine's default under a
    mesh): weights Megatron-TP over 'model' (heads / d_ff / SSM inner
    dims), decode-state page pools and the slot batch over 'data'. The
    scheduler/allocator stay host-side and mesh-blind — page ids and
    slot ids are global; only device arrays carry shardings (see
    docs/sharding.md)."""
    return ShardingConfig(batch="data", heads="model", mlp="model",
                          vocab="model", layers=None, pages="data")


# gradient-accumulation microbatches per arch for train_4k: bounds the live
# MGRIT state + activation memory per chip (EXPERIMENTS.md §Dry-run)
TRAIN_MICROBATCHES = {
    "deepseek_7b": 4, "phi4_mini_3p8b": 4, "qwen3_1p7b": 4,
    "qwen2_vl_7b": 4, "granite_34b": 16, "grok1_314b": 8,
    "qwen3_moe_235b": 16, "seamless_m4t_v2": 8, "falcon_mamba_7b": 8,
    "zamba2_1p2b": 4,
}
