"""Reduced configs for CPU smoke tests: same family/structure, tiny dims.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct);
smoke tests instantiate these reductions and run a real forward/train step.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (MGRITConfig, ModelConfig, MoEConfig,
                                RunConfig, SSMConfig, ShapeConfig)


def reduce_model(m: ModelConfig) -> ModelConfig:
    kw = dict(
        d_model=64,
        n_heads=4,
        n_kv_heads=min(m.n_kv_heads, 2) if m.n_kv_heads < m.n_heads else 4,
        d_ff=128,
        vocab_size=256,
        max_seq_len=128,
        head_dim=16,
    )
    if m.family == "encdec":
        kw["n_layers"] = 6
        kw["n_dec_layers"] = 6
    elif m.family == "hybrid":
        kw["n_layers"] = 8
        kw["hybrid_attn_every"] = 3
    else:
        kw["n_layers"] = 10
    if m.moe is not None:
        kw["moe"] = MoEConfig(num_experts=4, top_k=2, d_ff=128)
    if m.ssm is not None:
        kw["ssm"] = SSMConfig(version=m.ssm.version, d_state=8, d_conv=4,
                              expand=2, headdim=16)
    return dataclasses.replace(m, **kw)


def reduce_mgrit(mg: MGRITConfig, model: ModelConfig) -> MGRITConfig:
    if not mg.enabled:
        return mg
    n_open = min(mg.n_open, 1)
    n_close = min(mg.n_close, 1)
    if model.family == "encdec":
        n_open = n_close = 0
        pad_to = 8
    else:
        pad_to = 8
    return dataclasses.replace(mg, cf=2, levels=2, n_open=n_open,
                               n_close=n_close, pad_to=pad_to)


def reduce_config(rcfg: RunConfig, seq: int = 16, batch: int = 2) -> RunConfig:
    model = reduce_model(rcfg.model)
    return dataclasses.replace(
        rcfg,
        model=model,
        mgrit=reduce_mgrit(rcfg.mgrit, model),
        shape=ShapeConfig("smoke", "train", seq, batch),
        use_pallas=False,
        microbatches=1,
    )
