"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2 [hf:xai-org/grok-1; unverified].

314B params: weights are layer-sharded over 'model' (LP chunks) AND
storage-sharded over 'data' (FSDP) — XLA all-gathers just-in-time.
"""
import dataclasses

from repro.configs.base import (MGRITConfig, ModelConfig, MoEConfig,
                                OptimizerConfig, RunConfig)
from repro.configs import registry

MODEL = ModelConfig(
    name="grok-1-314b", family="decoder", n_layers=64, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=32768, vocab_size=131072,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff=32768),
    act="gelu", norm="rmsnorm")

# 64 = 1 + 1 buffers + 62 -> pad 64 (J=16 @ cf=4)
MGRIT = MGRITConfig(cf=4, levels=2, fwd_iters=2, bwd_iters=1,
                    n_open=1, n_close=1, pad_to=64)

# bf16 moments: 314B params x 12B/param of fp32 Adam state would not fit a
# single pod's 4 TB HBM (see EXPERIMENTS.md §Dry-run)
CONFIG = RunConfig(
    model=MODEL, mgrit=MGRIT,
    optimizer=OptimizerConfig(moment_dtype="bfloat16"),
    sharding=dataclasses.replace(registry.train_sharding(),
                                 fsdp="data", experts=None))


def sharding_for(shape):
    if shape.kind == "train":
        return CONFIG.sharding
    return dataclasses.replace(
        registry.decode_sharding(long_context=shape.name == "long_500k"),
        fsdp="data")
