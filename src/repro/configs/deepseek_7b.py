"""deepseek-7b [dense]: 30L d_model=4096 32H (GQA kv=32) d_ff=11008
vocab=102400 — llama-arch [arXiv:2401.02954; hf]."""

from repro.configs.base import MGRITConfig, ModelConfig, RunConfig
from repro.configs import registry

MODEL = ModelConfig(
    name="deepseek-7b", family="decoder", n_layers=30, d_model=4096,
    n_heads=32, n_kv_heads=32, d_ff=11008, vocab_size=102400,
    act="silu", norm="rmsnorm")

# 30 = 1 open + 1 close buffer + 28 ParallelNet padded to 32 (J=16 @ cf=2)
MGRIT = MGRITConfig(cf=2, levels=2, fwd_iters=2, bwd_iters=1,
                    n_open=1, n_close=1, pad_to=32)

CONFIG = RunConfig(model=MODEL, mgrit=MGRIT,
                   sharding=registry.train_sharding())


def sharding_for(shape):
    if shape.kind == "train":
        return registry.train_sharding()
    return registry.decode_sharding(long_context=shape.name == "long_500k")
