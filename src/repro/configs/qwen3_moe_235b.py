"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

128 experts shard 8-per-device over the 16-way 'data' axis (EP) while the
layer grid shards over 'model' (LP) — the paper's orthogonal-parallelism
claim exercised with expert parallelism instead of plain DP.
"""
import dataclasses

from repro.configs.base import (MGRITConfig, ModelConfig, MoEConfig,
                                RunConfig)
from repro.configs import registry

MODEL = ModelConfig(
    name="qwen3-moe-235b-a22b", family="decoder", n_layers=94, d_model=4096,
    n_heads=64, n_kv_heads=4, d_ff=1536, vocab_size=151936,
    qk_norm=True, moe=MoEConfig(num_experts=128, top_k=8, d_ff=1536),
    act="silu", norm="rmsnorm", rope_theta=1000000.0)

# 94 = 1 + 1 buffers + 92 -> pad 96; cf=3 J=32 L=2
MGRIT = MGRITConfig(cf=3, levels=2, fwd_iters=2, bwd_iters=1,
                    n_open=1, n_close=1, pad_to=96)

CONFIG = RunConfig(
    model=MODEL, mgrit=MGRIT,
    sharding=dataclasses.replace(registry.train_sharding(),
                                 experts="data", fsdp="data"))


def sharding_for(shape):
    if shape.kind == "train":
        return CONFIG.sharding
    return dataclasses.replace(
        registry.decode_sharding(long_context=shape.name == "long_500k"),
        experts="data", fsdp="data")
