"""Config system for the layer-parallel transformer framework.

Every architecture is described by a `ModelConfig`; the MGRIT layer-parallel
solver by an `MGRITConfig`; an experiment cell (arch x input shape x mesh) by
a `RunConfig`. Configs are plain frozen dataclasses so they are hashable and
usable as jit static arguments.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    # d_ff of each expert (may differ from dense d_ff)
    d_ff: int = 0
    router_jitter: float = 0.0
    # load-balancing auxiliary loss weight (Switch-style)
    aux_loss_weight: float = 0.01
    # dispatch group size along the sequence (GShard groups): 0 = whole
    # sequence per group (baseline). Smaller groups shrink the
    # (B,S,E,C) dispatch/combine tensors quadratically (§Perf).
    group_size: int = 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-family state space config."""
    version: int = 1             # 1 = Mamba1 (falcon-mamba), 2 = Mamba2 (zamba2)
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2              # d_inner = expand * d_model
    headdim: int = 64            # mamba2 head dim
    dt_rank: int = 0             # 0 -> ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "decoder"      # decoder | encoder | encdec | hybrid | ssm
    n_layers: int = 12           # decoder layers for decoder/ssm/hybrid,
                                 # encoder layers for encoder family
    n_dec_layers: int = 0        # only for encdec
    d_model: int = 768
    n_heads: int = 12
    n_kv_heads: int = 12
    d_ff: int = 3072
    vocab_size: int = 32000
    max_seq_len: int = 4096
    head_dim: int = 0            # 0 -> d_model // n_heads
    # block features
    qk_norm: bool = False
    rope_theta: float = 10000.0
    mrope: bool = False          # multimodal rope (qwen2-vl) -- positions stub
    act: str = "silu"            # silu (SwiGLU) | gelu
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one shared attention block applied every
    # `hybrid_attn_every` backbone blocks
    hybrid_attn_every: int = 0
    # modality frontend stub: "none" | "audio" | "vision"
    frontend: str = "none"
    dropout: float = 0.0
    dtype: str = "bfloat16"      # compute dtype
    # "float32" baseline; "bfloat16" = mixed precision with fp32 master
    # weights in the optimizer (halves weight-read + FSDP-gather bytes)
    param_dtype: str = "float32"
    # switch to flash-style chunked attention at this sequence length
    # (8192 baseline = dense below 8k, as a vanilla XLA model would run)
    attn_chunk: int = 8192

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + self.n_heads * hd * d
        if self.ssm is not None and self.family == "ssm":
            di = self.ssm.expand * d
            blk = d * (2 * di) + di * d + di * (self.ssm.d_state * 2 + 2) \
                + di * self.ssm.d_conv
            n_blocks = self.n_layers
            total = n_blocks * blk
        elif self.moe is not None:
            ff = self.moe.d_ff or self.d_ff
            moe_mlp = self.moe.num_experts * 3 * d * ff + d * self.moe.num_experts
            total = self.n_layers * (attn + moe_mlp)
        else:
            mlp = 3 * d * self.d_ff if self.act == "silu" else 2 * d * self.d_ff
            total = self.n_layers * (attn + mlp)
            if self.family == "encdec":
                total += self.n_dec_layers * (2 * attn + mlp)
        total += v * d * (1 if self.tie_embeddings else 2)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top_k of num_experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        ff = self.moe.d_ff or self.d_ff
        dense = self.param_count() - self.n_layers * self.moe.num_experts * 3 * d * ff
        return dense + self.n_layers * self.moe.top_k * 3 * d * ff


# ---------------------------------------------------------------------------
# MGRIT / layer-parallel
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MGRITConfig:
    enabled: bool = True
    cf: int = 4                  # coarsening factor
    levels: int = 2              # L
    fwd_iters: int = 1           # V-cycles for forward solve (0 = serial fwd)
    bwd_iters: int = 1           # V-cycles for adjoint solve (0 = serial bwd)
    n_open: int = 0              # serial buffer layers at the start (App. B)
    n_close: int = 0             # serial buffer layers at the end
    h: float = 1.0               # fine-level time step
    # pad the ParallelNet depth to a multiple of this (layer-parallel degree
    # divisibility); padded steps are exact identity (gate = 0).
    pad_to: int = 0
    # adaptive control (paper 3.2.3)
    check_every: int = 500       # batches between indicator probes
    switch_threshold: float = 1.0
    # how many MGRIT levels keep their chunk axis sharded (1 = level 0
    # only, the paper's layout; 2 also shards the first coarse level's
    # relaxation when divisible — §Perf)
    shard_levels: int = 1


# ---------------------------------------------------------------------------
# Input shapes (the assigned shape suite)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                    # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4096, 256),
    ShapeConfig("prefill_32k", "prefill", 32768, 32),
    ShapeConfig("decode_32k", "decode", 32768, 128),
    ShapeConfig("long_500k", "decode", 524288, 1),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


# ---------------------------------------------------------------------------
# Sharding strategy (logical->physical axis rules)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardingConfig:
    """Maps logical axes to physical mesh axes.

    Logical axes used throughout the codebase:
      batch, layers, heads, kv_heads, mlp, embed, vocab, experts, kv_seq,
      seq, pages
    Values are physical axis names or None (replicated). "data+pod" means the
    product of the two axes.
    """
    batch: Optional[str] = "data"
    layers: Optional[str] = None      # MGRIT chunk axis
    heads: Optional[str] = None       # TP over attention heads
    mlp: Optional[str] = None         # TP over d_ff
    vocab: Optional[str] = "model"    # logits/vocab sharding
    embed: Optional[str] = None
    experts: Optional[str] = None     # expert parallelism
    kv_seq: Optional[str] = None      # KV-cache sequence sharding (long ctx)
    pages: Optional[str] = None       # paged-serving state pools (page axis)
    fsdp: Optional[str] = None        # storage sharding of big weight dims
    # whether gradient reduction across pods uses int8 compression
    compress_grads: bool = False


# ---------------------------------------------------------------------------
# Run config = one experiment cell
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    # bf16 moments let 300B-class models fit a single pod (EXPERIMENTS §Dry-run)
    moment_dtype: str = "float32"
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    schedule: str = "cosine"     # cosine | linear | constant
    total_steps: int = 10000


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    mgrit: MGRITConfig = MGRITConfig()
    optimizer: OptimizerConfig = OptimizerConfig()
    shape: ShapeConfig = SHAPES[0]
    sharding: ShardingConfig = ShardingConfig()
    use_pallas: bool = False
    remat: bool = True           # activation checkpointing in serial path
    # gradient-accumulation microbatches (bounds live MGRIT state memory)
    microbatches: int = 1
    seed: int = 0

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)
