"""Paper machine-translation config (Table 2): Marian-style enc-dec,
6+6 layers, d=512, 8H, d_ff=2048, vocab 32000 (OPUS de-en). MGRIT Table 3:
cf=3, L=2, 3 bwd iterations; Fig. 7 scales depth to 160+160."""
from repro.configs.base import MGRITConfig, ModelConfig, RunConfig
from repro.configs import registry

MODEL = ModelConfig(
    name="mt-marian", family="encdec", n_layers=6, n_dec_layers=6,
    d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=32000,
    act="gelu", norm="layernorm", max_seq_len=274, dropout=0.1)

MGRIT = MGRITConfig(cf=3, levels=2, fwd_iters=2, bwd_iters=3, pad_to=6)

CONFIG = RunConfig(model=MODEL, mgrit=MGRIT,
                   sharding=registry.train_sharding())
