"""phi4-mini-3.8b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064 — RoPE SwiGLU GQA [arXiv:2412.08905; hf]."""
from repro.configs.base import MGRITConfig, ModelConfig, RunConfig
from repro.configs import registry

MODEL = ModelConfig(
    name="phi4-mini-3.8b", family="decoder", n_layers=32, d_model=3072,
    n_heads=24, n_kv_heads=8, d_ff=8192, vocab_size=200064,
    act="silu", norm="rmsnorm")

# 32 = 1 + 1 buffers + 30 -> pad 32 (J=16 @ cf=2)
MGRIT = MGRITConfig(cf=2, levels=2, fwd_iters=2, bwd_iters=1,
                    n_open=1, n_close=1, pad_to=32)

CONFIG = RunConfig(model=MODEL, mgrit=MGRIT,
                   sharding=registry.train_sharding())


def sharding_for(shape):
    if shape.kind == "train":
        return registry.train_sharding()
    return registry.decode_sharding(long_context=shape.name == "long_500k")
