"""qwen3-1.7b [dense]: 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936 — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
from repro.configs.base import MGRITConfig, ModelConfig, RunConfig
from repro.configs import registry

MODEL = ModelConfig(
    name="qwen3-1.7b", family="decoder", n_layers=28, d_model=2048,
    n_heads=16, n_kv_heads=8, d_ff=6144, vocab_size=151936,
    qk_norm=True, act="silu", norm="rmsnorm", rope_theta=1000000.0)

# 28 = 1 + 1 buffers + 26 -> pad 32 (J=16 @ cf=2)
MGRIT = MGRITConfig(cf=2, levels=2, fwd_iters=2, bwd_iters=1,
                    n_open=1, n_close=1, pad_to=32)

CONFIG = RunConfig(model=MODEL, mgrit=MGRIT,
                   sharding=registry.train_sharding())


def sharding_for(shape):
    if shape.kind == "train":
        return registry.train_sharding()
    return registry.decode_sharding(long_context=shape.name == "long_500k")
