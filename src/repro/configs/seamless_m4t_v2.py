"""seamless-m4t-large-v2 [audio]: 24L d_model=1024 16H d_ff=8192
vocab=256206 — enc-dec, multimodal [arXiv:2308.11596; hf].

The paper's NOVEL encoder-decoder neural-ODE formulation (Eq. 3): the
stacked time grid is block-triangular (X frozen after T_enc, Y frozen
before), so it is implemented as two chained MGRIT solves — mathematically
identical, see DESIGN.md §6. The speech frontend is a STUB: input_specs()
provides precomputed frame embeddings.
"""
from repro.configs.base import MGRITConfig, ModelConfig, RunConfig
from repro.configs import registry

MODEL = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec", n_layers=24,
    n_dec_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab_size=256206, frontend="audio", act="gelu", norm="layernorm")

# enc 24 -> pad 32, dec 24 -> pad 32 (J=16 @ cf=2), no buffers (enc-dec)
MGRIT = MGRITConfig(cf=2, levels=2, fwd_iters=2, bwd_iters=1,
                    n_open=0, n_close=0, pad_to=32)

CONFIG = RunConfig(model=MODEL, mgrit=MGRIT,
                   sharding=registry.train_sharding())


def sharding_for(shape):
    if shape.kind == "train":
        return registry.train_sharding()
    return registry.decode_sharding(long_context=shape.name == "long_500k")
