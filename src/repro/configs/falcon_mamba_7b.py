"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16 — mamba1 arch [arXiv:2410.05355; unverified].

Attention-free residual trunk: F = Mamba1 o RMSNorm is a textbook neural-ODE
right-hand side, so the paper's technique applies directly. O(1) decode
state => runs the long_500k shape.
"""
from repro.configs.base import MGRITConfig, ModelConfig, RunConfig, SSMConfig
from repro.configs import registry

MODEL = ModelConfig(
    name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
    n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=65024,
    ssm=SSMConfig(version=1, d_state=16, d_conv=4, expand=2),
    norm="rmsnorm")

# 64 = 1 + 1 buffers + 62 -> pad 64 (J=16 @ cf=4, paper's BERT cf)
MGRIT = MGRITConfig(cf=4, levels=2, fwd_iters=2, bwd_iters=1,
                    n_open=1, n_close=1, pad_to=64)

CONFIG = RunConfig(model=MODEL, mgrit=MGRIT,
                   sharding=registry.train_sharding())


def sharding_for(shape):
    if shape.kind == "train":
        return registry.train_sharding()
    return registry.decode_sharding(long_context=shape.name == "long_500k")
