"""Paper morphological-classification config (Table 2): encoder-only
neural ODE transformer, d=128, 1 head, d_ff=128, up to 64+ layers in the
scaling studies. MGRIT: cf=2 (Table 3: cf=8 for strong scaling; Fig. 3 uses
cf=2), 2 fwd / 1 bwd iterations."""
from repro.configs.base import MGRITConfig, ModelConfig, RunConfig
from repro.configs import registry

MODEL = ModelConfig(
    name="mc-tiny", family="encoder", n_layers=64, d_model=128,
    n_heads=1, n_kv_heads=1, d_ff=128, vocab_size=8000,
    act="gelu", norm="layernorm", max_seq_len=2048)

MGRIT = MGRITConfig(cf=2, levels=2, fwd_iters=2, bwd_iters=1, pad_to=64)

CONFIG = RunConfig(model=MODEL, mgrit=MGRIT,
                   sharding=registry.train_sharding())
