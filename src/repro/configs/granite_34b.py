"""granite-34b [dense]: 88L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152 — llama-arch, code [arXiv:2405.04324; hf].

Deepest assigned arch — the paper's sweet spot (Fig. 8 right: LP benefit
grows with depth). MQA (kv=1) makes head-TP unattractive; LP sidesteps it.
"""
from repro.configs.base import MGRITConfig, ModelConfig, RunConfig
from repro.configs import registry

MODEL = ModelConfig(
    name="granite-34b", family="decoder", n_layers=88, d_model=6144,
    n_heads=48, n_kv_heads=1, d_ff=24576, vocab_size=49152,
    act="gelu", norm="layernorm")

# 88 = 1 + 1 buffers + 86 -> pad 96; cf=2 J=48, L=3 (48 -> 24 serial)
MGRIT = MGRITConfig(cf=2, levels=3, fwd_iters=2, bwd_iters=1,
                    n_open=1, n_close=1, pad_to=96)

CONFIG = RunConfig(model=MODEL, mgrit=MGRIT,
                   sharding=registry.train_sharding())


def sharding_for(shape):
    if shape.kind == "train":
        import dataclasses
        # 68B bf16 params need storage sharding over data as well
        return dataclasses.replace(registry.train_sharding(), fsdp="data")
    return registry.decode_sharding(long_context=shape.name == "long_500k")
