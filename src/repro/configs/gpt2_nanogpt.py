"""Paper GPT2 config (Table 2 + App. B): 20 decoder layers, d=768, 12H,
nanoGPT-style. Buffer layers: 2 open + 2 close serial (Delta-t=1), middle 16
in the ParallelNet with Delta-t = 1/16 (App. B / Fig. 12). Serial forward,
1 parallel backward iteration, cf=4 (Table 3)."""
from repro.configs.base import MGRITConfig, ModelConfig, RunConfig
from repro.configs import registry

MODEL = ModelConfig(
    name="gpt2-nanogpt", family="decoder", n_layers=20, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=50304,
    act="gelu", norm="layernorm", max_seq_len=1024)

MGRIT = MGRITConfig(cf=4, levels=2, fwd_iters=0, bwd_iters=1,
                    n_open=2, n_close=2, pad_to=16, h=1.0 / 16.0)

CONFIG = RunConfig(model=MODEL, mgrit=MGRIT,
                   sharding=registry.train_sharding())
