"""Paper ViT config: 32 encoder layers, d=768, 12H, patch16 (frontend
stubbed as patch embeddings). Serial forward, 1 parallel backward, cf=4."""
from repro.configs.base import MGRITConfig, ModelConfig, RunConfig
from repro.configs import registry

MODEL = ModelConfig(
    name="vit32", family="encoder", n_layers=32, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=1000,
    frontend="vision", act="gelu", norm="layernorm", max_seq_len=197)

MGRIT = MGRITConfig(cf=4, levels=2, fwd_iters=0, bwd_iters=1, pad_to=32)

CONFIG = RunConfig(model=MODEL, mgrit=MGRIT,
                   sharding=registry.train_sharding())
