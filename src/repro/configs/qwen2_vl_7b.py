"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (B, S_mm, D) prepended to token embeddings.
"""
from repro.configs.base import MGRITConfig, ModelConfig, RunConfig
from repro.configs import registry

MM_TOKENS = 256  # stubbed patch-embedding positions per sample

MODEL = ModelConfig(
    name="qwen2-vl-7b", family="decoder", n_layers=28, d_model=3584,
    n_heads=28, n_kv_heads=4, d_ff=18944, vocab_size=152064,
    mrope=True, frontend="vision", act="silu", norm="rmsnorm")

# 28 = 1 + 1 buffers + 26 -> pad 32 (J=16 @ cf=2)
MGRIT = MGRITConfig(cf=2, levels=2, fwd_iters=2, bwd_iters=1,
                    n_open=1, n_close=1, pad_to=32)

CONFIG = RunConfig(model=MODEL, mgrit=MGRIT,
                   sharding=registry.train_sharding())


def sharding_for(shape):
    if shape.kind == "train":
        return registry.train_sharding()
    return registry.decode_sharding(long_context=shape.name == "long_500k")
