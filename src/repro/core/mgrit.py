"""MGRIT (multigrid-reduction-in-time) over the transformer layer dimension.

This is the paper's core algorithm (Fig. 2 / Appendix A), adapted from
MPI+GPU (TorchBraid) to JAX GSPMD:

  * the fine time grid of N layers is chunked into J = N/c_f coarse
    intervals; the J axis is the logical "layers" axis, sharded over the
    physical "model" mesh axis (the paper's layer distribution over ranks);
  * F-relaxation = vmap over J of a (c_f-1)-step lax.scan  -> fully parallel;
  * C-relaxation's cross-chunk shift lowers to collective-permute
    (the MPI halo exchange);
  * the FAS coarse solve gathers coarse points to replicated (the serial
    coarse solve of the paper) and either scans exactly (coarsest level) or
    recurses (L > 2).

The solver is generic over the stepping function, so the *same* code runs
the forward solve (nonlinear Phi) and the adjoint solve (linearized
transpose propagator) — see :mod:`repro.core.adjoint`.

Notation maps to the paper: ``step_fn`` is Phi, ``cf`` is c_f, ``levels`` is
L, one call to :func:`_vcycle` is one MGRIT V-cycle iteration.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import logical_constraint

# step_fn(stacked_n: pytree_slice, z, h: float) -> z_next
StepFn = Callable[[Any, Any, float], Any]


@dataclasses.dataclass(frozen=True)
class MGRITSpec:
    cf: int = 4
    levels: int = 2
    iters: int = 1
    h: float = 1.0
    # constrain the level-0 chunk axis to the "layers" logical axis
    shard: bool = True
    # levels [0, shard_levels) keep the chunk axis sharded; deeper levels
    # replicate (the paper's serial coarse solve). Non-divisible chunk
    # counts fall back to replication automatically.
    shard_levels: int = 1
    # names of the state's own axes, e.g. ("batch", None, None) for (B,S,D)
    znames: Tuple[Optional[str], ...] = ("batch", None, None)


def _tree_idx(tree, idx):
    return jax.tree.map(lambda a: a[idx], tree)


def _chunk(tree, J: int, cf: int):
    return jax.tree.map(lambda a: a.reshape((J, cf) + a.shape[1:]), tree)


def _constrain(x, spec: MGRITSpec, lead: Tuple[Optional[str], ...]):
    if not spec.shard:
        return x
    return logical_constraint(x, lead + spec.znames)


# ---------------------------------------------------------------------------
# Relaxation sweeps
# ---------------------------------------------------------------------------


def _f_relax(step_fn: StepFn, chunked, Zc, g, spec: MGRITSpec, h: float):
    """F-relaxation: propagate c_f - 1 steps from every coarse point.

    Zc: (J, *state) current coarse-point values.
    g:  None or (J, cf, *state) FAS rhs (g[j, i] is added producing point
        j*cf + i + 1).
    Returns U: (J, cf, *state) with U[j, i] = Z_{j*cf+i}.
    """
    cf = spec.cf

    def chunk_fn(z0j, p_chunk, g_chunk):
        def stp(z, xs):
            p_i, g_i = xs
            z2 = step_fn(p_i, z, h)
            if g_i is not None:
                z2 = z2 + g_i
            return z2, z2

        if cf == 1:
            return z0j[None]
        xs = (_tree_idx(p_chunk, slice(0, cf - 1)),
              g_chunk[: cf - 1] if g_chunk is not None else None)
        if g_chunk is None:
            # avoid scanning a None: wrap with zero-free variant
            def stp0(z, p_i):
                z2 = step_fn(p_i, z, h)
                return z2, z2
            _, ys = jax.lax.scan(stp0, z0j, _tree_idx(p_chunk, slice(0, cf - 1)))
        else:
            _, ys = jax.lax.scan(lambda z, xs: stp(z, xs), z0j, xs)
        return jnp.concatenate([z0j[None], ys], axis=0)

    U = jax.vmap(chunk_fn)(Zc, chunked, g)
    return _constrain(U, spec, ("layers", None))


def _c_step(step_fn: StepFn, chunked, U, g, spec: MGRITSpec, h: float):
    """Propagate the last fine point of every chunk across the boundary:
    W[j] = Phi(U[j, cf-1]) (+ g[j, cf-1]) = candidate value for Z_{(j+1)cf}."""
    cf = spec.cf
    p_last = _tree_idx(chunked, (slice(None), cf - 1))
    u_last = U[:, cf - 1]
    W = jax.vmap(lambda p, u: step_fn(p, u, h))(p_last, u_last)
    if g is not None:
        W = W + g[:, cf - 1]
    return _constrain(W, spec, ("layers",))


def _shift(z0, W, spec: MGRITSpec):
    """New coarse points after C-relaxation: [z0, W[0], ..., W[J-2]];
    the slice across the sharded J axis lowers to collective-permute."""
    Zc = jnp.concatenate([z0[None], W[:-1]], axis=0)
    return _constrain(Zc, spec, ("layers",))


# ---------------------------------------------------------------------------
# Exact serial solves (coarsest level / reference / buffer layers)
# ---------------------------------------------------------------------------


def serial_solve(step_fn: StepFn, stacked, z0, h: float, g=None,
                 remat: bool = False):
    """Exact forward substitution Z_{n+1} = Phi(Z_n) + g_n (a lax.scan).

    Returns (states, zT): states[n] = Z_n for n = 0..N-1 and zT = Z_N.
    """
    body = step_fn
    if remat:
        body = jax.checkpoint(step_fn, static_argnums=(2,))

    def stp(z, xs):
        if g is None:
            p = xs
            z2 = body(p, z, h)
        else:
            p, g_n = xs
            z2 = body(p, z, h) + g_n
        return z2, z

    xs = stacked if g is None else (stacked, g)
    zT, states = jax.lax.scan(stp, z0, xs)
    return states, zT


# ---------------------------------------------------------------------------
# Level restriction
# ---------------------------------------------------------------------------


def coarse_restrict(stacked, cf: int):
    """Level restriction R: the coarse propagator's stacked arguments are
    the fine arguments at every ``cf``-th layer (paper Fig. 2 — coarse
    point j reuses the fine weights of layer ``j*cf``; the ODE step is
    rescaled by the caller: ``h_c = h * cf`` inside the V-cycle, a gate /
    residual scale at serve time). This is the single owner of the
    coarse-grid restriction, shared by the MGRIT solver below and the
    serve engine's coarse-propagator draft model
    (``repro.serve.spec`` via ``transformer.coarse_draft_params``).

    Unlike the solver (which requires ``N % cf == 0``), the restriction
    itself accepts any depth: the last coarse layer of a ragged stack
    stands in for ``N - (J-1)*cf < cf`` fine layers.
    """
    return jax.tree.map(lambda a: a[::cf], stacked)


# ---------------------------------------------------------------------------
# The V-cycle
# ---------------------------------------------------------------------------


def _vcycle(step_fn: StepFn, stacked, z0, states, zT, g, spec: MGRITSpec,
            level: int, h: float, final_frelax: bool = True):
    """One FAS MGRIT V-cycle at `level`.

    stacked: pytree (N_l, ...); states: (N_l, *state) current values
    (states[n] = Z_n, n < N_l); zT: Z_{N_l}; g: None or (N_l, *state).
    Returns (states, zT, resnorm) improved.

    ``final_frelax=False`` skips the trailing interpolation F-relaxation:
    it is bit-identical to the FIRST sweep of the next V-cycle (F-points
    are recomputed from unchanged C-points), so consecutive cycles only
    need it once (§Perf beyond-paper optimization; saves one relaxation
    sweep per extra iteration).
    """
    N = jax.tree.leaves(stacked)[0].shape[0]
    cf = spec.cf
    assert N % cf == 0, f"level {level}: N={N} not divisible by cf={cf}"
    J = N // cf
    lspec = spec if level < spec.shard_levels else \
        dataclasses.replace(spec, shard=False)

    chunked = _chunk(stacked, J, cf)
    gc_fine = None if g is None else g.reshape((J, cf) + g.shape[1:])
    Zc = states.reshape((J, cf) + states.shape[1:])[:, 0]
    Zc = _constrain(Zc, lspec, ("layers",))

    # ---- FCF relaxation (paper Alg. 1) ----
    U = _f_relax(step_fn, chunked, Zc, gc_fine, lspec, h)          # F
    W = _c_step(step_fn, chunked, U, gc_fine, lspec, h)            # C
    Zc = _shift(z0, W, lspec)
    zT = W[-1]
    U = _f_relax(step_fn, chunked, Zc, gc_fine, lspec, h)          # F
    # propagated C-values of the relaxed iterate (for residual + FAS rhs)
    W = _c_step(step_fn, chunked, U, gc_fine, lspec, h)

    # ---- residual at C-points:  r_{(j+1)cf} = W[j] - Z_{(j+1)cf} ----
    u0 = jnp.concatenate([Zc, zT[None]], axis=0)                   # (J+1, ...)
    r = W - u0[1:]
    resnorm = jnp.sqrt(jnp.sum(jnp.square(r.astype(jnp.float32))))

    # ---- coarse grid (FAS): u_{j+1} = Phi_c(u_j) + g_c[j] ----
    coarse = coarse_restrict(stacked, cf)
    h_c = h * cf
    # replicate the coarse problem (the paper's serial coarse solve)
    u0_rep = logical_constraint(u0, (None,) + spec.znames) \
        if lspec.shard else u0
    phi_c_u0 = jax.vmap(lambda p, u: step_fn(p, u, h_c))(coarse, u0_rep[:-1])
    g_c = W - phi_c_u0                                             # (J, ...)
    if lspec.shard:
        g_c = logical_constraint(g_c, (None,) + spec.znames)

    if level + 1 >= spec.levels - 1 or J % cf != 0:
        # exact coarsest solve: serial forward substitution
        cs, czT = serial_solve(step_fn, coarse, z0, h_c, g=g_c)
        u_new = jnp.concatenate([cs, czT[None]], axis=0)
    else:
        cs0 = u0_rep[:-1]
        cs, czT, _ = _vcycle(step_fn, coarse, z0, cs0, u0_rep[-1], g_c,
                             spec, level + 1, h_c)
        u_new = jnp.concatenate([cs, czT[None]], axis=0)

    # ---- correct C-points and final F-relax (interpolation) ----
    e = u_new - u0_rep
    if lspec.shard:
        e = _constrain(e[:-1], lspec, ("layers",))
        Zc = Zc + e
        zT = zT + u_new[-1] - u0_rep[-1]
    else:
        Zc = Zc + e[:-1]
        zT = zT + e[-1]
    if final_frelax:
        U = _f_relax(step_fn, chunked, Zc, gc_fine, lspec, h)
        states = U.reshape((N,) + U.shape[2:])
    else:
        # write back corrected C-points only; stale F-points are overwritten
        # by the next cycle's opening F-relaxation anyway
        U = U.at[:, 0].set(Zc)
        states = U.reshape((N,) + U.shape[2:])
    return states, zT, resnorm


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def mgrit_solve(step_fn: StepFn, stacked, z0, spec: MGRITSpec,
                init_states=None, init_zT=None):
    """Run `spec.iters` MGRIT V-cycles for the evolution
    ``Z_{n+1} = step_fn(stacked[n], Z_n, h)``.

    Returns (states (N, *state) with states[n] = Z_n, zT, resnorms (iters,)).

    Initialization (when init_states is None) is the coarse-grid
    propagation (nested iteration / FMG init): serial coarse traversal with
    Phi_c, then an F-relaxation fills fine points.
    """
    N = jax.tree.leaves(stacked)[0].shape[0]
    cf = spec.cf
    J = N // cf
    chunked = _chunk(stacked, J, cf)

    if init_states is None:
        coarse = coarse_restrict(stacked, cf)
        cs, czT = serial_solve(step_fn, coarse, z0, spec.h * cf)
        Zc0 = _constrain(cs, spec, ("layers",))
        U = _f_relax(step_fn, chunked, Zc0, None, spec, spec.h)
        states = U.reshape((N,) + U.shape[2:])
        zT = czT
    else:
        states, zT = init_states, init_zT

    norms = []
    n_iters = max(spec.iters, 1)
    for i in range(n_iters):
        states, zT, rn = _vcycle(step_fn, stacked, z0, states, zT, None,
                                 spec, 0, spec.h,
                                 final_frelax=(i == n_iters - 1))
        norms.append(rn)
    return states, zT, jnp.stack(norms)
