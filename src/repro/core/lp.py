"""LayerParallelNet: the paper's ParallelNet as a composable JAX module.

``lp_forward`` evaluates the neural-ODE trunk Z_{n+1} = Z_n + h*gate_n*F_n(Z_n)
with either an exact serial solve (fwd_iters=0) or `fwd_iters` MGRIT V-cycles
(inexact, layer-parallel). Its custom VJP runs the *adjoint* equation
(paper Eq. 4 right) through the same MGRIT solver with an independent
`bwd_iters` count — reproducing the paper's inexact biased gradients with
serial-forward/parallel-backward combinations (Table 3's dashes).

Everything inside the trunk is stacked over the layer (time) axis, so the
logical "layers" axis shards the solve over the mesh's "model" axis.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MGRITConfig, ModelConfig
from repro.core import mgrit
from repro.models.blocks import block_F

Extra = Dict[str, Any]  # differentiable per-call inputs: rope cos/sin, xa


@dataclasses.dataclass(frozen=True)
class LPStatic:
    cfg: ModelConfig
    mgrit: MGRITConfig
    kind: str               # block kind: attn_mlp | attn_moe | encdec_dec | mamba1 | mamba2
    causal: bool = True
    use_pallas: bool = False
    znames: Tuple[Optional[str], ...] = ("batch", None, None)

    def spec(self, iters: int) -> mgrit.MGRITSpec:
        return mgrit.MGRITSpec(cf=self.mgrit.cf, levels=self.mgrit.levels,
                               iters=iters, h=self.mgrit.h, shard=True,
                               shard_levels=self.mgrit.shard_levels,
                               znames=self.znames)


def eval_F(static: LPStatic, params, z, extra: Extra):
    """The ODE right-hand side F(t_n, Z) of paper Eq. 1/2."""
    f, _ = block_F(params, z, static.cfg, kind=static.kind,
                   causal=static.causal, positions=None,
                   rope=extra.get("rope"), xa=extra.get("xa"),
                   use_pallas=static.use_pallas)
    return f


def make_fwd_step(static: LPStatic, extra: Extra) -> mgrit.StepFn:
    """Phi(z) = z + h * gate * F(z). `slot` = {"params", "gate"}."""
    def step(slot, z, h):
        f = eval_F(static, slot["params"], z, extra)
        return z + (jnp.asarray(h, z.dtype) * slot["gate"].astype(z.dtype)) * f
    return step


def make_adj_step(static: LPStatic, extra: Extra) -> mgrit.StepFn:
    """Adjoint propagator Psi(lam) = lam + h*gate*(dF/dZ)^T lam, evaluated at
    the stored forward state. `slot` = {"params", "gate", "z"}."""
    def step(slot, lam, h):
        _, vjp_fn = jax.vjp(
            lambda z: eval_F(static, slot["params"], z, extra), slot["z"])
        (dz,) = vjp_fn(lam)
        return lam + (jnp.asarray(h, lam.dtype)
                      * slot["gate"].astype(lam.dtype)) * dz
    return step


# ---------------------------------------------------------------------------
# Solves
# ---------------------------------------------------------------------------


def _forward_solve(static: LPStatic, stacked, z0, extra, iters: int):
    step = make_fwd_step(static, extra)
    if iters <= 0:
        states, zT = mgrit.serial_solve(step, stacked, z0, static.mgrit.h)
        norms = jnp.zeros((1,), jnp.float32)
    else:
        states, zT, norms = mgrit.mgrit_solve(step, stacked, z0,
                                              static.spec(iters))
    return states, zT, norms


def _adjoint_solve(static: LPStatic, stacked, states, lamN, extra,
                   iters: int):
    """Solve the adjoint backward from lam_N. Returns (rev_lam, lam0, norms)
    with rev_lam[n] = lambda_{n+1} (the multiplier hitting layer n's output)."""
    rev = lambda a: jnp.flip(a, axis=0)
    adj_stacked = {
        "params": jax.tree.map(rev, stacked["params"]),
        "gate": rev(stacked["gate"]),
        "z": rev(states),
    }
    step = make_adj_step(static, extra)
    if iters <= 0:
        mu_states, mu_T = mgrit.serial_solve(step, adj_stacked, lamN,
                                             static.mgrit.h)
        norms = jnp.zeros((1,), jnp.float32)
    else:
        mu_states, mu_T, norms = mgrit.mgrit_solve(step, adj_stacked, lamN,
                                                   static.spec(iters))
    # mu_states[m] = lambda_{N-m}; layer n consumes lambda_{n+1} = mu[N-1-n]
    rev_lam = rev(mu_states)
    return rev_lam, mu_T, norms


def _param_grads(static: LPStatic, stacked, states, rev_lam, extra):
    """Per-layer gradients g_theta_n = h*gate_n*(dF/dtheta_n)^T lambda_{n+1}
    and the summed extra-input cotangent — fully layer-parallel (vmap)."""
    h = static.mgrit.h

    def one(p, gate, z, lam_next):
        def f(pp, ee):
            return eval_F(static, pp, z, ee)
        _, vjp_fn = jax.vjp(f, p, extra)
        ct = (jnp.asarray(h, lam_next.dtype) * gate.astype(lam_next.dtype)) \
            * lam_next
        dp, de = vjp_fn(ct)
        return dp, de

    dps, des = jax.vmap(one)(stacked["params"], stacked["gate"], states,
                             rev_lam)
    d_extra = jax.tree.map(lambda a: jnp.sum(a, axis=0), des)
    return dps, d_extra


# ---------------------------------------------------------------------------
# custom_vjp binding
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def lp_forward(static: LPStatic, stacked, z0, extra: Extra):
    """Returns (zT, fwd_residual_norms). Gradient is the MGRIT adjoint."""
    _, zT, norms = _forward_solve(static, stacked, z0, extra,
                                  static.mgrit.fwd_iters)
    return zT, norms


def _lp_fwd(static, stacked, z0, extra):
    states, zT, norms = _forward_solve(static, stacked, z0, extra,
                                       static.mgrit.fwd_iters)
    return (zT, norms), (stacked, states, extra)


def _lp_bwd(static, res, cts):
    stacked, states, extra = res
    ct_zT, _ct_norms = cts
    # the adjoint runs in the trunk's compute dtype (lambda ~ z)
    ct_zT = ct_zT.astype(states.dtype)
    rev_lam, lam0, _ = _adjoint_solve(static, stacked, states, ct_zT, extra,
                                      static.mgrit.bwd_iters)
    dps, d_extra = _param_grads(static, stacked, states, rev_lam, extra)
    d_stacked = {"params": dps,
                 "gate": jnp.zeros_like(stacked["gate"]),
                 }
    return d_stacked, lam0, d_extra


lp_forward.defvjp(_lp_fwd, _lp_bwd)


# ---------------------------------------------------------------------------
# Diagnostics for the adaptive controller (paper 3.2.3, Fig. 5)
# ---------------------------------------------------------------------------


def lp_diagnose(static: LPStatic, stacked, z0, extra, seed_ct,
                fwd_iters: int, bwd_iters: int):
    """Run forward + adjoint MGRIT with explicit iteration counts and return
    both residual-norm sequences (the controller doubles the counts to
    estimate the convergence factor of the final iteration)."""
    states, zT, fwd_norms = _forward_solve(static, stacked, z0, extra,
                                           max(fwd_iters, 1))
    lamN = seed_ct(zT)
    _, _, bwd_norms = _adjoint_solve(static, stacked, states, lamN, extra,
                                     max(bwd_iters, 1))
    return fwd_norms, bwd_norms


# ---------------------------------------------------------------------------
# Stacked-layer utilities (padding, gates, buffers)
# ---------------------------------------------------------------------------


def pad_depth(n_real: int, pad_to: int) -> int:
    if pad_to <= 0:
        return n_real
    return ((n_real + pad_to - 1) // pad_to) * pad_to


def make_gates(n_real: int, n_padded: int, dtype=jnp.float32):
    g = jnp.arange(n_padded) < n_real
    return g.astype(dtype)


def stack_init(init_fn, key, n: int):
    """vmap an init function over n layer keys -> stacked params (n, ...)."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)
