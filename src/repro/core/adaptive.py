"""Adaptive control of MGRIT inexactness (paper §3.2.3, Fig. 5).

Every ``check_every`` batches the trainer runs a *probe*: it transiently
doubles the MGRIT iteration count and evaluates the convergence factor of the
final iteration, rho = ||r^(k+1)|| / ||r^(k)||. When rho exceeds the
threshold (1.0 in the paper) the gradients' bias has grown too large; the
controller either raises the iteration count or switches the trainer to the
serial (exact) jitted step — reproducing the green curves of Fig. 4.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.configs.base import MGRITConfig


@dataclasses.dataclass
class ControllerState:
    mode: str = "lp"                  # "lp" | "serial"
    fwd_iters: int = 1
    bwd_iters: int = 1
    step_of_switch: Optional[int] = None
    history: List[Tuple[int, float, float]] = dataclasses.field(
        default_factory=list)        # (step, rho_fwd, rho_bwd)


def convergence_factor(norms: np.ndarray) -> float:
    """rho of the final iteration: ||r^(k+1)||/||r^(k)||."""
    norms = np.asarray(norms, dtype=np.float64)
    if norms.size < 2:
        return 0.0
    denom = norms[-2]
    if denom <= 1e-30:   # already at machine floor: converged
        return 0.0
    return float(norms[-1] / denom)


class AdaptiveController:
    """Host-side controller; the trainer consults it to pick the jitted
    step (LP vs serial) and the iteration counts."""

    def __init__(self, mgrit: MGRITConfig, escalate: bool = False,
                 max_iters: int = 8):
        self.cfg = mgrit
        self.escalate = escalate      # raise iters instead of going serial
        self.max_iters = max_iters
        self.state = ControllerState(
            mode="lp" if mgrit.enabled else "serial",
            fwd_iters=mgrit.fwd_iters, bwd_iters=mgrit.bwd_iters)

    def should_probe(self, step: int) -> bool:
        return (self.state.mode == "lp" and step > 0
                and step % self.cfg.check_every == 0)

    def probe_iters(self) -> Tuple[int, int]:
        """Doubled iteration counts used for the probe (paper 3.2.3)."""
        return (max(2 * self.state.fwd_iters, 2),
                max(2 * self.state.bwd_iters, 2))

    def observe(self, step: int, fwd_norms, bwd_norms) -> str:
        rho_f = convergence_factor(fwd_norms)
        rho_b = convergence_factor(bwd_norms)
        self.state.history.append((step, rho_f, rho_b))
        rho = max(rho_f, rho_b)
        if rho < self.cfg.switch_threshold:
            return "ok"
        if self.escalate and max(self.state.fwd_iters,
                                 self.state.bwd_iters) < self.max_iters:
            self.state.fwd_iters = min(2 * max(self.state.fwd_iters, 1),
                                       self.max_iters)
            self.state.bwd_iters = min(2 * max(self.state.bwd_iters, 1),
                                       self.max_iters)
            return "escalated"
        self.state.mode = "serial"
        self.state.step_of_switch = step
        return "switched"
