"""Trainer: the paper's full training procedure.

  * layer-parallel (MGRIT) steps by default, serial steps on demand;
  * adaptive inexactness control (paper §3.2.3): every ``check_every``
    steps run a doubled-iteration probe, compute the convergence factor,
    and switch LP -> serial when it crosses 1 (Fig. 4 green curves);
  * fault tolerance: periodic atomic checkpoints, resume-from-latest,
    emergency checkpoint on exception;
  * straggler watch: EWMA of step wall-time, slow steps logged.

The LP and serial steps are two separately jitted functions; switching is a
host-side decision (it happens once per run, like the paper's).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.core.adaptive import AdaptiveController
from repro.core import lp as lp_mod
from repro.data.pipeline import make_pipeline, shard_batch
from repro.launch import steps as steps_mod
from repro.models import transformer
from repro.models.blocks import block_kind
from repro.optim import optimizers
from repro.train import checkpoint as ckpt_mod


@dataclasses.dataclass
class TrainReport:
    losses: List[float]
    mode_trace: List[str]
    controller_history: List
    switched_at: Optional[int]
    steps_per_sec: float


class Trainer:
    def __init__(self, rcfg: RunConfig, mesh=None, ckpt_dir: str = "",
                 seed: int = 0, data_path: str = ""):
        self.rcfg = rcfg
        self.mesh = mesh
        self.ckpt_dir = ckpt_dir
        self.controller = AdaptiveController(rcfg.mgrit)
        self.pipeline = make_pipeline(rcfg, seed, data_path)
        key = jax.random.PRNGKey(seed)
        self.params = transformer.init_model(key, rcfg)
        self.opt_state = optimizers.init_opt_state(
            rcfg.optimizer, self.params,
            moment_dtype=jnp.dtype(rcfg.optimizer.moment_dtype))
        self.step = 0
        self._steps: Dict[str, Callable] = {}
        self._probe_fn = None
        self._ewma_dt = None

        if ckpt_dir:
            restored = ckpt_mod.restore(ckpt_dir, self.params,
                                        self.opt_state, mesh, rcfg)
            if restored is not None:
                self.params, self.opt_state, self.step, extra = restored
                if extra.get("controller_mode"):
                    self.controller.state.mode = extra["controller_mode"]

    # -- jitted steps (built lazily, cached per mode) --
    def _step_fn(self, mode: str):
        if mode not in self._steps:
            rcfg = self.rcfg
            if mode == "serial":
                rcfg = rcfg.replace(
                    mgrit=dataclasses.replace(rcfg.mgrit, enabled=False))
            self._steps[mode] = jax.jit(steps_mod.make_train_fn(
                rcfg, self.mesh), donate_argnums=(0, 1))
        return self._steps[mode]

    def _probe(self, batch):
        """Paper's indicator probe: doubled iterations, measure rho."""
        fwd_it, bwd_it = self.controller.probe_iters()
        rcfg = self.rcfg
        cfg = rcfg.model
        kind = block_kind(cfg)
        if cfg.family in ("hybrid",):
            return None  # LP inapplicable; controller never probes anyway

        static = lp_mod.LPStatic(
            cfg=cfg,
            mgrit=dataclasses.replace(rcfg.mgrit, fwd_iters=fwd_it,
                                      bwd_iters=bwd_it),
            kind=kind, causal=cfg.family != "encoder")

        from repro.models.layers import rope_freqs
        from repro.models.transformer import _embed_inputs, _serial_buffer

        def run(params, batch):
            z = _embed_inputs(params, batch, cfg)
            rope = None if kind in ("mamba1", "mamba2") else rope_freqs(
                cfg.resolved_head_dim, cfg.rope_theta,
                jnp.arange(z.shape[1], dtype=jnp.int32))
            z = _serial_buffer(params.get("open"), z, cfg, kind=kind,
                               causal=static.causal, rope=rope)
            extra = {"rope": rope} if rope is not None else {}
            return lp_mod.lp_diagnose(
                static, params["mid"], z, extra,
                seed_ct=lambda zT: jnp.ones_like(zT)
                / jnp.asarray(zT.size, zT.dtype),
                fwd_iters=fwd_it, bwd_iters=bwd_it)

        if self._probe_fn is None:
            self._probe_fn = jax.jit(run)
        return self._probe_fn(self.params, batch)

    def train(self, num_steps: int, ckpt_every: int = 0,
              log_every: int = 50, probe: bool = True) -> TrainReport:
        losses, modes = [], []
        t_start = time.time()
        try:
            for _ in range(num_steps):
                batch = shard_batch(self.pipeline.batch_at(self.step),
                                    self.mesh, self.rcfg)
                mode = self.controller.state.mode
                t0 = time.time()

                if probe and self.controller.should_probe(self.step):
                    res = self._probe(batch)
                    if res is not None:
                        fwd_norms, bwd_norms = res
                        action = self.controller.observe(
                            self.step, np.asarray(fwd_norms),
                            np.asarray(bwd_norms))
                        if action == "switched":
                            mode = "serial"

                fn = self._step_fn(mode)
                self.params, self.opt_state, metrics = fn(
                    self.params, self.opt_state, batch)
                dt = time.time() - t0
                self._ewma_dt = dt if self._ewma_dt is None else \
                    0.9 * self._ewma_dt + 0.1 * dt
                if dt > 3.0 * self._ewma_dt:
                    print(f"[straggler] step {self.step} took {dt:.2f}s "
                          f"(ewma {self._ewma_dt:.2f}s)")
                losses.append(float(metrics["loss"]))
                modes.append(mode)
                self.step += 1
                if ckpt_every and self.step % ckpt_every == 0:
                    self._save()
                if log_every and self.step % log_every == 0:
                    print(f"step {self.step} [{mode}] "
                          f"loss={losses[-1]:.4f}")
        except Exception:
            if self.ckpt_dir:
                self._save(tag="emergency")
            raise
        dt_total = time.time() - t_start
        return TrainReport(
            losses=losses, mode_trace=modes,
            controller_history=list(self.controller.state.history),
            switched_at=self.controller.state.step_of_switch,
            steps_per_sec=len(losses) / max(dt_total, 1e-9))

    def _save(self, tag: str = ""):
        ckpt_mod.save(self.ckpt_dir, self.step, self.params, self.opt_state,
                      extra={"controller_mode": self.controller.state.mode,
                             "tag": tag})
