"""Fault-tolerant checkpointing.

Design for 1000+ nodes (documented trade-offs for the single-host build):
  * atomic write: serialize to <dir>/.tmp-<step>, fsync, rename — a crash
    mid-write never corrupts the latest checkpoint;
  * keep-k rotation + a LATEST pointer file;
  * checkpoints store *logical* (fully-replicated) arrays + the pytree
    structure, so restore can re-shard onto ANY mesh — this is the elastic
    scaling path (restart on 128 chips from a 256-chip checkpoint);
  * resume contract: (params, opt_state, step, controller_state); the data
    pipeline is step-indexed so the stream replays exactly;
  * emergency checkpoint hook for trainer exceptions (straggler/node-failure
    path: the surviving coordinator snapshots and the job restarts
    elsewhere). At real scale the np.savez leaves become per-host shard
    files written in parallel; the atomic-rename + manifest protocol is
    unchanged.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    arrs = {f"a{i}": np.asarray(jax.device_get(x)) for i, x in
            enumerate(leaves)}
    return arrs, treedef


def save(ckpt_dir: str, step: int, params, opt_state,
         extra: Optional[Dict[str, Any]] = None, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=f".tmp-{step}-", dir=ckpt_dir)
    try:
        p_arrs, _ = _flatten(params)
        o_arrs, _ = _flatten(opt_state)
        np.savez(os.path.join(tmp, "params.npz"), **p_arrs)
        np.savez(os.path.join(tmp, "opt.npz"), **o_arrs)
        meta = {"step": step, "extra": extra or {}}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        final = os.path.join(ckpt_dir, f"step_{step:010d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    with open(os.path.join(ckpt_dir, "LATEST"), "w") as f:
        f.write(os.path.basename(final))
    _rotate(ckpt_dir, keep)
    return final


def _rotate(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name, "meta.json")):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, params_template, opt_template,
            mesh=None, rcfg=None) -> Optional[Tuple[Any, Any, int, Dict]]:
    """Restore onto the CURRENT mesh (elastic: templates define the target
    sharding; stored arrays are logical/full)."""
    step = latest_step(ckpt_dir)
    if step is None:
        return None
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)

    def load(npz_path, template):
        arrs = np.load(npz_path)
        leaves, treedef = jax.tree.flatten(template)
        loaded = [arrs[f"a{i}"] for i in range(len(leaves))]
        if mesh is not None and rcfg is not None:
            from repro.parallel.params import param_specs
            specs = jax.tree.flatten(param_specs(template, rcfg, mesh))[0] \
                if template is not None else None
        out = []
        for a, t in zip(loaded, leaves, strict=True):
            a = a.astype(t.dtype) if hasattr(t, "dtype") else a
            out.append(jax.device_put(a))
        return jax.tree.unflatten(treedef, out)

    params = load(os.path.join(d, "params.npz"), params_template)
    opt_state = load(os.path.join(d, "opt.npz"), opt_template)
    if mesh is not None and rcfg is not None:
        from repro.parallel.params import param_specs
        specs = param_specs(params, rcfg, mesh)
        params = jax.tree.map(lambda a, s: jax.device_put(a, s), params,
                              specs)
    return params, opt_state, meta["step"], meta.get("extra", {})
