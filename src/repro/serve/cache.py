"""Unified ``CacheBackend`` API — one decode-state protocol per family.

The paper's neural-ODE view treats every block family as one abstract
propagator Phi; this module does the same for *decode state*. The serve
engine and scheduler never mention model families: they talk to a
:class:`CacheBackend`, which owns

  device half  ``init(max_batch, n_pages) -> state`` plus the jitted
               occupancy-masked step — ``prefill(state, slots, tokens)``
               (S = prompt bucket) and ``step(state, slots, tokens)``
               (S = 1) both return ``(state, next_tokens)`` with
               per-request sampling applied inside the jitted call;
  host half    the page ops ``alloc_view / share / fork / release`` over a
               refcounted :class:`~repro.serve.kv_pages.PageAllocator`.
               Backends with non-paged state may implement them as no-ops;
               all three backends here are fully paged.

Backends:

- :class:`PagedKVBackend` — attention decoders. Pages hold ``page_size``
  tokens of K/V per layer (``attention.init_paged_kv_cache``).
- :class:`SSMStateBackend` — mamba1/mamba2 models. Pages hold fixed-size
  recurrent-state *snapshots*: page p of a slot is the (conv window, h)
  state after exactly ``(p+1)*page_size`` tokens (see
  ``repro.models.ssm`` "Paged recurrent state"), so the same allocator
  refcounting, prefix trie, and copy-on-write forking apply.
- :class:`HybridBackend` — per-block composition keyed by block kind
  (zamba2): mamba2 snapshot pools for the backbone + KV pools for the
  interleaved shared-attention block, one shared page id space.

The ``snapshot_state`` capability is the only semantic difference the
scheduler ever sees: snapshot pages cannot be read in the same jitted call
that writes them (state reads happen at scan start), and a full-prompt
prefix hit cannot rewind a snapshot to recompute just the final token —
the scheduler drops such pages from the match instead of forking them.

**Meshes.** ``make_backend(..., mesh=, sharding=)`` makes any backend
SPMD: params are placed tensor-parallel over the mesh's 'model' axis
(heads / d_ff / SSM inner dims, via :func:`repro.parallel.params.
param_specs`) and the page pools are sharded over 'data' on the physical
page axis (:func:`repro.parallel.params.paged_state_specs`), so
``prefill`` / ``step`` / ``verify`` each stay ONE jitted call — GSPMD
inserts the collectives. Everything host-side (scheduler, allocator,
prefix trie, page tables, slot ids) is mesh-blind: page ids are global,
only device arrays carry :class:`jax.sharding.NamedSharding`. See
docs/sharding.md.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.configs.registry import serve_sharding
from repro.launch import steps as steps_mod
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer
from repro.models.blocks import block_kind
from repro.obs import profile as obs_profile
from repro.parallel import params as pshard
from repro.parallel.sharding import _axis_size, resolve_axis
from repro.serve.kv_pages import PageAllocator


@dataclasses.dataclass
class SlotBatch:
    """Host-side view of the decode slots for one jitted call: decode
    coordinates (per-slot position, occupancy, page mapping) plus the
    vectorized per-request sampling parameters."""
    lengths: np.ndarray        # (B,)   cached tokens per slot
    n_new: np.ndarray          # (B,)   new tokens this call (0 = idle slot)
    page_table: np.ndarray     # (B, P) physical page ids (0 = scratch)
    temps: np.ndarray          # (B,)   0 = exact greedy argmax path
    top_ks: np.ndarray         # (B,)   0 disables
    top_ps: np.ndarray         # (B,)   1 disables
    seeds: np.ndarray          # (B,)   per-request PRNG stream
    counters: np.ndarray       # (B,)   tokens already emitted

    @classmethod
    def greedy(cls, batch: int, page_table, lengths=None, n_new=None):
        """All-greedy slots (probes, tests)."""
        return cls(
            lengths=np.zeros((batch,), np.int32) if lengths is None
            else np.asarray(lengths, np.int32),
            n_new=np.ones((batch,), np.int32) if n_new is None
            else np.asarray(n_new, np.int32),
            page_table=np.asarray(page_table, np.int32),
            temps=np.zeros((batch,), np.float32),
            top_ks=np.zeros((batch,), np.int32),
            top_ps=np.ones((batch,), np.float32),
            seeds=np.zeros((batch,), np.int32),
            counters=np.zeros((batch,), np.int32))


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_tree_page(state, src, dst):
    return jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), state)


def copy_state_page(state, src: int, dst: int):
    """Copy-on-write fork, device half: duplicate physical page ``src``
    into ``dst`` across every pool leaf of any backend's state (page axis
    1 by convention). src/dst are traced scalars, so one compile per
    state structure covers all id pairs. The host half — refcounts and
    picking ``dst`` — is ``PageAllocator.fork`` via
    :meth:`CacheBackend.fork`."""
    return _copy_tree_page(state, jnp.asarray(src, jnp.int32),
                           jnp.asarray(dst, jnp.int32))


class CacheBackend:
    """Base backend: subclasses set ``snapshot_state`` and implement
    ``init_state`` (device pools) + ``_decode_fn`` (the family's paged
    forward, signature of ``transformer.paged_decode_step``).

    Args:
        rcfg: the model's RunConfig; ``rcfg.sharding`` supplies the
            logical->physical axis rules when a mesh is active.
        params: model weights. Under a mesh they are re-placed
            tensor-parallel (``param_specs``) at construction; callers
            keep their replicated copy untouched.
        mesh: optional ``jax.sharding.Mesh`` with ('data', 'model') axes.
            None (default) runs single-device, exactly as before.
        page_size: tokens per KV page / tokens between state snapshots.
        sharding: optional ShardingConfig override for serving; defaults
            to :func:`repro.configs.registry.serve_sharding` when a mesh
            is given (TP weights + 'data'-sharded page pools) and to
            ``rcfg.sharding`` otherwise.
        fused: route decode/prefill through the fused paged kernels
            (``repro.kernels.ops``): page-walking attention / compact-
            commit SSM cores plus the sort-free sampling epilogue, with
            the page table sliced host-side to the live-page bucket
            (power-of-two widths, so at most log2(P)+1 step traces).
            Temperature-0 output is bitwise-identical either way. The
            speculative ``verify`` wave gets the same treatment — fused
            forwards and a sliced table — so spec decode keeps its edge
            over the (equally fused) plain decode it races.
        obs: optional :class:`repro.obs.Observability` bundle. Every
            jitted callable this backend builds registers an XLA-trace
            counter in ``obs.compile_counts`` (the
            ``engine.compiles_per_callable`` gauge), and the host
            dispatch sites are wrapped in opt-in profiler spans. None
            keeps a private counts dict and no-op spans — same compiled
            code either way.
    """

    #: pages are state snapshots (SSM/hybrid): no intra-wave sharing, no
    #: tail forks on full-prompt prefix hits (see module docstring)
    snapshot_state = False

    def __init__(self, rcfg: RunConfig, params, mesh=None,
                 page_size: int = 16, sharding=None, fused: bool = True,
                 obs=None):
        if mesh is not None:
            rcfg = rcfg.replace(sharding=sharding or serve_sharding())
            params = jax.device_put(
                params, pshard.param_specs(params, rcfg, mesh))
        elif sharding is not None:
            rcfg = rcfg.replace(sharding=sharding)
        self.rcfg = rcfg
        self.params = params
        self.mesh = mesh
        self.page_size = page_size
        self.fused = fused
        self.alloc: Optional[PageAllocator] = None
        # compile-event counters: the pre-jit body runs once per XLA
        # trace, so this dict counts compilations of every callable the
        # backend (and the draft, which shares the dict) builds
        self.compile_counts = obs.compile_counts if obs is not None \
            else {}
        self._span = obs.span if obs is not None \
            else obs_profile.span_factory(False)
        self._step_fn = jax.jit(
            obs_profile.count_traces(
                f"{type(self).__name__}.serve_step",
                steps_mod.make_paged_serve_fn(rcfg, mesh,
                                              self._decode_fn(),
                                              fused=fused),
                self.compile_counts),
            donate_argnums=(1,))
        self._verify_fn = None          # built lazily (spec decode only)

    # -- device half --------------------------------------------------------

    def _decode_fn(self):
        raise NotImplementedError

    def init_state(self, n_pages: int):
        """Fresh device page pools only (no allocator, replicated) —
        probes and tests use this for scratch state. The engine-owned
        pools go through :meth:`init`, which also mesh-shards them."""
        raise NotImplementedError

    def shard_state(self, state):
        """Place a page-pool state tree on the mesh (pages over 'data',
        head/inner dims over 'model' — ``paged_state_specs``); identity
        without a mesh. The paged step fns re-constrain their outputs to
        the same logical axes, so the pools stay sharded across calls."""
        if self.mesh is None:
            return state
        return jax.device_put(
            state, pshard.paged_state_specs(state, self.rcfg, self.mesh))

    def pool_pages(self, n_pages: int) -> int:
        """Round a pool size up so the physical-page axis divides its
        mesh sharding axis. An indivisible size would make the
        divisibility check silently drop the 'pages' mapping and
        replicate the pools — forfeiting the per-device pool-memory
        scaling that is the point of sharding over serving DP. Identity
        without a mesh (or with 'pages' unmapped); the extra pages are
        ordinary allocatable capacity."""
        if self.mesh is None:
            return n_pages
        ax = resolve_axis("pages", self.rcfg.sharding, self.mesh)
        if ax is None:
            return n_pages
        size = _axis_size(self.mesh, ax)
        return -(-n_pages // size) * size

    def init(self, max_batch: int, n_pages: int):
        """Set up the host allocator and return the (mesh-sharded)
        device state. ``n_pages`` includes scratch page 0."""
        del max_batch                      # geometry is pool-global
        self.alloc = PageAllocator(n_pages)
        return self.shard_state(self.init_state(n_pages))

    def _table_view(self, slots: SlotBatch):
        """The page-table columns this call actually needs. Unfused
        backends keep the full width (the dense gather re-materializes
        every column anyway); fused backends slice to the power-of-two
        bucket covering ``max(lengths + n_new)`` — masked-out key slots
        contribute exactly-zero softmax mass and unwritten snapshot
        pages are outside every slot's write window, so truncating dead
        columns leaves all outputs bitwise-unchanged while the kernels
        (and the ref gather on CPU) only touch live pages. Bucketing
        keeps the jitted step at <= log2(P)+1 shape variants."""
        table = slots.page_table
        if not self.fused:
            return table
        P = table.shape[1]
        need = int(np.max(slots.lengths + slots.n_new, initial=1))
        p_eff = 1 << (max(-(-need // self.page_size), 1) - 1).bit_length()
        return table[:, :min(P, p_eff)]

    def _apply(self, state, slots: SlotBatch, tokens,
               label: str = "serve.step"):
        with self._span(label):
            nxt, state = self._step_fn(
                self.params, state, np.asarray(tokens, np.int32),
                slots.lengths, slots.n_new, self._table_view(slots),
                slots.temps, slots.top_ks, slots.top_ps, slots.seeds,
                slots.counters)
        return state, nxt

    def prefill(self, state, slots: SlotBatch, tokens):
        """Chunked prefill: tokens (B, S) with per-slot occupancy in
        ``slots.n_new``; returns (state, first sampled token (B, 1))."""
        return self._apply(state, slots, tokens, "serve.prefill")

    def step(self, state, slots: SlotBatch, tokens):
        """Steady-state decode: tokens (B, 1); returns (state, next
        (B, 1)). Same compiled fn as prefill at S == 1."""
        return self._apply(state, slots, tokens, "serve.decode")

    # -- device half: speculative decoding ----------------------------------

    def _verify_fns(self):
        """(verify forward, deferred commit or None) for this family —
        the two halves :func:`repro.launch.steps.make_paged_verify_fn`
        fuses into the jitted verify call."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support speculative decoding")

    def verify(self, state, slots: SlotBatch, tokens, draft_probs):
        """Multi-token speculative verification: tokens (B, k+1) =
        [pending, d_1..d_k] per slot with ``slots.n_new`` real entries
        (0 = idle), draft_probs (B, k, V) the drafts' proposal
        distributions. ONE jitted occupancy-masked call scores every
        position with the full model, accepts the longest valid prefix
        (greedy: exact match — bitwise plain decode; sampled: rejection
        sampling with leftover redraws), and commits state for exactly
        the accepted prefix — rejected suffixes are rolled back (KV:
        stale entries beyond ``lengths`` stay masked; snapshot pools:
        the deferred commit never writes them). Returns (state,
        accepted (B,), next_token (B,)); the host advances each slot by
        ``accepted + 1`` emitted tokens."""
        if self._verify_fn is None:
            vf, cf = self._verify_fns()
            self._verify_fn = jax.jit(
                obs_profile.count_traces(
                    f"{type(self).__name__}.verify_step",
                    steps_mod.make_paged_verify_fn(self.rcfg, self.mesh,
                                                   vf, cf),
                    self.compile_counts),
                donate_argnums=(1,))
        with self._span("serve.verify"):
            acc, nxt, state = self._verify_fn(
                self.params, state, tokens, slots.lengths, slots.n_new,
                self._table_view(slots), slots.temps, slots.top_ks,
                slots.top_ps, slots.seeds, slots.counters, draft_probs)
        return state, acc, nxt

    def coarse_draft(self, cf: int):
        """(draft_params, draft_rcfg, n_coarse) — the paper's coarse
        propagator over this backend's weights (every cf-th layer, ODE
        step rescaled); see ``transformer.coarse_draft_params``."""
        return transformer.coarse_draft_params(self.params, self.rcfg, cf)

    def init_draft_state(self, draft_rcfg: RunConfig, n_layers: int,
                         n_pages: int):
        """Fresh page pools for a coarse-depth twin of this backend's
        state (the draft's private, allocator-free pool)."""
        raise NotImplementedError

    # -- host half: page ops ------------------------------------------------
    # No-ops (empty views, identity) would be valid for a non-paged
    # backend; these delegate to the refcounted allocator. Refcount
    # lifecycle: alloc_view -> 1 per page, share -> +1, release -> -1
    # (the LAST release returns the page to the pool; releasing at 0
    # raises — exact double-free detection). Invariant the scheduler
    # upholds: any page inside a slot's write range
    # [lengths, lengths + n_new) is private (refcount 1) when the jitted
    # call launches — fork() first if other readers remain.

    def alloc_view(self, n: int):
        """n private pages (refcount 1 each) or None when the pool can't
        serve them right now (the caller waits for running requests to
        free pages, or evicts prefix-trie leaves)."""
        return self.alloc.alloc(n)

    def share(self, pages):
        """Map already-written pages read-only into another view
        (refcount +1 each; pages must be live — sharing a freed page
        raises)."""
        self.alloc.share(pages)

    def release(self, pages):
        """Drop one reference per page; the last reference frees the
        page back to the pool."""
        self.alloc.free(pages)

    def fork(self, state, page: int):
        """Copy-on-write detach: returns (state, private page id) — the
        same id if ``page`` had one reader, a device-copied fresh page
        otherwise, or (state, None) when the pool is empty."""
        dst = self.alloc.fork(page)
        if dst is None or dst == page:
            return state, dst
        return copy_state_page(state, page, dst), dst

    def fork_partial(self, state, page: int, n_valid: int):
        """Token-granular copy-on-write: copy ``page`` into a fresh
        private page whose first ``n_valid`` tokens the caller reuses
        (``1 <= n_valid < page_size`` — a full page is :meth:`fork`'s
        business). The whole page is copied; entries beyond ``n_valid``
        are stale but invisible — positional KV rows are overwritten
        before any position attends to them, and rows past a slot's
        length mask out of the causal window. ``page`` keeps all its
        references (the caller holds one across this call so eviction
        cannot recycle the source mid-copy). Only valid on
        positional-page backends: a state *snapshot* page holds the
        post-page-boundary state, which has no token-granular prefix to
        reuse — snapshot backends fall back to whole-page matches
        (``snapshot_state``, docs/cache-backends.md). Returns
        (state, fresh page id) or (state, None) when the pool is
        empty."""
        if not 1 <= n_valid < self.page_size:
            raise ValueError(
                f"fork_partial n_valid={n_valid} outside [1, "
                f"{self.page_size}): a 0-token copy is pointless and a "
                f"full-page copy is fork()'s job")
        if self.snapshot_state:
            raise ValueError(
                "fork_partial on a snapshot-state backend: snapshot "
                "pages are only valid at page boundaries "
                "(docs/cache-backends.md)")
        dst = self.alloc.fork_partial(page)
        if dst is None:
            return state, None
        return copy_state_page(state, page, dst), dst

    # -- preemption: spill / restore ----------------------------------------
    # The device half of scheduler preemption (docs/scheduling.md): a
    # victim slot's live pages are gathered to host memory, its refcounts
    # released, and the contents scattered back into freshly allocated
    # pages when the request resumes — the same per-page gather/scatter
    # PrefixCache.save/load run for trie persistence, so sharded pools
    # spill and restore unchanged (page ids are global, jax moves the
    # bytes).

    def spill(self, state, pages):
        """Read the given physical pages out of every pool leaf (page
        axis 1 by convention) into host memory. Returns the per-leaf
        page contents in ``jax.tree`` order — the ``restore``
        payload."""
        idx = jnp.asarray(np.asarray(pages, np.int32))
        return [np.asarray(leaf[:, idx]) for leaf in jax.tree.leaves(state)]

    def restore(self, state, pages, leaves):
        """Scatter previously spilled page contents into ``pages``
        (freshly allocated ids, same order/count as the ``spill`` call)
        and re-place the pools on the mesh. Returns the new state; the
        restored pages are bit-identical to the spilled ones, so a
        resumed greedy request decodes exactly what it would have
        undisturbed."""
        idx = jnp.asarray(np.asarray(pages, np.int32))
        flat, treedef = jax.tree.flatten(state)
        flat = [leaf.at[:, idx].set(jnp.asarray(d, leaf.dtype))
                for leaf, d in zip(flat, leaves, strict=True)]
        return self.shard_state(jax.tree.unflatten(treedef, flat))

    def page_nbytes(self, state) -> int:
        """Host bytes one physical page occupies across every pool leaf
        — the restore-cost side of the scheduler's recompute-vs-restore
        preemption model."""
        return sum(leaf.dtype.itemsize * leaf.size // leaf.shape[1]
                   for leaf in jax.tree.leaves(state))


class PagedKVBackend(CacheBackend):
    """Attention decoders (attn_mlp / attn_moe): block/paged KV cache."""

    snapshot_state = False

    def _decode_fn(self):
        return functools.partial(transformer.paged_decode_step,
                                 fused=self.fused)

    def init_state(self, n_pages: int):
        return transformer.init_paged_cache(self.rcfg, n_pages,
                                            self.page_size)

    def _verify_fns(self):
        # rollback = truncate lengths: stale KV beyond len is masked
        return (functools.partial(transformer.paged_verify_step,
                                  fused=self.fused),
                None)

    def init_draft_state(self, draft_rcfg: RunConfig, n_layers: int,
                         n_pages: int):
        return attn_mod.init_paged_kv_cache(draft_rcfg.model, n_layers,
                                            n_pages, self.page_size)


class SSMStateBackend(CacheBackend):
    """Mamba1/mamba2 models: recurrent state as snapshot pages."""

    snapshot_state = True

    def _decode_fn(self):
        return functools.partial(transformer.ssm_paged_decode_step,
                                 page_size=self.page_size,
                                 fused=self.fused)

    def init_state(self, n_pages: int):
        return transformer.init_paged_ssm_cache(self.rcfg, n_pages)

    def _verify_fns(self):
        # rollback = snapshot-page restore: the verify forward defers all
        # pool writes, the fused commit publishes the accepted prefix only
        return (functools.partial(transformer.ssm_paged_verify_step,
                                  page_size=self.page_size,
                                  fused=self.fused),
                functools.partial(transformer.ssm_paged_commit_step,
                                  page_size=self.page_size))

    def init_draft_state(self, draft_rcfg: RunConfig, n_layers: int,
                         n_pages: int):
        return ssm_mod.init_paged_ssm_pool(draft_rcfg.model, n_layers,
                                           n_pages,
                                           draft_rcfg.model.ssm.version)


class HybridBackend(CacheBackend):
    """Hybrid (zamba2): mamba2 snapshot pools + shared-attention KV pools
    composed per block kind, one page table for both."""

    snapshot_state = True

    def _decode_fn(self):
        return functools.partial(transformer.hybrid_paged_decode_step,
                                 page_size=self.page_size,
                                 fused=self.fused)

    def init_state(self, n_pages: int):
        return transformer.init_paged_hybrid_cache(self.rcfg, n_pages,
                                                   self.page_size)

    def _verify_fns(self):
        return (functools.partial(transformer.hybrid_paged_verify_step,
                                  page_size=self.page_size,
                                  fused=self.fused),
                functools.partial(transformer.hybrid_paged_commit_step,
                                  page_size=self.page_size))

    def init_draft_state(self, draft_rcfg: RunConfig, n_layers: int,
                         n_pages: int):
        # draft_rcfg carries the coarse n_layers / attn cadence
        return transformer.init_paged_hybrid_cache(draft_rcfg, n_pages,
                                                   self.page_size)


def make_backend(rcfg: RunConfig, params, mesh=None,
                 page_size: int = 16, sharding=None,
                 fused: bool = True, obs=None) -> CacheBackend:
    """The only family dispatch in the serve stack: everything downstream
    (scheduler, engine) speaks the CacheBackend protocol. ``mesh`` /
    ``sharding`` make the backend SPMD (see :class:`CacheBackend`);
    ``fused`` selects the fused paged-decode kernels (bitwise-identical
    at temperature 0 — see :class:`CacheBackend`); ``obs`` threads the
    engine's observability bundle into the backend's compile counters
    and profiler spans."""
    cfg = rcfg.model
    kind = block_kind(cfg)
    if cfg.family == "decoder" and kind in ("attn_mlp", "attn_moe"):
        return PagedKVBackend(rcfg, params, mesh, page_size, sharding,
                              fused, obs)
    if cfg.family == "ssm" and kind in ("mamba1", "mamba2"):
        return SSMStateBackend(rcfg, params, mesh, page_size, sharding,
                               fused, obs)
    if cfg.family == "hybrid":
        return HybridBackend(rcfg, params, mesh, page_size, sharding,
                             fused, obs)
    raise NotImplementedError(
        f"no CacheBackend for family={cfg.family!r} (kind={kind!r}): "
        "encoder models have no autoregressive decode, and encdec needs "
        "per-request encoder state — use transformer.decode_step directly")
