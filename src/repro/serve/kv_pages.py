"""Host-side bookkeeping for the block/paged KV cache.

The device tensors live in :func:`repro.models.attention.init_paged_kv_cache`
(a pool of fixed-size pages shared by every sequence, stacked over layers).
This module owns the free-list allocator and the capacity math: the
scheduler allocates ``pages_needed(prompt + max_new)`` physical pages when a
request is admitted and returns them the moment it finishes, so sequences
of different lengths share one pool with no per-slot max_len reservation.

Page ``SCRATCH_PAGE`` (id 0) is never allocated: the jitted step routes
writes from padded prompt positions and unoccupied slots there, which keeps
every shape static regardless of occupancy.
"""
from __future__ import annotations

from typing import List, Optional

SCRATCH_PAGE = 0


def pages_needed(n_tokens: int, page_size: int) -> int:
    """Physical pages required to hold n_tokens."""
    return -(-max(int(n_tokens), 0) // page_size)


class PageAllocator:
    """LIFO free-list over physical page ids 1..n_pages-1 (0 is scratch)."""

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("need at least one allocatable page + scratch")
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages - 1, 0, -1))

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop n pages, or None (caller waits for frees) if not available."""
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if not 0 < p < self.n_pages:
                raise ValueError(f"bad page id {p}")
            if p in self._free:
                raise ValueError(f"double free of page {p}")
            self._free.append(p)
