"""Host-side bookkeeping for paged decode state — any backend.

The device tensors live behind the ``repro.serve.cache.CacheBackend``
protocol (a pool of fixed-size pages shared by every sequence, stacked
over layers: KV pages for attention, recurrent-state snapshot pages for
SSM, both for hybrid). This module owns the allocator and the capacity
math: ``pages_needed(prompt + max_new)`` physical pages are allocated when
a request is admitted and returned the moment it finishes, so sequences of
different lengths share one pool with no per-slot max_len reservation.

Pages are **refcounted** so several page tables can map the same physical
page read-only (prefix sharing): ``alloc`` hands out private pages at
refcount 1, ``share`` adds readers, and ``free`` only returns a page to the
pool when its last reference dies. ``fork`` is the host half of
copy-on-write — before a slot writes into a page other readers can still
see, the scheduler forks it into a private copy (the device copy is
:func:`repro.serve.cache.copy_state_page`).

:class:`PrefixCache` is a trie over *full* prompt pages (page_size tokens
per level, keyed by the page's token tuple) mapping shared prompt prefixes
to the physical pages that already hold their state. A request whose
prompt walks k trie levels maps those k pages read-only and skips
re-prefilling ``k * page_size`` tokens (on snapshot backends it resumes
from the last matched page's state snapshot). Positional-page backends
additionally get **token-granular tails**: partial pages published at
request completion and near-miss full pages are matched by longest
common token prefix (:meth:`PrefixCache.match_tail`) and copied via
``fork_partial`` so a prompt sharing only the first 37 tokens of a
64-token page still reuses them. The trie pins each cached page with one
allocator reference of its own; under pool pressure the scheduler evicts
least-recently-matched leaves.

Page ``SCRATCH_PAGE`` (id 0) is never allocated: the jitted step routes
writes from padded prompt positions and unoccupied slots there, which keeps
every shape static regardless of occupancy.

Everything in this module is host-side and **placement-blind**: page ids
are global integers even when the device pools are mesh-sharded over the
'data' axis (``CacheBackend.shard_state`` / docs/sharding.md) —
refcounts, the trie, and npz persistence never see a mesh; the
``PrefixCache.save``/``load`` device gathers/scatters go through jax and
work on sharded pools unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

SCRATCH_PAGE = 0


def pages_needed(n_tokens: int, page_size: int) -> int:
    """Physical pages required to hold n_tokens."""
    return -(-max(int(n_tokens), 0) // page_size)


@dataclasses.dataclass
class SpilledPages:
    """Host-memory copy of a preempted slot's live pages.

    The device half is ``CacheBackend.spill`` / ``restore`` (the same
    page gather/scatter machinery :meth:`PrefixCache.save` / ``load``
    use for trie pages). ``length`` is the token count the pages cover
    — the slot's ``lengths`` entry at preemption time; ``leaves`` holds
    each pool leaf's page contents in ``jax.tree`` order, exactly what
    ``restore`` scatters back into freshly allocated pages. Host-side
    and placement-blind like everything else in this module."""
    length: int
    leaves: List["np.ndarray"]


class PageAllocator:
    """Refcounted pool over physical page ids 1..n_pages-1 (0 is scratch).

    The free pool is a LIFO stack (hot pages get reused first) backed by a
    set, so membership checks and frees are O(1) instead of the old
    O(n_free) list scan. Refcounts detect double frees exactly: freeing a
    page whose refcount is already 0 raises.
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("need at least one allocatable page + scratch")
        self.n_pages = n_pages
        self._free_stack: List[int] = list(range(n_pages - 1, 0, -1))
        self._free_set = set(self._free_stack)
        self._ref = [0] * n_pages

    @property
    def n_free(self) -> int:
        return len(self._free_stack)

    def refcount(self, page: int) -> int:
        return self._ref[page]

    def is_free(self, page: int) -> bool:
        return page in self._free_set

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop n private pages (refcount 1 each), or None (caller waits
        for frees / evicts cached prefixes) if not available."""
        if n > len(self._free_stack):
            return None
        pages = [self._free_stack.pop() for _ in range(n)]
        for p in pages:
            self._free_set.discard(p)
            self._ref[p] = 1
        return pages

    def share(self, pages: List[int]) -> None:
        """Add one reader to each page (it must be live)."""
        for p in pages:
            self._check_id(p)
            if self._ref[p] < 1:
                raise ValueError(f"share of unallocated page {p}")
        for p in pages:
            self._ref[p] += 1

    def free(self, pages: List[int]) -> None:
        """Drop one reference per page; a page returns to the pool when
        its last reference dies."""
        for p in pages:
            self._check_id(p)
            if self._ref[p] == 0:
                raise ValueError(f"double free of page {p}")
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free_stack.append(p)
                self._free_set.add(p)

    def fork(self, page: int) -> Optional[int]:
        """Copy-on-write split: detach one reference of ``page`` onto a
        private copy. Returns ``page`` itself when it is already private
        (no copy needed), a fresh page id (refcount 1 — the caller must
        copy the device KV) when other readers remain, or None when the
        pool is empty."""
        self._check_id(page)
        if self._ref[page] < 1:
            raise ValueError(f"fork of unallocated page {page}")
        if self._ref[page] == 1:
            return page
        got = self.alloc(1)
        if got is None:
            return None
        self._ref[page] -= 1
        return got[0]

    def fork_partial(self, page: int) -> Optional[int]:
        """Token-granular copy-on-write, host half: allocate a fresh
        private page (refcount 1) to receive a copy of ``page`` whose
        first ``n_valid`` tokens the caller will reuse. Unlike
        :meth:`fork`, the source keeps *all* its references — this is an
        independent new page seeded from ``page``'s content, not a
        detached reader (the caller holds its own reference on ``page``
        across the device copy, so eviction cannot free it mid-copy).
        Returns the fresh id, or None when the pool is empty."""
        self._check_id(page)
        if self._ref[page] < 1:
            raise ValueError(f"fork_partial of unallocated page {page}")
        got = self.alloc(1)
        return None if got is None else got[0]

    def _check_id(self, p: int) -> None:
        if not 0 < p < self.n_pages:
            raise ValueError(f"bad page id {p}")


class _PrefixNode:
    __slots__ = ("children", "tails", "page", "tick")

    def __init__(self, page: int, tick: int):
        self.children: Dict[Tuple[int, ...], "_PrefixNode"] = {}
        self.tails: Dict[Tuple[int, ...], "_PrefixNode"] = {}
        self.page = page
        self.tick = tick


def _common_prefix(key: Tuple[int, ...], rest, cap: int) -> int:
    """Leading tokens ``key`` and ``rest`` agree on, capped at ``cap``."""
    n = 0
    for a, b in zip(key, rest, strict=False):
        if n >= cap or a != b:
            break
        n += 1
    return n


class PrefixCache:
    """Trie over full prompt pages -> physical pages holding their KV.

    Level d of the trie is keyed by the token tuple of prompt page d, so a
    path from the root spells out a prompt prefix in whole-page units.
    Each node pins one physical page with a trie-owned allocator reference
    (taken at :meth:`insert`); the page therefore outlives the request
    that prefilled it and later requests map it read-only via
    :meth:`match` + ``PageAllocator.share``.

    **Token-granular tails** (positional-page backends only): each node
    additionally carries *tail* entries — partial pages keyed by a token
    tuple shorter than ``page_size``, published at request completion
    (the page then also holds tokens past the prompt tail; only the
    keyed prefix is ever reused). A later prompt that shares only the
    first n tokens of a page finds the longest such entry — or the
    longest common token prefix of a full-page key — via
    :meth:`match_tail` and copies the source page with
    ``CacheBackend.fork_partial`` instead of recomputing from the page
    boundary. Tail entries pin their page like ordinary nodes and take
    part in LRU eviction; they are **not** persisted by
    :meth:`save`/:meth:`load` (a restart republishes them as requests
    complete).
    """

    def __init__(self, alloc: PageAllocator, page_size: int, stats=None):
        self.alloc = alloc
        self.page_size = page_size
        self.children: Dict[Tuple[int, ...], _PrefixNode] = {}
        self.tails: Dict[Tuple[int, ...], _PrefixNode] = {}
        self._tick = 0
        # counters may be injected (the scheduler hands in a dict the
        # metrics registry registered under the 'trie' namespace) so the
        # registry owns them without the trie knowing about obs at all
        self.stats = {"hit_pages": 0, "miss_prompts": 0, "evicted": 0} \
            if stats is None else stats

    def _chunks(self, prompt: np.ndarray):
        ps = self.page_size
        for i in range(len(prompt) // ps):
            yield tuple(int(t) for t in prompt[i * ps:(i + 1) * ps])

    def match(self, prompt) -> List[int]:
        """Longest already-cached chain of the prompt's full pages.
        Returns their physical page ids in prompt order; the caller must
        ``share`` them before any allocator traffic (e.g. eviction) could
        otherwise free them."""
        self._tick += 1
        pages: List[int] = []
        children = self.children
        for key in self._chunks(prompt):
            node = children.get(key)
            if node is None:
                break
            node.tick = self._tick
            pages.append(node.page)
            children = node.children
        self.stats["hit_pages"] += len(pages)
        if not pages:
            self.stats["miss_prompts"] += 1
        return pages

    def insert(self, prompt, pages: List[int]) -> None:
        """Publish the prompt's first ``len(pages)`` full pages (already
        written physical ids, in prompt order). New nodes pin their page
        with one trie-owned reference; existing nodes keep their original
        page (concurrent prefills of the same prefix are harmless)."""
        self._tick += 1
        children = self.children
        for key, page in zip(self._chunks(prompt), pages, strict=False):
            node = children.get(key)
            if node is None:
                self.alloc.share([page])
                node = _PrefixNode(page, self._tick)
                children[key] = node
            node.tick = self._tick
            children = node.children

    def match_tail(self, prompt, matched_pages: int,
                   pending=frozenset()) -> Optional[Tuple[int, int]]:
        """Best token-granular partial match for the prompt's remainder
        after ``matched_pages`` full trie pages: the longest common token
        prefix among the stop node's tail entries *and* full-page child
        keys (a near-miss full page is just a tail with ``page_size``
        published tokens). Returns ``(src_page, n_tokens)`` with
        ``1 <= n_tokens < page_size`` and ``n_tokens`` strictly below the
        remainder length (at least one token is always recomputed for
        its logits), or None. Pages in ``pending`` — written by an
        in-flight wave, device content not landed — are skipped. The
        caller must ``share`` the source page before any allocator
        traffic (eviction) could free it, then release its reference
        after the device copy."""
        ps = self.page_size
        rest = [int(t) for t in prompt[matched_pages * ps:]]
        cap = min(len(rest) - 1, ps - 1)
        if cap < 1:
            return None
        children, tails = self.children, self.tails
        for i, key in enumerate(self._chunks(prompt)):
            if i >= matched_pages:
                break
            node = children.get(key)
            if node is None:          # caller matched deeper than us?
                return None
            children, tails = node.children, node.tails
        best: Optional[Tuple[int, _PrefixNode]] = None
        for entries in (tails, children):
            for key, node in entries.items():
                if node.page in pending:
                    continue
                n = _common_prefix(key, rest, cap)
                if n >= 1 and (best is None or n > best[0]):
                    best = (n, node)
        if best is None:
            return None
        self._tick += 1
        best[1].tick = self._tick
        return best[1].page, best[0]

    def insert_tail(self, prompt, page: int) -> bool:
        """Publish the prompt's final partial page (its last
        ``len(prompt) % page_size`` tokens live in physical ``page``) as
        a tail entry under the node chain of its full pages. No-op —
        returns False — when the prompt is page-aligned, an ancestor is
        not cached, or an existing entry already covers the same tokens
        (a longer entry subsumes a shorter one: common-prefix matching
        serves both). A strictly-shorter entry that this one extends is
        replaced. Takes one trie-owned reference on ``page``."""
        ps = self.page_size
        n = len(prompt) % ps
        if n == 0:
            return False
        children, tails = self.children, self.tails
        for key in self._chunks(prompt):
            node = children.get(key)
            if node is None:
                return False
            children, tails = node.children, node.tails
        key = tuple(int(t) for t in prompt[len(prompt) - n:])
        self._tick += 1
        for other in list(tails):
            if len(other) >= len(key) and other[:len(key)] == key:
                tails[other].tick = self._tick     # subsumed: just touch
                return False
            if len(other) < len(key) and key[:len(other)] == other:
                old = tails.pop(other)             # we extend it: replace
                self.alloc.free([old.page])
        self.alloc.share([page])
        tails[key] = _PrefixNode(page, self._tick)
        return True

    def _walk(self):
        """Yields (parent_dict, key, node) over the whole trie — full-page
        nodes and tail entries alike (tail nodes have no children)."""
        stack = [(self.children, k) for k in list(self.children)]
        stack += [(self.tails, k) for k in list(self.tails)]
        while stack:
            children, key = stack.pop()
            node = children[key]
            yield children, key, node
            stack.extend((node.children, k) for k in list(node.children))
            stack.extend((node.tails, k) for k in list(node.tails))

    @property
    def n_cached_pages(self) -> int:
        return sum(1 for _ in self._walk())

    def evict(self, n_needed: int) -> int:
        """Drop least-recently-matched leaves (full-page nodes with no
        children and no tails, or tail entries) whose page only the trie
        still references, until ``n_needed`` pages have returned to the
        pool or nothing more can be freed. Returns pages freed."""
        freed = 0
        while freed < n_needed:
            leaves = [(node.tick, key, children)
                      for children, key, node in self._walk()
                      if not node.children and not node.tails
                      and self.alloc.refcount(node.page) == 1]
            if not leaves:
                break
            leaves.sort(key=lambda t: t[0])
            for _, key, children in leaves:
                if freed >= n_needed:
                    break
                node = children.pop(key)
                self.alloc.free([node.page])
                freed += 1
                self.stats["evicted"] += 1
        return freed

    def clear(self) -> None:
        """Release every cached page (trie references only — pages still
        mapped by live requests stay allocated until those finish)."""
        for _, _, node in list(self._walk()):
            self.alloc.free([node.page])
        self.children = {}
        self.tails = {}

    # -- persistence --------------------------------------------------------
    # The trie + the device contents of its pinned pages round-trip
    # through one npz file, so a restarted engine starts warm: cached
    # prompt prefixes skip their prefill again without recomputation.
    # State leaves are saved in jax.tree order (page axis 1 by the
    # CacheBackend convention) — load requires the same model config.

    def save(self, path: str, state) -> int:
        """Write the trie structure + pinned page contents to ``path``.
        ``state`` is the backend's device state whose pages the trie
        pins. Tail entries (token-granular partial pages) ride along in
        parallel ``tail_*`` arrays, keys padded to page_size with -1.
        Returns the number of pages saved (full + tail)."""
        import jax
        import numpy as np

        recs: List[Tuple[int, Tuple[int, ...], int]] = []
        tail_recs: List[Tuple[int, Tuple[int, ...], int]] = []

        def walk(children, tails, parent):
            for key, node in tails.items():
                tail_recs.append((parent, key, node.page))
            for key, node in children.items():
                recs.append((parent, key, node.page))
                walk(node.children, node.tails, len(recs) - 1)

        walk(self.children, self.tails, -1)
        ps = self.page_size
        pages = np.asarray([r[2] for r in recs], np.int32)
        tail_pages = np.asarray([r[2] for r in tail_recs], np.int32)
        tail_keys = np.full((len(tail_recs), ps), -1, np.int32)
        for i, (_, key, _) in enumerate(tail_recs):
            tail_keys[i, :len(key)] = key
        data = {
            "page_size": np.int32(ps),
            "parents": np.asarray([r[0] for r in recs], np.int32),
            "keys": np.asarray([r[1] for r in recs],
                               np.int32).reshape(len(recs), ps),
            "pages": pages,
            "tail_parents": np.asarray([r[0] for r in tail_recs],
                                       np.int32),
            "tail_keys": tail_keys,
            "tail_lens": np.asarray([len(r[1]) for r in tail_recs],
                                    np.int32),
            "tail_pages": tail_pages,
        }
        all_pages = np.concatenate([pages, tail_pages])
        for i, leaf in enumerate(jax.tree.leaves(state)):
            data[f"leaf_{i}"] = np.asarray(leaf[:, all_pages])
        np.savez(path, **data)
        return len(recs) + len(tail_recs)

    def load(self, path: str, state):
        """Restore a saved cache into this (empty) trie: allocates fresh
        pages, scatters the saved contents into ``state``, and rebuilds
        the trie nodes pinning them. Nodes that no longer fit the pool —
        or whose parent was dropped — are skipped with their subtrees.
        Returns (new_state, n_pages_restored)."""
        import jax
        import jax.numpy as jnp
        import numpy as np

        d = np.load(path)
        if int(d["page_size"]) != self.page_size:
            raise ValueError(
                f"prefix cache was saved with page_size="
                f"{int(d['page_size'])}, engine uses {self.page_size}")
        parents = d["parents"]
        n = len(parents)
        new_ids = np.full((n,), -1, np.int32)
        nodes: Dict[int, _PrefixNode] = {}
        kept: List[int] = []
        for i in range(n):
            parent = int(parents[i])
            if parent >= 0 and parent not in nodes:
                continue                       # subtree of a dropped node
            children = self.children if parent < 0 \
                else nodes[parent].children
            key = tuple(int(t) for t in d["keys"][i])
            if key in children:                # already cached post-restart
                nodes[i] = children[key]
                continue
            got = self.alloc.alloc(1)
            if got is None:
                continue                       # pool full: drop subtree
            new_ids[i] = got[0]
            self._tick += 1
            node = _PrefixNode(got[0], self._tick)
            children[key] = node
            nodes[i] = node
            kept.append(i)
        # tail entries (absent in files saved before token-granular
        # sharing): attach to a surviving parent unless an equal-or-
        # longer entry already covers the same tokens
        m = len(d["tail_parents"]) if "tail_parents" in d.files else 0
        tail_new = np.full((m,), -1, np.int32)
        tail_kept: List[int] = []
        for i in range(m):
            parent = int(d["tail_parents"][i])
            if parent >= 0 and parent not in nodes:
                continue                       # parent node was dropped
            owner = self.tails if parent < 0 else nodes[parent].tails
            klen = int(d["tail_lens"][i])
            key = tuple(int(t) for t in d["tail_keys"][i][:klen])
            if any(len(o) >= klen and o[:klen] == key for o in owner):
                continue                       # already cached/subsumed
            got = self.alloc.alloc(1)
            if got is None:
                continue                       # pool full: drop entry
            tail_new[i] = got[0]
            self._tick += 1
            owner[key] = _PrefixNode(got[0], self._tick)
            tail_kept.append(i)
        if kept or tail_kept:
            src = kept + [n + i for i in tail_kept]
            dst = jnp.asarray(np.concatenate(
                [new_ids[kept], tail_new[tail_kept]]).astype(np.int32))
            leaves, treedef = jax.tree.flatten(state)
            leaves = [
                leaf.at[:, dst].set(
                    jnp.asarray(d[f"leaf_{j}"][:, src], leaf.dtype))
                for j, leaf in enumerate(leaves)]
            state = jax.tree.unflatten(treedef, leaves)
        return state, len(kept) + len(tail_kept)
