"""Coarse-propagator speculative decoding — the paper's multilevel
hierarchy as a decode accelerator.

The MGRIT coarse grid approximates the fine network with every ``cf``-th
layer and the ODE step rescaled by ``cf`` (Günther et al.; Lauga et al.).
That is exactly the shape of a *free* draft model: zero extra parameters,
zero training, same tokenizer/embedding — so the serve engine can draft
``k`` tokens with the coarse propagator and verify them with ONE
occupancy-masked full-model call per wave.

Wave protocol (2 jitted calls + 1 host sync, any batch composition):

1. **draft wave** (:func:`repro.launch.steps.make_draft_wave_fn`): the
   coarse model ingests the canonical tokens it has not yet cached plus
   the pending token (committing true draft state), then runs k-1
   in-call autoregressive steps proposing ``d_1..d_k`` with their
   proposal distributions ``q_i``. On snapshot backends the partial
   state page is saved post-ingest and restored in-call, so speculative
   writes never corrupt committed draft state.
2. **verify** (:meth:`repro.serve.cache.CacheBackend.verify`): the fine
   model scores ``[pending, d_1..d_k]`` in one call, accepts the longest
   valid prefix (greedy: exact argmax match — emitted tokens are bitwise
   identical to plain decode; sampled: leftover-distribution rejection
   sampling keyed off the canonical ``fold_in(seed, n_emitted)`` streams
   — the emitted distribution is exactly the target), emits
   ``accepted + 1`` tokens, and commits fine state for exactly the
   accepted prefix (KV: host-side length truncation; snapshot pools:
   deferred in-call commit).

The draft's decode state is deliberately simple: a private per-slot
linear page region (no allocator, no prefix trie, no COW) sized
``max_batch * pages_per_slot`` pages of the COARSE stack — about
``1/cf`` of one fine pool. Draft quality only moves the acceptance rate;
correctness is carried entirely by verification — the coarse grid is a
good draft when the weights sit in the near-identity *trained regime*
the paper's coarsening assumes (§2); on raw random init acceptance is
tie-breaking luck. The benchmark's damped init reproducing that regime
lives in ``benchmarks.bench_spec``: ``trained_regime(params, factor)``
with the per-family ``TRAINED_REGIME_DAMP`` factors — not in this
module, which never touches weight values.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.launch import steps as steps_mod
from repro.obs import profile as obs_profile
from repro.serve.cache import CacheBackend


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs: ``cf`` is the layer-coarsening factor
    of the draft (the paper's c_f), ``k`` the number of tokens drafted
    per verify wave."""
    cf: int = 4
    k: int = 4

    def __post_init__(self):
        if self.cf < 1:
            raise ValueError("spec cf must be >= 1")
        if self.k < 1:
            raise ValueError("spec k must be >= 1")


class CoarseDraft:
    """Self-speculative draft model + its private decode state.

    Built from a fine :class:`~repro.serve.cache.CacheBackend`: the draft
    params are the backend's weights restricted to every ``cf``-th layer
    (``transformer.coarse_draft_params``), the decode fn is the same
    family step the backend uses, and the state is a coarse-depth page
    pool with a static per-slot page table. ``lengths[b]`` tracks the
    draft's committed canonical tokens for slot b — always <= the fine
    scheduler's lengths, and re-synced by each wave's catch-up ingest.
    """

    def __init__(self, backend: CacheBackend, spec: SpecConfig,
                 max_batch: int, pages_per_slot: int):
        self.spec = spec
        self.backend = backend
        self.max_batch = max_batch
        # the draft always serves on the fine backend's mesh (a separate
        # mesh could silently disagree with where shard_state puts the
        # draft pools)
        mesh = backend.mesh
        params_d, rcfg_d, n_coarse = backend.coarse_draft(spec.cf)
        self.params = params_d
        self.rcfg = rcfg_d
        self.n_coarse = n_coarse
        n_pages = backend.pool_pages(1 + max_batch * pages_per_slot)
        # the draft's pools ride the same mesh placement as the fine
        # pools (pages over serving DP, inner dims over TP); the slots'
        # linear page regions only use ids 1..max_batch*pages_per_slot,
        # any rounding surplus just sits unaddressed
        self.state = backend.shard_state(
            backend.init_draft_state(rcfg_d, n_coarse, n_pages))
        self.table = np.asarray(
            1 + np.arange(max_batch * pages_per_slot).reshape(
                max_batch, pages_per_slot), np.int32)
        self.lengths = np.zeros((max_batch,), np.int32)
        decode_fn = backend._decode_fn()
        # the draft's jitted callables register in the fine backend's
        # compile-counts dict, so engine.compiles_per_callable covers
        # the whole wave (draft prefill + draft wave + fine verify)
        self._prefill_fn = jax.jit(
            obs_profile.count_traces(
                "CoarseDraft.prefill",
                steps_mod.make_paged_serve_fn(rcfg_d, mesh, decode_fn),
                backend.compile_counts),
            donate_argnums=(1,))
        self._wave_fn = jax.jit(
            obs_profile.count_traces(
                "CoarseDraft.wave",
                steps_mod.make_draft_wave_fn(
                    rcfg_d, mesh, decode_fn, k=spec.k,
                    page_size=backend.page_size,
                    snapshot_state=backend.snapshot_state),
                backend.compile_counts),
            donate_argnums=(1,))
        self._greedy = (np.zeros((max_batch,), np.float32),
                        np.zeros((max_batch,), np.int32),
                        np.ones((max_batch,), np.float32),
                        np.zeros((max_batch,), np.int32),
                        np.zeros((max_batch,), np.int32))

    def reset_slot(self, slot: int) -> None:
        """Forget a reaped slot's committed draft length (its linear
        page region is reused in-place by the next admission)."""
        self.lengths[slot] = 0

    def prefill(self, tokens: np.ndarray, n_new: np.ndarray) -> None:
        """One jitted call writes every admitted slot's FULL prompt into
        the draft pools (the draft has no prefix trie, so it always
        prefills from position 0). The sampled output is discarded."""
        lengths = np.zeros((self.max_batch,), np.int32)
        temps, top_ks, top_ps, seeds, counters = self._greedy
        _, self.state = self._prefill_fn(
            self.params, self.state, np.asarray(tokens, np.int32), lengths,
            np.asarray(n_new, np.int32), self.table, temps, top_ks, top_ps,
            seeds, counters)
        self.lengths[:] = np.where(n_new > 0, n_new, self.lengths)

    def wave(self, ingest, n_in, n_draft, temps, top_ks, top_ps, seeds,
             counters):
        """Catch-up ingest + k drafted tokens in one jitted call. Returns
        (drafted (B, k), draft_probs (B, k, V)) as device arrays and
        advances the committed draft lengths by ``n_in``."""
        d, q, self.state = self._wave_fn(
            self.params, self.state, np.asarray(ingest, np.int32),
            self.lengths.copy(), np.asarray(n_in, np.int32), self.table,
            temps, top_ks, top_ps, seeds, np.asarray(counters, np.int32),
            np.asarray(n_draft, np.int32))
        self.lengths += np.asarray(n_in, np.int32)
        return d, q
