"""Batched serving engine: prefill + KV-cache decode.

Continuous-batching-lite: requests are grouped into a fixed batch, prefilled
teacher-forced (one forward), then decoded token-by-token with the jitted
serve step. Serving shards with Megatron TP (+ kv_seq sharding for long
contexts) — the paper's layer-parallelism targets training (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.launch import steps as steps_mod
from repro.models import transformer


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # (T,) int32
    max_new_tokens: int = 16
    output: Optional[np.ndarray] = None


class ServeEngine:
    def __init__(self, rcfg: RunConfig, params, mesh=None,
                 max_len: int = 0):
        self.rcfg = rcfg
        self.params = params
        self.mesh = mesh
        self.max_len = max_len or min(rcfg.model.max_seq_len, 4096)
        self._decode = jax.jit(steps_mod.make_serve_fn(rcfg, mesh))
        self._prefill_logits = jax.jit(
            lambda p, b: transformer.forward(p, b, rcfg, mode="serial")[0])

    def _prefill_into_cache(self, tokens: jnp.ndarray):
        """Feed the prompt through the decode step token-by-token to
        populate the cache (simple and exactly consistent with decode).
        Returns (cache, last_logits_argmax)."""
        B, T = tokens.shape
        cache = transformer.init_cache(self.rcfg, B, self.max_len)
        nxt = None
        for t in range(T):
            nxt, cache = self._decode(self.params, cache, tokens[:, t:t + 1])
        return cache, nxt

    def generate(self, requests: List[Request]) -> List[Request]:
        B = len(requests)
        T = max(len(r.prompt) for r in requests)
        toks = np.zeros((B, T), np.int32)
        for i, r in enumerate(requests):
            toks[i, T - len(r.prompt):] = r.prompt    # left-pad
        tokens = jnp.asarray(toks)
        cache, nxt = self._prefill_into_cache(tokens)
        max_new = max(r.max_new_tokens for r in requests)
        outs = [nxt]
        cur = nxt
        for _ in range(max_new - 1):
            cur, cache = self._decode(self.params, cache, cur)
            outs.append(cur)
        gen = np.concatenate([np.asarray(o) for o in outs], axis=1)
        for i, r in enumerate(requests):
            r.output = gen[i, : r.max_new_tokens]
        return requests

    def throughput_probe(self, batch: int, steps: int = 8) -> float:
        """tokens/sec of steady-state decode at the given batch."""
        cache = transformer.init_cache(self.rcfg, batch, self.max_len)
        tok = jnp.ones((batch, 1), jnp.int32)
        tok, cache = self._decode(self.params, cache, tok)  # compile
        jax.block_until_ready(tok)
        t0 = time.time()
        for _ in range(steps):
            tok, cache = self._decode(self.params, cache, tok)
        jax.block_until_ready(tok)
        return batch * steps / (time.time() - t0)
