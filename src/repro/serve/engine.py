"""Continuous-batching serving engine.

Every decode-capable family — attention decoders, SSM (mamba1/mamba2),
and hybrid — serves through the same paged path: **batched chunked
prefill** (all admitted prompts -> state pages in one jitted call), a
**refcounted page pool** (KV pages or recurrent-state snapshot pages,
sequences of different lengths share one pool, common prompt prefixes
share physical pages copy-on-write), **per-request sampling**
(temperature / top-k / top-p / seed vectorized inside the jitted step;
temperature 0 is the exact greedy path), and the **scheduler** (admit
from queue into in-flight decode slots, evict finished sequences
mid-decode, refill without recompiling — static batch shape, dynamic
occupancy mask). The engine and scheduler are family-blind: everything
state-shaped lives behind the :class:`repro.serve.cache.CacheBackend`
protocol. Serving shards with Megatron TP (+ kv_seq sharding for long
contexts) — the paper's layer-parallelism targets training (DESIGN.md §6).

:meth:`ServeEngine.submit` with ``stream=True`` returns an iterator
yielding ``(token_id, text_piece)`` as tokens are emitted, with
incremental detokenization; dropping it cancels the request and frees
its pages. ``spec=SpecConfig(cf, k)`` turns on coarse-propagator
speculative decoding (:mod:`repro.serve.spec`): the paper's multilevel
coarse grid drafts k tokens per wave from the same weights and the full
model verifies them in one call — greedy output is bitwise identical to
plain decode. ``prefix_cache_path`` restores a persisted prefix cache
(:meth:`save_prefix_cache` / ``PrefixCache.save``) so restarts begin
warm.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.launch import steps as steps_mod
from repro.models import transformer
from repro.obs import Observability
from repro.obs import profile as obs_profile
from repro.serve.cache import SlotBatch
from repro.serve.scheduler import Scheduler, bucket_len
from repro.serve.spec import SpecConfig


@dataclasses.dataclass
class Request:
    """One generation request. Generation stops early at ``eos_id`` and is
    capped so prompt + output never exceeds the engine's max_len — len(
    output) can be < max_new_tokens in both cases.

    Sampling (every backend): ``temperature`` 0 is the exact greedy argmax
    path; > 0 samples from the temperature-scaled distribution restricted
    by ``top_k`` (0 disables) then ``top_p`` (1 disables). ``seed`` names
    the request's private RNG stream — the same (prompt, sampling params,
    seed) yields the same tokens in any slot and any batch composition.

    SLO fields: ``priority`` orders admission (smaller = more urgent,
    nice-style; urgent requests may preempt strictly-less-urgent running
    ones under pool pressure) and ``ttft_target_s`` / ``tpot_target_s``
    declare latency targets used for deadline-slack ordering and goodput
    reporting (``slo_met``) — targets never cause a request to be dropped.
    A request the engine cannot serve fails ALONE: ``error`` is set and
    ``output`` is empty, while every other request keeps decoding
    (failure isolation — nothing in the serve path raises engine-wide
    for a per-request condition).
    """
    prompt: np.ndarray           # (T,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    priority: int = 0
    ttft_target_s: Optional[float] = None
    tpot_target_s: Optional[float] = None
    output: Optional[np.ndarray] = None
    ttft_s: Optional[float] = None      # None if never prefilled
    latency_s: Optional[float] = None
    tpot_s: Optional[float] = None      # mean s/token after the first
    error: Optional[str] = None         # set iff the request failed

    @property
    def slo_met(self) -> bool:
        """Whether the finished request met its declared targets (absent
        targets pass trivially; failed requests never count)."""
        if self.error is not None:
            return False
        if self.ttft_target_s is not None and (
                self.ttft_s is None or self.ttft_s > self.ttft_target_s):
            return False
        if self.tpot_target_s is not None and (
                self.tpot_s is not None and self.tpot_s > self.tpot_target_s):
            return False
        return True


def default_detokenize(ids) -> str:
    """Placeholder id->text mapping (this repro carries no tokenizer):
    renders every id as one printable piece. Swap in a real detokenizer
    via ``ServeEngine(..., detokenize=...)`` — any callable mapping the
    full id list to text works; streaming emits the text diff."""
    return "".join(f"⟨{int(i)}⟩" for i in ids)


class ServeEngine:
    """User-facing serving API over the :class:`~repro.serve.scheduler.
    Scheduler`: batch generation (:meth:`generate`), queued submission
    and streaming (:meth:`submit`), prefix-cache persistence, merged
    counters (:attr:`stats`), and the throughput/prefill probes the
    benchmarks use. One engine = one model + one page pool + one
    (optional) mesh."""

    def __init__(self, rcfg: RunConfig, params, mesh=None,
                 max_len: int = 0, max_batch: int = 8, page_size: int = 16,
                 n_pages: int = 0, share_prefix: bool = True, sharding=None,
                 detokenize: Optional[Callable] = None,
                 spec: Optional[SpecConfig] = None,
                 prefix_cache_path: Optional[str] = None,
                 fused: bool = True, preempt_policy: str = "auto",
                 partial_prefix: bool = True,
                 prefill_chunk_tokens: int = 0,
                 observability: bool = True,
                 trace_capacity: int = 65536):
        """Args:
            rcfg / params: model config and weights.
            mesh: optional ('data', 'model') ``jax.sharding.Mesh`` —
                serving goes SPMD: weights tensor-parallel over 'model',
                page pools sharded over 'data', one jitted call per wave
                either way (see docs/sharding.md). ``sharding``
                optionally overrides the default
                :func:`repro.configs.registry.serve_sharding` rules.
            max_len / max_batch / page_size / n_pages / share_prefix:
                forwarded to the :class:`~repro.serve.scheduler.Scheduler`
                (``n_pages`` sizes the page pool; 0 = every slot can hold
                a max_len sequence — smaller pools exercise overload
                handling: rejection, skip-ahead, preemption).
            detokenize: ids -> text callable for streaming (defaults to
                rendering each id as ``⟨id⟩``).
            spec: SpecConfig enabling speculative decoding.
            prefix_cache_path: restore a persisted prefix cache npz.
            fused: fused paged-decode kernels (default; bitwise-identical
                greedy output) vs the gathered dense-view decode path —
                the benchmarks build one engine of each for the
                ``decode_*_fused`` speedup rows.
            preempt_policy: 'auto' (recompute-vs-restore cost model),
                'spill' / 'recompute' (force one side), or 'off' (never
                preempt) — see docs/scheduling.md.
            partial_prefix: token-granular prefix sharing on
                positional-page backends (trie tail entries +
                ``CacheBackend.fork_partial``; snapshot backends keep
                whole-page matching either way — docs/cache-backends.md).
                False restores exact whole-page-only matching.
            prefill_chunk_tokens: > 0 interleaves chunked prefill with
                decode — at most this many prompt tokens ingest per
                scheduler wave, between decode waves, so a long prompt's
                admission never stalls in-flight decode by more than one
                chunk (docs/scheduling.md). 0 (default) keeps serial
                whole-prompt admission. Token streams are bitwise
                identical either way (tests/test_serve_equivalence.py).
            observability: build the engine's :class:`repro.obs.
                Observability` bundle (metrics registry + lifecycle
                trace + compile counters; docs/observability.md). False
                collapses every emission site to a no-op — the
                ``serve/obs_overhead`` bench row holds the enabled cost
                to ≤3% of decode throughput.
            trace_capacity: lifecycle-trace ring size in events (oldest
                events drop first, counted); 0 disables tracing while
                keeping metrics.
        """
        self.rcfg = rcfg
        self.params = params
        self.mesh = mesh
        self.max_len = max_len or min(rcfg.model.max_seq_len, 4096)
        self.detokenize = detokenize or default_detokenize
        self.obs = Observability(enabled=observability,
                                 trace_capacity=trace_capacity)
        self.scheduler = Scheduler(
            rcfg, params, max_batch=max_batch, page_size=page_size,
            max_len=self.max_len, n_pages=n_pages, mesh=mesh,
            sharding=sharding, share_prefix=share_prefix, spec=spec,
            fused=fused, preempt_policy=preempt_policy,
            partial_prefix=partial_prefix,
            prefill_chunk_tokens=prefill_chunk_tokens, obs=self.obs)
        self.backend = self.scheduler.backend
        # dense-cache decode fn: the serial-forward oracle and the
        # apples-to-apples comparison probe (throughput_probe(paged=False));
        # built from the backend's rcfg so both paths share one set of
        # sharding rules under a mesh
        self._decode = jax.jit(obs_profile.count_traces(
            "ServeEngine.dense_decode",
            steps_mod.make_serve_fn(self.backend.rcfg, mesh),
            self.backend.compile_counts))
        if prefix_cache_path and os.path.exists(prefix_cache_path):
            self.load_prefix_cache(prefix_cache_path)

    # -- prefix-cache persistence -------------------------------------------

    def save_prefix_cache(self, path: str) -> int:
        """Persist the prefix trie + the device contents of its pinned
        pages to ``path`` (npz). Returns the number of pages saved."""
        sched = self.scheduler
        if sched.prefix is None:
            raise ValueError("engine was built with share_prefix=False")
        return sched.prefix.save(path, sched.state)

    def load_prefix_cache(self, path: str) -> int:
        """Restore a saved prefix cache into this engine's (empty) trie
        and page pool — a warm restart: prompts whose prefixes were
        cached before the restart skip their prefill again. Returns the
        number of pages restored (pages that no longer fit the pool are
        dropped with their subtrees)."""
        sched = self.scheduler
        if sched.prefix is None:
            raise ValueError("engine was built with share_prefix=False")
        sched.state, n = sched.prefix.load(path, sched.state)
        return n

    # -- reporting ----------------------------------------------------------

    @property
    def stats(self) -> Dict[str, float]:
        """One merged counter dict: scheduler counters (prefill/decode/
        spec-decode: draft_calls, verify_calls, tokens_drafted/accepted)
        + prefix-trie counters (hit/miss/evictions) + the mesh shape the
        engine decodes on (``mesh_dp``/``mesh_tp``/``mesh_devices``, all
        1 single-device) + ``compiles_per_callable`` (mean XLA traces
        per jitted serve callable — the recompile-leak canary). A
        backwards-compatible view over the metrics registry: every
        legacy key keeps its exact name and meaning
        (docs/observability.md)."""
        s = dict(self.scheduler.stats)
        prefix = self.scheduler.prefix
        s["trie_hit_pages"] = prefix.stats["hit_pages"] if prefix else 0
        s["trie_miss_prompts"] = prefix.stats["miss_prompts"] if prefix \
            else 0
        s["trie_evictions"] = prefix.stats["evicted"] if prefix else 0
        s["accept_rate"] = self.scheduler.accept_rate()
        shape = dict(self.mesh.shape) if self.mesh is not None else {}
        s["mesh_dp"] = int(shape.get("data", 1))
        s["mesh_tp"] = int(shape.get("model", 1))
        s["mesh_devices"] = int(self.mesh.devices.size) \
            if self.mesh is not None else 1
        s["compiles_per_callable"] = obs_profile.compiles_per_callable(
            self.backend.compile_counts)
        return s

    def metrics_snapshot(self) -> Dict[str, object]:
        """JSON-able snapshot of the metrics registry: every counter,
        gauge (sampled now), and histogram (count/sum/p50/p95/p99).
        Empty when the engine was built with ``observability=False``."""
        return self.obs.metrics.snapshot()

    def metrics_prometheus(self) -> str:
        """Prometheus text exposition of the same registry."""
        return self.obs.metrics.to_prometheus()

    def save_trace(self, path: str) -> int:
        """Write the request-lifecycle trace as Chrome/Perfetto
        trace-event JSON (load at https://ui.perfetto.dev). Returns the
        number of trace events written; raises when tracing is off."""
        if self.obs.trace is None:
            raise ValueError("engine has no trace buffer (built with "
                             "observability=False or trace_capacity=0)")
        return self.obs.trace.save(path)

    # -- generation ---------------------------------------------------------

    def _validate(self, requests: List[Request]) -> None:
        # validate the whole batch before any request is queued, so a bad
        # request can't leave earlier ones orphaned in the scheduler
        for r in requests:
            if r.max_new_tokens < 1:
                raise ValueError("max_new_tokens must be >= 1")
            if len(r.prompt) >= self.max_len:
                raise ValueError(f"prompt ({len(r.prompt)}) >= max_len "
                                 f"({self.max_len})")
            if r.temperature < 0.0 or r.top_k < 0 \
                    or not 0.0 < r.top_p <= 1.0:
                raise ValueError("bad sampling params: need temperature "
                                 ">= 0, top_k >= 0, top_p in (0, 1]")
            for target in (r.ttft_target_s, r.tpot_target_s):
                if target is not None and target <= 0:
                    raise ValueError("SLO targets must be > 0 (None "
                                     "disables)")

    def _submit_one(self, r: Request):
        return self.scheduler.submit_request(
            r.prompt, r.max_new_tokens, r.eos_id, temperature=r.temperature,
            top_k=r.top_k, top_p=r.top_p, seed=r.seed, priority=r.priority,
            ttft_target_s=r.ttft_target_s, tpot_target_s=r.tpot_target_s)

    @staticmethod
    def _finalize(r: Request, fin) -> Request:
        r.output = np.asarray(fin.out, np.int32)
        r.ttft_s = fin.ttft
        r.latency_s = fin.latency
        r.tpot_s = fin.tpot
        r.error = fin.error
        return r

    def generate(self, requests: List[Request]) -> List[Request]:
        """Queue every request, drain the scheduler, and return the same
        Request objects with ``output`` / ``ttft_s`` / ``latency_s``
        filled in (order preserved). The whole batch is validated before
        anything is queued, so a bad request can't orphan earlier ones."""
        self._validate(requests)
        sched = self.scheduler
        rids = [self._submit_one(r).rid for r in requests]
        done = sched.run()
        return [self._finalize(r, done.pop(rid))
                for r, rid in zip(requests, rids, strict=True)]

    def submit(self, request: Request, *, stream: bool = False,
               detokenize: Optional[Callable] = None):
        """Queue one request. ``stream=False`` returns its rid (drain with
        ``engine.scheduler.run()``). ``stream=True`` returns a generator
        yielding ``(token_id, text_piece)`` as tokens are emitted — pulling
        it drives the scheduler, so queued requests decode lock-step with
        the streamed one; on exhaustion the Request's output/ttft/latency
        fields are filled in."""
        self._validate([request])
        sreq = self._submit_one(request)
        if not stream:
            return sreq.rid
        return self._stream(sreq, request, detokenize or self.detokenize)

    def _stream(self, req, request: Request, detokenize: Callable):
        """Incremental detokenization: each new token re-detokenizes the
        full emitted prefix and yields the text *diff*, so multi-byte /
        multi-token pieces surface as soon as they are complete.

        Dropping the iterator mid-generation (``close()`` / GeneratorExit
        / an exception in the consumer) cancels the request: its slot and
        pages go back to the pool immediately instead of leaking until
        someone else happens to drive the scheduler."""
        sched = self.scheduler
        emitted, text = 0, ""
        try:
            while True:
                while emitted < len(req.out):
                    tok = req.out[emitted]
                    emitted += 1
                    full = detokenize(req.out[:emitted])
                    piece = full[len(text):] if full.startswith(text) \
                        else full
                    text = full
                    yield int(tok), piece
                if req.done:
                    break
                sched.step()     # never raises for pool pressure: an
                # unservable request finishes with req.error set instead
        finally:
            if not req.done:
                sched.cancel(req)
            self._finalize(request, req)

    # -- probes -------------------------------------------------------------

    def throughput_probe(self, batch: int, steps: int = 8,
                         paged: bool = True,
                         table_pages: int = 0) -> float:
        """tokens/sec of steady-state decode at the given batch.
        ``paged=False`` measures the dense-cache decode step instead (the
        seed design) for apples-to-apples comparison. ``table_pages``
        widens each slot's page table to the given production width and
        starts decode at a quarter of that context depth, so
        fused-vs-gathered probes measure realistic mid-sequence decode
        rather than an empty-table best case."""
        if paged:
            return self._paged_probe(batch, steps, table_pages)
        cache = transformer.init_cache(self.rcfg, batch, self.max_len)
        tok = jnp.ones((batch, 1), jnp.int32)
        tok, cache = self._decode(self.params, cache, tok)  # compile
        jax.block_until_ready(tok)
        # perf_counter, matching the scheduler's clock: time.time() can
        # jump under NTP adjustments and mis-measure short probes
        t0 = time.perf_counter()
        for _ in range(steps):
            tok, cache = self._decode(self.params, cache, tok)
        jax.block_until_ready(tok)
        return batch * steps / (time.perf_counter() - t0)

    def _scratch_table(self, batch: int, n_tokens: int,
                       min_pages: int = 0) -> np.ndarray:
        """Page table giving every slot n_tokens of capacity (host-only;
        page 0 stays the scratch page)."""
        per = max(min_pages, 1, -(-n_tokens // self.scheduler.page_size))
        return np.asarray(
            1 + np.arange(batch * per).reshape(batch, per), np.int32)

    def _paged_probe(self, batch: int, steps: int,
                     table_pages: int = 0) -> float:
        """Steady-state paged decode at full occupancy on a probe-local
        scratch state (reuses the backend's compiled step; under a mesh
        the scratch pools are placed like the engine's own)."""
        ps = self.scheduler.page_size
        start = (table_pages * ps) // 4 if table_pages else 0
        table = self._scratch_table(batch, start + steps + 1, table_pages)
        state = self.backend.shard_state(self.backend.init_state(
            self.backend.pool_pages(1 + table.size)))
        slots = SlotBatch.greedy(
            batch, table, lengths=np.full((batch,), start, np.int32))
        tok = np.ones((batch, 1), np.int32)
        state, tok = self.backend.step(state, slots, tok)   # compile
        jax.block_until_ready(tok)
        t0 = time.perf_counter()
        for _ in range(steps):
            slots.lengths = slots.lengths + 1
            state, tok = self.backend.step(state, slots, tok)
        jax.block_until_ready(tok)
        return batch * steps / (time.perf_counter() - t0)

    def prefill_probe(self, prompt_len: int, batch: int = 1,
                      iters: int = 3) -> float:
        """tokens/sec of chunked prefill at the given prompt length: one
        jitted call writes the whole prompt on every backend."""
        rcfg = self.rcfg
        S = bucket_len(prompt_len)
        rng = np.random.default_rng(0)
        toks = rng.integers(0, rcfg.model.vocab_size, (batch, S),
                            dtype=np.int32)
        table = self._scratch_table(batch, S)
        slots = SlotBatch.greedy(
            batch, table, n_new=np.full((batch,), prompt_len, np.int32))

        def call():
            state = self.backend.shard_state(self.backend.init_state(
                self.backend.pool_pages(1 + table.size)))
            return self.backend.prefill(state, slots, toks)

        out = call()
        jax.block_until_ready(out)
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            out = call()
            jax.block_until_ready(out)
            ts.append(time.perf_counter() - t0)
        return batch * prompt_len / float(np.median(ts))
