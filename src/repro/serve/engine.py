"""Continuous-batching serving engine.

Decoder-family attention models take the paged path: **batched chunked
prefill** (all admitted prompts -> KV pages in one jitted call), a
**block/paged KV cache** (fixed-size refcounted pages, sequences of
different lengths share one pool, common prompt prefixes share physical
pages copy-on-write), **per-request sampling** (temperature / top-k /
top-p / seed vectorized inside the jitted step; temperature 0 is the exact
greedy path), and the **scheduler** (admit from queue into in-flight
decode slots, evict finished sequences mid-decode, refill without
recompiling — static batch shape, dynamic occupancy mask).

SSM / hybrid / encdec families fall back to the seed-style dense-cache
batch engine (their recurrent caches advance token-by-token), still sharing
the jitted greedy decode step. Serving shards with Megatron TP (+ kv_seq
sharding for long contexts) — the paper's layer-parallelism targets
training (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.launch import steps as steps_mod
from repro.models import transformer
from repro.serve.scheduler import Scheduler, bucket_len


@dataclasses.dataclass
class Request:
    """One generation request. Generation stops early at ``eos_id`` and is
    capped so prompt + output never exceeds the engine's max_len — len(
    output) can be < max_new_tokens in both cases (on every engine path).

    Sampling (paged engine only; the dense fallback is greedy):
    ``temperature`` 0 is the exact greedy argmax path; > 0 samples from
    the temperature-scaled distribution restricted by ``top_k`` (0
    disables) then ``top_p`` (1 disables). ``seed`` names the request's
    private RNG stream — the same (prompt, sampling params, seed) yields
    the same tokens in any slot and any batch composition.
    """
    prompt: np.ndarray           # (T,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    output: Optional[np.ndarray] = None
    ttft_s: Optional[float] = None      # time to first token
    latency_s: Optional[float] = None


class ServeEngine:
    def __init__(self, rcfg: RunConfig, params, mesh=None,
                 max_len: int = 0, max_batch: int = 8, page_size: int = 16,
                 share_prefix: bool = True):
        self.rcfg = rcfg
        self.params = params
        self.mesh = mesh
        self.max_len = max_len or min(rcfg.model.max_seq_len, 4096)
        self.paged = transformer.paged_decode_supported(rcfg.model)
        self._decode = jax.jit(steps_mod.make_serve_fn(rcfg, mesh))
        if self.paged:
            self.scheduler = Scheduler(
                rcfg, params, max_batch=max_batch, page_size=page_size,
                max_len=self.max_len, mesh=mesh, share_prefix=share_prefix)
        else:
            self.scheduler = None

    # -- generation ---------------------------------------------------------

    def generate(self, requests: List[Request]) -> List[Request]:
        # validate the whole batch before any request is queued, so a bad
        # request can't leave earlier ones orphaned in the scheduler
        for r in requests:
            if r.max_new_tokens < 1:       # same contract on both paths
                raise ValueError("max_new_tokens must be >= 1")
            if len(r.prompt) >= self.max_len:
                raise ValueError(f"prompt ({len(r.prompt)}) >= max_len "
                                 f"({self.max_len})")
            if r.temperature < 0.0 or r.top_k < 0 \
                    or not 0.0 < r.top_p <= 1.0:
                raise ValueError("bad sampling params: need temperature "
                                 ">= 0, top_k >= 0, top_p in (0, 1]")
            if r.temperature > 0.0 and not self.paged:
                raise ValueError(
                    "sampling (temperature > 0) is only supported on the "
                    "paged engine; the dense fallback decodes greedily")
        if self.paged:
            return self._generate_paged(requests)
        return self._generate_dense(requests)

    def _generate_paged(self, requests: List[Request]) -> List[Request]:
        sched = self.scheduler
        rids = [sched.submit(r.prompt, r.max_new_tokens, r.eos_id,
                             temperature=r.temperature, top_k=r.top_k,
                             top_p=r.top_p, seed=r.seed)
                for r in requests]
        done = sched.run()
        for r, rid in zip(requests, rids):
            fin = done.pop(rid)
            r.output = np.asarray(fin.out, np.int32)
            r.ttft_s = fin.ttft
            r.latency_s = fin.latency
        return requests

    def _generate_dense(self, requests: List[Request]) -> List[Request]:
        """Fixed-batch fallback: left-pad to one rectangle, prefill, then
        lock-step decode (the dense cache has one shared write index)."""
        B = len(requests)
        T = max(len(r.prompt) for r in requests)
        t0 = time.perf_counter()
        toks = np.zeros((B, T), np.int32)
        for i, r in enumerate(requests):
            toks[i, T - len(r.prompt):] = r.prompt    # left-pad
        tokens = jnp.asarray(toks)
        cache = transformer.init_cache(self.rcfg, B, self.max_len)
        cur, cache = self._prefill_into_cache(tokens, cache)
        jax.block_until_ready(cur)
        t_first = time.perf_counter()
        # same cap as Scheduler.submit: the shared write index means the
        # longest (left-padded) row bounds everyone
        max_new = min(max(r.max_new_tokens for r in requests),
                      self.max_len - T)
        outs = [cur]
        for _ in range(max_new - 1):
            cur, cache = self._decode(self.params, cache, cur)
            outs.append(cur)
        jax.block_until_ready(cur)
        t_done = time.perf_counter()
        gen = np.concatenate([np.asarray(o) for o in outs], axis=1)
        for i, r in enumerate(requests):
            out = gen[i, : r.max_new_tokens]
            if r.eos_id is not None:
                hits = np.nonzero(out == r.eos_id)[0]
                if hits.size:          # include the EOS token, then stop
                    out = out[: hits[0] + 1]
            r.output = out
            r.ttft_s = t_first - t0
            r.latency_s = t_done - t0
        return requests

    def _prefill_into_cache(self, tokens: jnp.ndarray, cache):
        """Chunked prefill for attention kinds: the whole prompt goes
        through ONE jitted decode call (O(1) calls, not O(T)). SSM caches
        advance token-by-token, so those families keep the loop."""
        from repro.models.blocks import block_kind
        kind = block_kind(self.rcfg.model)
        if kind in ("attn_mlp", "attn_moe") \
                and self.rcfg.model.family != "encdec":
            return self._decode(self.params, cache, tokens)
        nxt = None
        for t in range(tokens.shape[1]):
            nxt, cache = self._decode(self.params, cache, tokens[:, t:t + 1])
        return nxt, cache

    # -- probes -------------------------------------------------------------

    def throughput_probe(self, batch: int, steps: int = 8,
                         paged: Optional[bool] = None) -> float:
        """tokens/sec of steady-state decode at the given batch. ``paged``
        overrides the engine's default path (False -> dense cache even on a
        paged engine, for apples-to-apples comparison)."""
        use_paged = self.paged if paged is None else paged
        if use_paged and not self.paged:
            raise ValueError("engine is not paged (non-decoder/attention "
                             "family); cannot probe the paged path")
        if use_paged:
            return self._paged_probe(batch, steps)
        cache = transformer.init_cache(self.rcfg, batch, self.max_len)
        tok = jnp.ones((batch, 1), jnp.int32)
        tok, cache = self._decode(self.params, cache, tok)  # compile
        jax.block_until_ready(tok)
        t0 = time.time()
        for _ in range(steps):
            tok, cache = self._decode(self.params, cache, tok)
        jax.block_until_ready(tok)
        return batch * steps / (time.time() - t0)

    def _scratch_table(self, batch: int, n_tokens: int) -> np.ndarray:
        """Page table giving every slot n_tokens of capacity (host-only;
        page 0 stays the scratch page)."""
        per = max(1, -(-n_tokens // self.scheduler.page_size))
        return np.asarray(
            1 + np.arange(batch * per).reshape(batch, per), np.int32)

    def _scratch_pages(self, table: np.ndarray):
        """Fresh probe-local device pool sized for ``table``."""
        return transformer.init_paged_cache(
            self.rcfg, 1 + table.size, self.scheduler.page_size)

    def _greedy_sampling_args(self, batch: int):
        """Per-slot sampling vectors selecting the exact argmax path."""
        return (np.zeros((batch,), np.float32),       # temperature
                np.zeros((batch,), np.int32),         # top_k (disabled)
                np.ones((batch,), np.float32),        # top_p (disabled)
                np.zeros((batch,), np.int32),         # seeds
                np.zeros((batch,), np.int32))         # counters

    def _paged_probe(self, batch: int, steps: int) -> float:
        """Steady-state paged decode at full occupancy on a scratch pool.
        Reuses the scheduler's cached jitted step (no retrace per probe)."""
        table = self._scratch_table(batch, steps + 1)
        pages = self._scratch_pages(table)
        fn = self.scheduler._step
        samp = self._greedy_sampling_args(batch)
        tok = np.ones((batch, 1), np.int32)
        n_new = np.ones((batch,), np.int32)
        lengths = np.zeros((batch,), np.int32)
        tok, pages = fn(self.params, pages, tok, lengths, n_new, table,
                        *samp)
        jax.block_until_ready(tok)
        t0 = time.time()
        for _ in range(steps):
            lengths = lengths + 1
            tok, pages = fn(self.params, pages, tok, lengths, n_new, table,
                            *samp)
        jax.block_until_ready(tok)
        return batch * steps / (time.time() - t0)

    def prefill_probe(self, prompt_len: int, batch: int = 1,
                      iters: int = 3) -> float:
        """tokens/sec of prefill at the given prompt length: one chunked
        call on the paged engine, the sequential per-token loop on the
        dense fallback (SSM-family caches advance token-by-token)."""
        rcfg = self.rcfg
        S = bucket_len(prompt_len)
        rng = np.random.default_rng(0)
        toks = rng.integers(0, rcfg.model.vocab_size, (batch, S),
                            dtype=np.int32)
        if self.paged:
            table = self._scratch_table(batch, S)
            n_new = np.full((batch,), prompt_len, np.int32)
            lengths = np.zeros((batch,), np.int32)
            fn = self.scheduler._step
            samp = self._greedy_sampling_args(batch)

            def call():
                pages = self._scratch_pages(table)
                return fn(self.params, pages, toks, lengths, n_new, table,
                          *samp)
        else:
            def call():
                cache = transformer.init_cache(rcfg, batch, self.max_len)
                return self._prefill_into_cache(
                    jnp.asarray(toks[:, :prompt_len]), cache)
        out = call()
        jax.block_until_ready(out)
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            out = call()
            jax.block_until_ready(out)
            ts.append(time.perf_counter() - t0)
        return batch * prompt_len / float(np.median(ts))
