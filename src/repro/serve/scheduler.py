"""Continuous-batching scheduler over a :class:`~repro.serve.cache.CacheBackend`.

The scheduler is backend-agnostic: it never mentions model families. All
decode state (attention KV pages, SSM state-snapshot pages, hybrid
composition) lives behind the CacheBackend protocol — the scheduler only
plans page views, occupancy, and sampling parameters.

The decode step always runs with a static (max_batch, 1) shape; which slots
are alive is the ``n_new`` occupancy mask, so admitting or evicting a
request never recompiles. One scheduler iteration:

  1. admit — pop queued requests into free slots while the page pool has
     room, then **batched chunked prefill**: every request admitted this
     wave shares ONE jitted (max_batch, bucket) call that writes all their
     prompts into the pages and yields each one's first token (prompt
     remainder padded to a power-of-two bucket, so compile count is
     O(log max_len), not O(T) and not O(queue)).
  2. decode — one lock-step call over all occupied slots; with
     ``spec=SpecConfig(cf, k)`` this becomes a **speculative wave**
     (:mod:`repro.serve.spec`): the coarse-propagator draft proposes k
     tokens per slot and one full-model verify call accepts a per-slot
     prefix, so each slot advances by a variable ``accepted + 1`` tokens
     per iteration (greedy output stays bitwise-plain-decode).
  3. reap — finished sequences (max_new reached or EOS) release their
     pages and slot immediately; the next iteration refills them.

**Prefix sharing / copy-on-write**: full prompt pages are published in a
trie (``kv_pages.PrefixCache``); a later request whose prompt starts with a
cached prefix maps those physical pages read-only (refcount +1) and
prefills only the remainder. When the remainder would write into a shared
page (a page-aligned full-prompt hit still recomputes the final token for
its logits), the page is forked first — ``CacheBackend.fork`` picks a
private copy and duplicates the device page. On backends whose pages are
state *snapshots* (``backend.snapshot_state``: SSM, hybrid) a snapshot
cannot be rewound to recompute just the final token, and cannot be read in
the same call that writes it — those matches drop the offending pages and
recompute their tokens instead. Under pool pressure, least-recently-matched
trie leaves are evicted.

**Sampling** is per-request and lives inside the jitted step
(``launch.steps.sample_tokens``): temperature 0 slots take the exact
greedy argmax path, others draw from the temperature-scaled,
top-k/top-p-masked distribution with key fold_in(PRNGKey(seed), n_emitted)
— reproducible regardless of slot placement or batch composition.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional, Set, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.serve.cache import CacheBackend, SlotBatch, make_backend
from repro.serve.kv_pages import (SCRATCH_PAGE, PrefixCache, pages_needed)
from repro.serve.spec import CoarseDraft, SpecConfig


@dataclasses.dataclass
class ScheduledRequest:
    """Scheduler-internal view of one request: prompt + sampling params
    + the growing ``out`` token list (the streaming path watches it) +
    submit/first-token/done timestamps. Produced by
    :meth:`Scheduler.submit_request`; the engine converts finished ones
    back into :class:`repro.serve.engine.Request` results."""
    rid: int
    prompt: np.ndarray               # (T,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    temperature: float = 0.0         # 0 = greedy (exact argmax path)
    top_k: int = 0                   # 0 = disabled
    top_p: float = 1.0               # 1 = disabled
    seed: int = 0                    # per-request sampling stream
    out: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0             # first token produced (end of prefill)
    t_done: float = 0.0

    @property
    def done(self) -> bool:
        return self.t_done > 0.0

    @property
    def ttft(self) -> float:
        return self.t_first - self.t_submit

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit


def bucket_len(n: int, lo: int = 8) -> int:
    """Next power-of-two prompt bucket (bounds distinct prefill traces)."""
    b = lo
    while b < n:
        b *= 2
    return b


class Scheduler:
    """Continuous-batching slot scheduler (see module docstring): admits
    queued requests into ``max_batch`` decode slots, plans/maps pages
    host-side, and drives the backend's jitted calls — one batched
    prefill per admission wave, one decode (or draft+verify) call per
    iteration, reaping finished slots in between. Family- and
    mesh-blind: everything device-shaped lives behind ``self.backend``."""

    def __init__(self, rcfg: RunConfig, params, *, max_batch: int = 8,
                 page_size: int = 16, max_len: int = 0, n_pages: int = 0,
                 mesh=None, sharding=None, share_prefix: bool = True,
                 backend: Optional[CacheBackend] = None,
                 spec: Optional[SpecConfig] = None, fused: bool = True):
        """Args:
            rcfg / params: model config and weights (under a mesh the
                backend re-places the weights tensor-parallel).
            max_batch: in-flight decode slots (the static batch shape).
            page_size: tokens per state page.
            max_len: per-request prompt+output cap; defaults to
                min(model max_seq_len, 4096).
            n_pages: physical page-pool size incl. scratch page 0;
                defaults to every slot holding a max_len sequence.
            mesh / sharding: SPMD placement, forwarded to
                :func:`repro.serve.cache.make_backend` — the scheduler
                itself stays host-side and mesh-blind.
            share_prefix: publish full prompt pages in the prefix trie.
            backend: pre-built CacheBackend (tests); otherwise built via
                ``make_backend``.
            spec: SpecConfig to enable coarse-propagator speculative
                decoding.
            fused: forwarded to ``make_backend`` — fused paged-decode
                kernels (default) vs the gathered dense-view path.
        """
        self.rcfg, self.params = rcfg, params
        self.max_len = max_len or min(rcfg.model.max_seq_len, 4096)
        self.page_size = page_size
        self.max_batch = max_batch
        self.backend = backend if backend is not None else \
            make_backend(rcfg, params, mesh=mesh, page_size=page_size,
                         sharding=sharding, fused=fused)
        assert self.backend.page_size == page_size
        self.pages_per_slot = pages_needed(self.max_len, page_size)
        # default pool: every slot can hold a max_len sequence, + scratch;
        # under a mesh the size is rounded up so the page axis divides
        # the 'pages' sharding axis (pool_pages — else it silently
        # replicates)
        n_pages = self.backend.pool_pages(
            n_pages or 1 + max_batch * self.pages_per_slot)
        self.state = self.backend.init(max_batch, n_pages)
        self.alloc = self.backend.alloc
        self.prefix: Optional[PrefixCache] = \
            PrefixCache(self.alloc, page_size) if share_prefix else None
        self._pending: Set[int] = set()   # pages this admit wave will write
        self.spec: Optional[CoarseDraft] = None
        if spec is not None:
            # the draft derives its mesh from the backend, so a prebuilt
            # mesh backend keeps draft and fine placement consistent
            self.spec = CoarseDraft(self.backend, spec, max_batch,
                                    self.pages_per_slot)

        self.page_table = np.full((max_batch, self.pages_per_slot),
                                  SCRATCH_PAGE, np.int32)
        self.lengths = np.zeros((max_batch,), np.int32)
        self.slot_req: List[Optional[ScheduledRequest]] = [None] * max_batch
        self.slot_pages: List[List[int]] = [[] for _ in range(max_batch)]
        # per-slot sampling parameters, fed to the jitted step every call
        self.temps = np.zeros((max_batch,), np.float32)
        self.top_ks = np.zeros((max_batch,), np.int32)
        self.top_ps = np.ones((max_batch,), np.float32)
        self.seeds = np.zeros((max_batch,), np.int32)
        self.queue: Deque[ScheduledRequest] = collections.deque()
        self.finished: Dict[int, ScheduledRequest] = {}
        self._next_rid = 0
        self.stats = {"prefill_tokens": 0, "prefill_s": 0.0,
                      "prefill_calls": 0, "decode_tokens": 0,
                      "decode_s": 0.0, "decode_steps": 0,
                      "shared_tokens": 0, "pages_allocated": 0,
                      "pages_shared": 0, "draft_calls": 0,
                      "verify_calls": 0, "tokens_drafted": 0,
                      "tokens_accepted": 0}

    # -- submission ---------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               eos_id: Optional[int] = None, *, temperature: float = 0.0,
               top_k: int = 0, top_p: float = 1.0, seed: int = 0) -> int:
        """Queue a request; returns its rid. max_new_tokens is capped so
        prompt + output fits max_len (the engine-wide Request contract)."""
        return self.submit_request(
            prompt, max_new_tokens, eos_id, temperature=temperature,
            top_k=top_k, top_p=top_p, seed=seed).rid

    def submit_request(self, prompt: np.ndarray, max_new_tokens: int,
                       eos_id: Optional[int] = None, *,
                       temperature: float = 0.0, top_k: int = 0,
                       top_p: float = 1.0, seed: int = 0) \
            -> ScheduledRequest:
        """Like :meth:`submit` but returns the live ScheduledRequest (the
        streaming path watches its ``out`` list grow)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) >= self.max_len:
            raise ValueError(f"prompt ({len(prompt)}) >= max_len "
                             f"({self.max_len})")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (prefill always "
                             "yields the first token)")
        if temperature < 0.0:
            raise ValueError("temperature must be >= 0")
        if top_k < 0:
            raise ValueError("top_k must be >= 0 (0 disables)")
        if not 0.0 < top_p <= 1.0:
            raise ValueError("top_p must be in (0, 1]")
        max_new = min(int(max_new_tokens), self.max_len - len(prompt))
        req = ScheduledRequest(self._next_rid, prompt, max_new, eos_id,
                               temperature=float(temperature),
                               top_k=int(top_k), top_p=float(top_p),
                               seed=int(seed) & 0x7FFFFFFF,
                               t_submit=time.perf_counter())
        self._next_rid += 1
        self.queue.append(req)
        return req

    # -- scheduler iteration ------------------------------------------------

    @property
    def n_active(self) -> int:
        """Occupied decode slots (in-flight requests, excluding queue)."""
        return sum(r is not None for r in self.slot_req)

    def _match_prefix(self, req: ScheduledRequest) -> List[int]:
        """Longest usable trie match for this prompt, with backend-capability
        adjustments applied, shared (refcount +1) before any allocator
        traffic could free the pages."""
        ps = self.page_size
        T = len(req.prompt)
        shared = self.prefix.match(req.prompt)
        if self.backend.snapshot_state:
            # snapshot pages are read at scan start, before this wave's
            # writes land: anything written by this same admission wave
            # is unusable — truncate the chain at the first pending page
            for i, p in enumerate(shared):
                if p in self._pending:
                    shared = shared[:i]
                    break
            # a full-prompt hit must recompute the final token, but a
            # snapshot can't rewind mid-page: drop the last page and
            # recompute its page_size tokens (no fork needed)
            if shared and len(shared) * ps >= T:
                shared.pop()
        elif shared and len(shared) * ps >= T and shared[-1] in self._pending:
            # KV pages: only a tail fork reads pages mid-flight; pending
            # pages can't be forked (their KV lands on device mid-call)
            shared.pop()
        self.backend.share(shared)
        return shared

    def _plan_admit(self, req: ScheduledRequest) \
            -> Optional[Tuple[List[int], int]]:
        """Map pages for one request: the longest trie-cached prompt
        prefix is shared read-only, fresh pages cover the rest, and a COW
        fork detaches the last shared page when the recomputed tail must
        write into it. Returns (pages, shared_len) or None when the pool
        cannot serve the request right now."""
        ps = self.page_size
        T = len(req.prompt)
        total = pages_needed(T + req.max_new_tokens, ps)
        shared: List[int] = []
        if self.prefix is not None:
            shared = self._match_prefix(req)
        shared_len = len(shared) * ps
        fork_src = None
        if shared and shared_len >= T:
            # page-aligned full-prompt hit (positional pages only): the
            # final prompt token is recomputed for its logits, writing
            # into the last shared page -> COW fork
            shared_len = T - 1
            fork_src = shared[-1]
        n_fresh = total - len(shared)
        fresh = self.backend.alloc_view(n_fresh)
        if fresh is None and self.prefix is not None:
            self.prefix.evict(n_fresh - self.alloc.n_free)
            fresh = self.backend.alloc_view(n_fresh)
        if fresh is None:
            self.backend.release(shared)
            return None
        if fork_src is not None:
            self.state, dst = self.backend.fork(self.state, fork_src)
            if dst is None and self.prefix is not None:
                self.prefix.evict(1)             # same fallback as alloc
                self.state, dst = self.backend.fork(self.state, fork_src)
            if dst is None:                      # needs one more page
                self.backend.release(fresh + shared)
                return None
            if dst != fork_src:
                self.stats["pages_allocated"] += 1
            shared[-1] = dst
        self.stats["pages_allocated"] += n_fresh
        self.stats["pages_shared"] += len(shared) - (fork_src is not None)
        self.stats["shared_tokens"] += shared_len
        return shared + fresh, shared_len

    def _admit(self) -> int:
        """Fill free slots from the queue, then prefill every admitted
        request in ONE batched jitted call. Returns how many were admitted
        (a request may finish during its own prefill, so admitted > 0 with
        n_active == 0 afterwards is normal — the caller re-admits)."""
        plans = []
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            plan = self._plan_admit(self.queue[0])
            if plan is None:           # pool full: wait for running reqs
                break
            pages, shared_len = plan
            req = self.queue.popleft()
            self.slot_req[slot] = req
            self.slot_pages[slot] = pages
            self.page_table[slot, :] = SCRATCH_PAGE
            self.page_table[slot, :len(pages)] = pages
            self.lengths[slot] = shared_len
            self.temps[slot] = req.temperature
            self.top_ks[slot] = req.top_k
            self.top_ps[slot] = req.top_p
            self.seeds[slot] = req.seed
            if self.prefix is not None:
                n_full = len(req.prompt) // self.page_size
                self.prefix.insert(req.prompt, pages[:n_full])
                self._pending.update(pages[shared_len // self.page_size:
                                           n_full])
            plans.append((slot, req, shared_len))
        if plans:
            if self.spec is not None:
                self._draft_prefill(plans)
            self._batched_prefill(plans)
            self._pending.clear()
        return len(plans)

    def _draft_prefill(self, plans) -> None:
        """Mirror an admission wave into the coarse draft: ONE jitted
        coarse-model call writes every admitted slot's FULL prompt into
        the draft's private pages (the draft has no prefix trie, so its
        bucket is the whole prompt, not the unshared remainder)."""
        S = bucket_len(max(len(r.prompt) for _, r, _ in plans))
        toks = np.zeros((self.max_batch, S), np.int32)
        n_new = np.zeros((self.max_batch,), np.int32)
        for slot, req, _ in plans:
            toks[slot, :len(req.prompt)] = req.prompt
            n_new[slot] = len(req.prompt)
        self.spec.prefill(toks, n_new)
        self.stats["draft_calls"] += 1

    def _slot_batch(self, n_new, counters) -> SlotBatch:
        return SlotBatch(self.lengths.copy(), n_new, self.page_table,
                         self.temps, self.top_ks, self.top_ps, self.seeds,
                         counters)

    def _batched_prefill(self, plans) -> None:
        """One jitted (max_batch, bucket) call writes every admitted
        prompt's non-shared remainder into its pages and samples each
        first token. Slots mid-decode ride along masked out (n_new == 0),
        so the call count per wave is 1 regardless of queue depth."""
        S = bucket_len(max(len(r.prompt) - sl for _, r, sl in plans))
        toks = np.zeros((self.max_batch, S), np.int32)
        n_new = np.zeros((self.max_batch,), np.int32)
        counters = np.zeros((self.max_batch,), np.int32)
        for slot, req, sl in plans:
            n = len(req.prompt) - sl
            toks[slot, :n] = req.prompt[sl:]
            n_new[slot] = n
        t0 = time.perf_counter()
        self.state, nxt = self.backend.prefill(
            self.state, self._slot_batch(n_new, counters), toks)
        nxt = np.asarray(nxt)
        now = time.perf_counter()
        self.stats["prefill_tokens"] += int(n_new.sum())
        self.stats["prefill_s"] += now - t0
        self.stats["prefill_calls"] += 1
        for slot, req, _ in plans:
            self.lengths[slot] = len(req.prompt)
            req.t_first = now
            tok = int(nxt[slot, 0])
            req.out.append(tok)
            if self._is_done(req, tok):
                self._reap(slot)

    def _decode_once(self) -> None:
        toks = np.zeros((self.max_batch, 1), np.int32)
        n_new = np.zeros((self.max_batch,), np.int32)
        counters = np.zeros((self.max_batch,), np.int32)
        for slot, req in enumerate(self.slot_req):
            if req is not None:
                toks[slot, 0] = req.out[-1]
                n_new[slot] = 1
                counters[slot] = len(req.out)
                # COW invariant: the page this slot writes is private
                assert self.alloc.refcount(
                    int(self.page_table[slot,
                                        self.lengths[slot]
                                        // self.page_size])) == 1
        t0 = time.perf_counter()
        self.state, nxt = self.backend.step(
            self.state, self._slot_batch(n_new, counters), toks)
        nxt = np.asarray(nxt)
        dt = time.perf_counter() - t0
        n_act = int(n_new.sum())
        self.stats["decode_tokens"] += n_act
        self.stats["decode_s"] += dt
        self.stats["decode_steps"] += 1
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            self.lengths[slot] += 1       # last token now lives in the cache
            tok = int(nxt[slot, 0])
            req.out.append(tok)
            if self._is_done(req, tok):
                self._reap(slot)

    def _spec_wave(self) -> None:
        """One speculative decode wave: coarse-propagator draft of up to
        ``k`` tokens per slot + ONE full-model verify call; each slot
        advances by ``accepted + 1`` tokens (greedy slots emit bitwise
        what plain decode would). Two jitted calls and one host sync for
        up to k+1 tokens per slot."""
        sp = self.spec
        k = sp.spec.k
        B = self.max_batch
        n_draft = np.zeros((B,), np.int32)
        n_in = np.zeros((B,), np.int32)
        ingest = np.zeros((B, k + 1), np.int32)
        counters = np.zeros((B,), np.int32)
        for b, req in enumerate(self.slot_req):
            if req is None:
                continue
            # never draft past the request's budget: accepted+1 <= room
            n_draft[b] = min(k, req.max_new_tokens - len(req.out) - 1)
            # canonical tokens the draft has not cached yet + the pending
            # token (position L); the catch-up is <= last wave's accepted
            # count, so k+1 columns always suffice
            row = req.out[int(sp.lengths[b]) - len(req.prompt):]
            assert 1 <= len(row) <= k + 1
            ingest[b, :len(row)] = row
            n_in[b] = len(row)
            counters[b] = len(req.out)
        t0 = time.perf_counter()
        d, q = sp.wave(ingest, n_in, n_draft, self.temps, self.top_ks,
                       self.top_ps, self.seeds, counters)
        # verify window: [pending, d_1..d_k] per slot, assembled on device
        pending = jnp.take_along_axis(
            jnp.asarray(ingest), jnp.maximum(n_in - 1, 0)[:, None], axis=1)
        ver_toks = jnp.concatenate([pending, d], axis=1)
        slots = self._slot_batch(np.where(n_in > 0, n_draft + 1, 0),
                                 counters)
        self.state, acc, nxt = self.backend.verify(self.state, slots,
                                                   ver_toks, q)
        acc = np.asarray(acc)
        nxt = np.asarray(nxt)
        d_host = np.asarray(d)
        dt = time.perf_counter() - t0
        self.stats["draft_calls"] += 1
        self.stats["verify_calls"] += 1
        self.stats["tokens_drafted"] += int(n_draft.sum())
        self.stats["decode_s"] += dt
        self.stats["decode_steps"] += 1
        for b, req in enumerate(self.slot_req):
            if req is None:
                continue
            a = int(acc[b])
            self.stats["tokens_accepted"] += a
            self.lengths[b] += a + 1   # committed: pending + accepted
            for tok in [*d_host[b, :a], nxt[b]]:
                req.out.append(int(tok))
                self.stats["decode_tokens"] += 1
                if self._is_done(req, int(tok)):
                    self._reap(b)
                    break

    def _is_done(self, req: ScheduledRequest, tok: int) -> bool:
        return (len(req.out) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id))

    def _reap(self, slot: int) -> None:
        req = self.slot_req[slot]
        req.t_done = time.perf_counter()
        self.finished[req.rid] = req
        self.backend.release(self.slot_pages[slot])
        self.slot_pages[slot] = []
        self.slot_req[slot] = None
        self.page_table[slot, :] = SCRATCH_PAGE
        self.lengths[slot] = 0
        self.temps[slot] = 0.0
        self.top_ks[slot] = 0
        self.top_ps[slot] = 1.0
        self.seeds[slot] = 0
        if self.spec is not None:
            self.spec.reset_slot(slot)

    def cancel(self, req: ScheduledRequest) -> None:
        """Abort a queued or in-flight request: its slot and pages return
        to the pool immediately and nothing more is generated (streaming
        early termination). Finished/unknown requests are a no-op."""
        if req.done:
            return
        try:
            self.queue.remove(req)
            req.t_done = time.perf_counter()
            self.finished[req.rid] = req
            return
        except ValueError:
            pass
        for slot, r in enumerate(self.slot_req):
            if r is req:
                self._reap(slot)
                return

    def drop_prefix_cache(self) -> None:
        """Release every trie-pinned page (pages still mapped by live
        requests stay allocated until those finish). Used between
        benchmark phases and by tests verifying the pool drains."""
        if self.prefix is not None:
            self.prefix.clear()

    def step(self) -> bool:
        """One scheduler iteration (admit wave + one decode). Returns
        False when idle (nothing queued or running); raises when the head
        request can never be served by this pool."""
        if not self.queue and not self.n_active:
            return False
        admitted = self._admit()
        if self.n_active:
            if self.spec is not None:
                self._spec_wave()
            else:
                self._decode_once()
        elif self.queue and admitted == 0:
            # nothing running, nothing admitted: the head request can
            # never get pages (admitted > 0 with everything already
            # finished in prefill just loops back to admit more)
            raise RuntimeError(
                f"request {self.queue[0].rid} needs more pages than the "
                f"pool holds ({self.alloc.n_pages - 1})")
        return True

    def run(self) -> Dict[int, ScheduledRequest]:
        """Drain the queue; returns {rid: finished request}."""
        while self.step():
            pass
        return self.finished

    # -- reporting ----------------------------------------------------------

    def accept_rate(self) -> float:
        """Fraction of spec-drafted tokens the verifier accepted (0 when
        spec decode is off) — the single owner of this derivation."""
        return self.stats["tokens_accepted"] / max(
            self.stats["tokens_drafted"], 1)

    def throughput(self) -> Dict[str, float]:
        """Aggregate rates derived from the counters: prefill/decode
        tokens per second of call wall-time, call counts, prompt tokens
        reused via prefix sharing, and the spec-decode accept rate."""
        s = self.stats
        return {
            "prefill_tok_s": s["prefill_tokens"] / max(s["prefill_s"], 1e-9),
            "decode_tok_s": s["decode_tokens"] / max(s["decode_s"], 1e-9),
            "decode_steps": float(s["decode_steps"]),
            "prefill_calls": float(s["prefill_calls"]),
            "shared_tokens": float(s["shared_tokens"]),
            "accept_rate": self.accept_rate(),
        }
