"""Continuous-batching scheduler over the paged KV cache.

The decode step always runs with a static (max_batch, 1) shape; which slots
are alive is the ``n_new`` occupancy mask, so admitting or evicting a
request never recompiles. One scheduler iteration:

  1. admit — pop queued requests into free slots while the page pool has
     room: allocate pages for prompt+max_new tokens, then **chunked
     prefill** writes the whole prompt into the pages with one jitted call
     (prompt length padded to a power-of-two bucket, so compile count is
     O(log max_len), not O(T)); the prefill logits yield the first token.
  2. decode — one lock-step call over all occupied slots.
  3. reap — finished sequences (max_new reached or EOS) release their
     pages and slot immediately; the next iteration refills them.

Greedy sampling, matching the seed engine.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import RunConfig
from repro.launch import steps as steps_mod
from repro.models import transformer
from repro.serve.kv_pages import SCRATCH_PAGE, PageAllocator, pages_needed


@dataclasses.dataclass
class ScheduledRequest:
    rid: int
    prompt: np.ndarray               # (T,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0             # first token produced (end of prefill)
    t_done: float = 0.0

    @property
    def ttft(self) -> float:
        return self.t_first - self.t_submit

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit


def bucket_len(n: int, lo: int = 8) -> int:
    """Next power-of-two prompt bucket (bounds distinct prefill traces)."""
    b = lo
    while b < n:
        b *= 2
    return b


class Scheduler:
    def __init__(self, rcfg: RunConfig, params, *, max_batch: int = 8,
                 page_size: int = 16, max_len: int = 0, n_pages: int = 0,
                 mesh=None):
        if not transformer.paged_decode_supported(rcfg.model):
            raise NotImplementedError(
                f"paged serving needs decoder attention blocks, got "
                f"family={rcfg.model.family!r}")
        self.rcfg, self.params, self.mesh = rcfg, params, mesh
        self.max_len = max_len or min(rcfg.model.max_seq_len, 4096)
        self.page_size = page_size
        self.max_batch = max_batch
        self.pages_per_slot = pages_needed(self.max_len, page_size)
        # default pool: every slot can hold a max_len sequence, + scratch
        n_pages = n_pages or 1 + max_batch * self.pages_per_slot
        self.alloc = PageAllocator(n_pages)
        self.pages = transformer.init_paged_cache(rcfg, n_pages, page_size)
        self._step = jax.jit(steps_mod.make_serve_fn(rcfg, mesh, paged=True),
                             donate_argnums=(1,))

        self.page_table = np.full((max_batch, self.pages_per_slot),
                                  SCRATCH_PAGE, np.int32)
        self.lengths = np.zeros((max_batch,), np.int32)
        self.slot_req: List[Optional[ScheduledRequest]] = [None] * max_batch
        self.slot_pages: List[List[int]] = [[] for _ in range(max_batch)]
        self.queue: Deque[ScheduledRequest] = collections.deque()
        self.finished: Dict[int, ScheduledRequest] = {}
        self._next_rid = 0
        self.stats = {"prefill_tokens": 0, "prefill_s": 0.0,
                      "decode_tokens": 0, "decode_s": 0.0, "decode_steps": 0}

    # -- submission ---------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               eos_id: Optional[int] = None) -> int:
        """Queue a request; returns its rid. max_new_tokens is capped so
        prompt + output fits max_len (the engine-wide Request contract)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) >= self.max_len:
            raise ValueError(f"prompt ({len(prompt)}) >= max_len "
                             f"({self.max_len})")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (prefill always "
                             "yields the first token)")
        max_new = min(int(max_new_tokens), self.max_len - len(prompt))
        req = ScheduledRequest(self._next_rid, prompt, max_new, eos_id,
                               t_submit=time.perf_counter())
        self._next_rid += 1
        self.queue.append(req)
        return req.rid

    # -- scheduler iteration ------------------------------------------------

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    def _admit(self) -> int:
        """Fill free slots from the queue; returns how many were admitted
        (a request may finish during its own prefill, so admitted > 0 with
        n_active == 0 afterwards is normal — the caller re-admits)."""
        admitted = 0
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue[0]
            need = pages_needed(len(req.prompt) + req.max_new_tokens,
                                self.page_size)
            pages = self.alloc.alloc(need)
            if pages is None:          # pool full: wait for running reqs
                break
            admitted += 1
            self.queue.popleft()
            self.slot_req[slot] = req
            self.slot_pages[slot] = pages
            self.page_table[slot, :] = SCRATCH_PAGE
            self.page_table[slot, :len(pages)] = pages
            self.lengths[slot] = 0
            self._prefill(slot, req)
        return admitted

    def _prefill(self, slot: int, req: ScheduledRequest) -> None:
        """One (or few) jitted calls write the whole prompt into the pages
        and return the first generated token — no per-token host loop."""
        T = len(req.prompt)
        S = bucket_len(T)
        toks = np.zeros((1, S), np.int32)
        toks[0, :T] = req.prompt
        t0 = time.perf_counter()
        nxt, self.pages = self._step(
            self.params, self.pages, toks,
            np.zeros((1,), np.int32), np.array([T], np.int32),
            self.page_table[slot:slot + 1])
        tok = int(jax.block_until_ready(nxt)[0, 0])
        now = time.perf_counter()
        self.stats["prefill_tokens"] += T
        self.stats["prefill_s"] += now - t0
        self.lengths[slot] = T
        req.t_first = now
        req.out.append(tok)
        if self._is_done(req, tok):
            self._reap(slot)

    def _decode_once(self) -> None:
        toks = np.zeros((self.max_batch, 1), np.int32)
        n_new = np.zeros((self.max_batch,), np.int32)
        for slot, req in enumerate(self.slot_req):
            if req is not None:
                toks[slot, 0] = req.out[-1]
                n_new[slot] = 1
        t0 = time.perf_counter()
        nxt, self.pages = self._step(self.params, self.pages, toks,
                                     self.lengths.copy(), n_new,
                                     self.page_table)
        nxt = np.asarray(jax.block_until_ready(nxt))
        dt = time.perf_counter() - t0
        n_act = int(n_new.sum())
        self.stats["decode_tokens"] += n_act
        self.stats["decode_s"] += dt
        self.stats["decode_steps"] += 1
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            self.lengths[slot] += 1       # last token now lives in the cache
            tok = int(nxt[slot, 0])
            req.out.append(tok)
            if self._is_done(req, tok):
                self._reap(slot)

    def _is_done(self, req: ScheduledRequest, tok: int) -> bool:
        return (len(req.out) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id))

    def _reap(self, slot: int) -> None:
        req = self.slot_req[slot]
        req.t_done = time.perf_counter()
        self.finished[req.rid] = req
        self.alloc.free(self.slot_pages[slot])
        self.slot_pages[slot] = []
        self.slot_req[slot] = None
        self.page_table[slot, :] = SCRATCH_PAGE
        self.lengths[slot] = 0

    def step(self) -> None:
        self._admit()
        if self.n_active:
            self._decode_once()

    def run(self) -> Dict[int, ScheduledRequest]:
        """Drain the queue; returns {rid: finished request}."""
        while self.queue or self.n_active:
            admitted = self._admit()
            if self.n_active:
                self._decode_once()
            elif self.queue and admitted == 0:
                # nothing running, nothing admitted: the head request can
                # never get pages (admitted > 0 with everything already
                # finished in prefill just loops back to admit more)
                raise RuntimeError(
                    f"request {self.queue[0].rid} needs more pages than the "
                    f"pool holds ({self.alloc.n_pages - 1})")
        return self.finished

    # -- reporting ----------------------------------------------------------

    def throughput(self) -> Dict[str, float]:
        s = self.stats
        return {
            "prefill_tok_s": s["prefill_tokens"] / max(s["prefill_s"], 1e-9),
            "decode_tok_s": s["decode_tokens"] / max(s["decode_s"], 1e-9),
            "decode_steps": float(s["decode_steps"]),
        }
