"""Continuous-batching scheduler over a :class:`~repro.serve.cache.CacheBackend`.

The scheduler is backend-agnostic: it never mentions model families. All
decode state (attention KV pages, SSM state-snapshot pages, hybrid
composition) lives behind the CacheBackend protocol — the scheduler only
plans page views, occupancy, and sampling parameters.

The decode step always runs with a static (max_batch, 1) shape; which slots
are alive is the ``n_new`` occupancy mask, so admitting or evicting a
request never recompiles. One scheduler iteration:

  1. admit — order the queue by (priority, deadline slack, arrival), pop
     requests into free slots while the page pool has room — scanning a
     bounded distance past an unservable head so small requests are not
     blocked behind a big one (skip-ahead; aging promotes a starving
     head, see docs/scheduling.md) — then **batched chunked prefill**:
     every request admitted this wave shares ONE jitted
     (max_batch, bucket) call that writes all their prompts into the
     pages and yields each one's first token (prompt remainder padded to
     a power-of-two bucket clamped at max_len, so compile count is
     O(log max_len), not O(T) and not O(queue)).
  2. decode — one lock-step call over all occupied slots; with
     ``spec=SpecConfig(cf, k)`` this becomes a **speculative wave**
     (:mod:`repro.serve.spec`): the coarse-propagator draft proposes k
     tokens per slot and one full-model verify call accepts a per-slot
     prefix, so each slot advances by a variable ``accepted + 1`` tokens
     per iteration (greedy output stays bitwise-plain-decode).
  3. reap — finished sequences (max_new reached or EOS) release their
     pages and slot immediately; the next iteration refills them.

**Failure isolation**: no per-request condition is engine-fatal. A request
that can never fit the pool is rejected at :meth:`Scheduler.submit_request`
(``ScheduledRequest.error`` set, surfaced on the engine's ``Request``);
a runtime admission failure on an otherwise idle engine fails that one
request the same way. Every other queued and in-flight request keeps
serving either way — overload degrades service, it never crashes the
engine.

**Preemption**: when a more urgent request (smaller ``priority``) cannot
get a slot or pages, least-recently-matched trie leaves are evicted
first, then a strictly-less-urgent running request is preempted: its
live pages are spilled to host memory (``CacheBackend.spill``) or
dropped for recompute — whichever the recompute-vs-restore cost model
predicts is cheaper — its refcounts released, and the request re-enters
the queue to resume later (``CacheBackend.restore`` scatters spilled
pages back bit-identically, so a resumed greedy request emits exactly
the tokens it would have undisturbed).

**Prefix sharing / copy-on-write**: full prompt pages are published in a
trie (``kv_pages.PrefixCache``); a later request whose prompt starts with a
cached prefix maps those physical pages read-only (refcount +1) and
prefills only the remainder. When the remainder would write into a shared
page (a page-aligned full-prompt hit still recomputes the final token for
its logits), the page is forked first — ``CacheBackend.fork`` picks a
private copy and duplicates the device page. On backends whose pages are
state *snapshots* (``backend.snapshot_state``: SSM, hybrid) a snapshot
cannot be rewound to recompute just the final token, and cannot be read in
the same call that writes it — those matches drop the offending pages and
recompute their tokens instead. Under pool pressure, least-recently-matched
trie leaves are evicted.

**Token-granular partial sharing** (``partial_prefix``, KV backends
only): finished prompts also publish their partial tail page
(``PrefixCache.insert_tail``); a later prompt matching only the first n
tokens of such a page reuses them via ``CacheBackend.fork_partial`` — a
whole-page COW *copy* (the source keeps all its references) appended to
the new request's table with n tokens valid. Snapshot backends fall
back to whole-page matching (docs/cache-backends.md).

**Chunked prefill / decode interleaving** (``prefill_chunk_tokens`` >
0, Sarathi-style): an admission wave plans pages and fills slots but
ingests each prompt in budget-bounded chunks — one ingest call of at
most the budget per scheduler wave, *before* that wave's decode, with
mid-ingest slots skipped by decode/spec waves — so a long prompt never
stalls in-flight decode by more than one chunk. Intermediate chunks'
sampled tokens are discarded; the completing chunk emits the first
token with counter 0 from the last prompt token's logits, so streams
stay bitwise identical to serial admission (the differential harness in
``tests/serve_oracle.py`` pins this; docs/scheduling.md has the wave
ordering and starvation interaction).

**Sampling** is per-request and lives inside the jitted step
(``launch.steps.sample_tokens``): temperature 0 slots take the exact
greedy argmax path, others draw from the temperature-scaled,
top-k/top-p-masked distribution with key fold_in(PRNGKey(seed), n_emitted)
— reproducible regardless of slot placement or batch composition.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import time
from typing import Deque, Dict, List, Optional, Set, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.obs import Observability
from repro.obs import profile as obs_profile
from repro.serve.cache import CacheBackend, SlotBatch, make_backend
from repro.serve.kv_pages import (SCRATCH_PAGE, PrefixCache, SpilledPages,
                                  pages_needed)
from repro.serve.spec import CoarseDraft, SpecConfig

#: assumed host->device replay bandwidth (bytes/s) for the preemption
#: cost model's restore side when no better estimate exists — only the
#: *ratio* against the measured prefill rate matters, so a conservative
#: constant is fine (docs/scheduling.md).
HOST_RESTORE_BYTES_S = 4e9


class COWViolationError(RuntimeError):
    """A decode slot was about to write into a page other readers can
    still see — an internal copy-on-write invariant violation (a
    scheduler bug), not a per-request failure. Raised by the
    debug-gated check in ``Scheduler._decode_once`` (``REPRO_SERVE_DEBUG=0``
    disables it); unlike the bare ``assert`` it replaced, it survives
    ``python -O`` and names the slot/page/refcount."""


@dataclasses.dataclass
class ScheduledRequest:
    """Scheduler-internal view of one request: prompt + sampling params
    + SLO fields (priority, TTFT/TPOT targets) + the growing ``out``
    token list (the streaming path watches it) + submit/first-token/done
    timestamps. Produced by :meth:`Scheduler.submit_request`; the engine
    converts finished ones back into :class:`repro.serve.engine.Request`
    results. ``error`` is set — instead of anything raising — when the
    request is rejected or fails admission (failure isolation)."""
    rid: int
    prompt: np.ndarray               # (T,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    temperature: float = 0.0         # 0 = greedy (exact argmax path)
    top_k: int = 0                   # 0 = disabled
    top_p: float = 1.0               # 1 = disabled
    seed: int = 0                    # per-request sampling stream
    priority: int = 0                # smaller = more urgent (nice-style)
    ttft_target_s: Optional[float] = None   # SLO: time to first token
    tpot_target_s: Optional[float] = None   # SLO: seconds per output token
    out: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0             # first token produced (end of prefill)
    t_done: float = 0.0
    error: Optional[str] = None      # set iff the request failed
    skips: int = 0                   # admission waves this request waited
    preemptions: int = 0
    spill: Optional[SpilledPages] = None   # host copy of preempted state

    @property
    def done(self) -> bool:
        return self.t_done > 0.0

    @property
    def failed(self) -> bool:
        return self.error is not None

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token — None when the request never reached
        prefill (rejected, or cancelled while queued), instead of the
        negative ``0 - t_submit`` it used to report."""
        if self.t_first <= 0.0:
            return None
        return self.t_first - self.t_submit

    @property
    def latency(self) -> Optional[float]:
        """Submit-to-done wall time (None while still in flight)."""
        if self.t_done <= 0.0:
            return None
        return self.t_done - self.t_submit

    @property
    def tpot(self) -> Optional[float]:
        """Mean seconds per output token after the first; None before
        completion or when fewer than two tokens were emitted."""
        if self.t_done <= 0.0 or self.t_first <= 0.0 or len(self.out) < 2:
            return None
        return (self.t_done - self.t_first) / (len(self.out) - 1)

    @property
    def slo_met(self) -> bool:
        """Whether a finished request met its declared targets (absent
        targets pass trivially; failed requests never count)."""
        if self.error is not None:
            return False
        if self.ttft_target_s is not None and (
                self.ttft is None or self.ttft > self.ttft_target_s):
            return False
        if self.tpot_target_s is not None and (
                self.tpot is not None and self.tpot > self.tpot_target_s):
            return False
        return True

    @property
    def resume_seq(self) -> np.ndarray:
        """The token sequence whose state must be in the cache before
        the pending token is fed: the prompt for a fresh request; prompt
        + emitted tokens except the last (which decode feeds next) for a
        request resuming after preemption."""
        if not self.out:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.out[:-1], np.int32)])


def bucket_len(n: int, lo: int = 8, hi: int = 0) -> int:
    """Next power-of-two prompt bucket (bounds distinct prefill traces).
    ``hi`` > 0 clamps the bucket: a prompt just under the cap would
    otherwise round up PAST it (e.g. 191 tokens under max_len 192
    tracing a 256-wide prefill) — the clamped bucket adds at most one
    extra trace at exactly ``hi``."""
    b = lo
    while b < n:
        b *= 2
    return min(b, max(n, hi)) if hi else b


class Scheduler:
    """Continuous-batching slot scheduler (see module docstring): admits
    queued requests into ``max_batch`` decode slots in SLO order, plans/
    maps pages host-side, and drives the backend's jitted calls — one
    batched prefill per admission wave, one decode (or draft+verify)
    call per iteration, reaping finished slots in between. Family- and
    mesh-blind: everything device-shaped lives behind ``self.backend``."""

    def __init__(self, rcfg: RunConfig, params, *, max_batch: int = 8,
                 page_size: int = 16, max_len: int = 0, n_pages: int = 0,
                 mesh=None, sharding=None, share_prefix: bool = True,
                 partial_prefix: bool = True,
                 prefill_chunk_tokens: int = 0,
                 backend: Optional[CacheBackend] = None,
                 spec: Optional[SpecConfig] = None, fused: bool = True,
                 admit_lookahead: int = 8, starvation_limit: int = 16,
                 age_every: int = 4, preempt_policy: str = "auto",
                 debug_checks: Optional[bool] = None,
                 obs: Optional[Observability] = None):
        """Args:
            rcfg / params: model config and weights (under a mesh the
                backend re-places the weights tensor-parallel).
            max_batch: in-flight decode slots (the static batch shape).
            page_size: tokens per state page.
            max_len: per-request prompt+output cap; defaults to
                min(model max_seq_len, 4096).
            n_pages: physical page-pool size incl. scratch page 0;
                defaults to every slot holding a max_len sequence.
            mesh / sharding: SPMD placement, forwarded to
                :func:`repro.serve.cache.make_backend` — the scheduler
                itself stays host-side and mesh-blind.
            share_prefix: publish full prompt pages in the prefix trie.
            partial_prefix: token-granular prefix sharing (positional-
                page backends only): publish each finished request's
                partial prompt-tail page and match near-miss prefixes by
                longest common token prefix, reusing them via
                ``CacheBackend.fork_partial``. Snapshot backends
                (SSM/hybrid) ignore this and keep whole-page matching —
                a snapshot is only valid at a page boundary. False
                restores exact whole-page-only matching (the
                differential harness's control arm).
            prefill_chunk_tokens: > 0 interleaves chunked prefill with
                decode (Sarathi-style): an admission wave maps pages and
                fills slots but ingests each prompt in budget-bounded
                chunks — at most this many tokens per scheduler wave
                across all ingesting slots, between decode waves — so a
                long prompt never stalls in-flight decode by more than
                one chunk. Chunk buckets reuse ``bucket_len``'s shape
                universe (no new jit shapes); emitted token streams are
                bitwise identical to serial admission (0, the default:
                one whole-prompt batched prefill per admission wave,
                exactly the pre-chunking path).
            backend: pre-built CacheBackend (tests); otherwise built via
                ``make_backend``.
            spec: SpecConfig to enable coarse-propagator speculative
                decoding.
            fused: forwarded to ``make_backend`` — fused paged-decode
                kernels (default) vs the gathered dense-view path.
            admit_lookahead: how many unservable queue entries one admit
                wave may scan past (bounded skip-ahead).
            starvation_limit: admission waves an unservable head may be
                skipped before it blocks all skip-ahead (aging — the
                head then drains the pool and admits; no starvation).
            age_every: every this many skipped waves a queued request's
                *effective* priority (queue ordering only) improves by
                one level.
            preempt_policy: 'auto' (recompute-vs-restore cost model),
                'spill' / 'recompute' (force one side), or 'off'
                (never preempt).
            debug_checks: run the host-side copy-on-write invariant
                check each decode wave; defaults to on unless
                ``REPRO_SERVE_DEBUG=0`` (cheap — O(max_batch) refcount
                lookups — and survives ``python -O``).
            obs: :class:`repro.obs.Observability` bundle. The metrics
                registry owns ``self.stats`` (and the trie counters),
                the trace buffer receives every request-lifecycle event,
                and the backend's jitted callables register compile
                counters. Defaults to a fresh enabled bundle;
                ``Observability(enabled=False)`` turns every emission
                site into a no-op (docs/observability.md).
        """
        self.rcfg, self.params = rcfg, params
        self.max_len = max_len or min(rcfg.model.max_seq_len, 4096)
        self.page_size = page_size
        self.max_batch = max_batch
        if preempt_policy not in ("auto", "spill", "recompute", "off"):
            raise ValueError(f"bad preempt_policy {preempt_policy!r}")
        if prefill_chunk_tokens < 0:
            raise ValueError("prefill_chunk_tokens must be >= 0 "
                             "(0 disables chunked-prefill interleaving)")
        self.prefill_chunk_tokens = int(prefill_chunk_tokens)
        self.admit_lookahead = admit_lookahead
        self.starvation_limit = starvation_limit
        self.age_every = max(int(age_every), 1)
        self.preempt_policy = preempt_policy
        self._debug_checks = debug_checks if debug_checks is not None \
            else os.environ.get("REPRO_SERVE_DEBUG", "1") != "0"
        self.obs = obs if obs is not None else Observability()
        self.trace = self.obs.trace
        self.backend = backend if backend is not None else \
            make_backend(rcfg, params, mesh=mesh, page_size=page_size,
                         sharding=sharding, fused=fused, obs=self.obs)
        if self.backend.page_size != page_size:
            raise ValueError(
                f"backend page_size {self.backend.page_size} != scheduler "
                f"page_size {page_size}: page-table indices would not "
                "agree across the allocator and the backend pools")
        self.pages_per_slot = pages_needed(self.max_len, page_size)
        # default pool: every slot can hold a max_len sequence, + scratch;
        # under a mesh the size is rounded up so the page axis divides
        # the 'pages' sharding axis (pool_pages — else it silently
        # replicates)
        n_pages = self.backend.pool_pages(
            n_pages or 1 + max_batch * self.pages_per_slot)
        self.state = self.backend.init(max_batch, n_pages)
        self.alloc = self.backend.alloc
        self._page_nbytes = 0            # filled lazily (preempt cost model)
        self.prefix: Optional[PrefixCache] = \
            PrefixCache(self.alloc, page_size,
                        stats=self.obs.metrics.stats_dict(
                            "trie", {"hit_pages": 0, "miss_prompts": 0,
                                     "evicted": 0})) \
            if share_prefix else None
        # token-granular tails apply to positional pages only; snapshot
        # backends fall back to whole-page matching (docs/cache-backends.md)
        self.partial_prefix = bool(partial_prefix) and share_prefix \
            and not self.backend.snapshot_state
        self._pending: Set[int] = set()   # pages this admit wave will write
        self._ingest: Dict[int, np.ndarray] = {}   # slot -> target sequence
        self._wave_preempted: Set[int] = set()   # rids preempted this wave
        self.spec: Optional[CoarseDraft] = None
        if spec is not None:
            # the draft derives its mesh from the backend, so a prebuilt
            # mesh backend keeps draft and fine placement consistent
            self.spec = CoarseDraft(self.backend, spec, max_batch,
                                    self.pages_per_slot)

        self.page_table = np.full((max_batch, self.pages_per_slot),
                                  SCRATCH_PAGE, np.int32)
        self.lengths = np.zeros((max_batch,), np.int32)
        self.slot_req: List[Optional[ScheduledRequest]] = [None] * max_batch
        self.slot_pages: List[List[int]] = [[] for _ in range(max_batch)]
        # per-slot sampling parameters, fed to the jitted step every call
        self.temps = np.zeros((max_batch,), np.float32)
        self.top_ks = np.zeros((max_batch,), np.int32)
        self.top_ps = np.ones((max_batch,), np.float32)
        self.seeds = np.zeros((max_batch,), np.int32)
        self.queue: Deque[ScheduledRequest] = collections.deque()
        self.finished: Dict[int, ScheduledRequest] = {}
        self._next_rid = 0
        self._wave = 0                 # scheduler iteration (trace scoping)
        self._last_counters = None     # last (free_pages, queue_depth) sampled
        # the metrics registry owns this dict (single-owner contract,
        # docs/observability.md); it stays a plain dict the hot path
        # mutates in place, so existing `stats[k] += n` / reset-to-zero
        # code (and every external reader) is unchanged
        self.stats = self.obs.metrics.stats_dict(
            "scheduler",
            {"prefill_tokens": 0, "prefill_s": 0.0,
             "prefill_calls": 0, "decode_tokens": 0,
             "decode_s": 0.0, "decode_steps": 0,
             "shared_tokens": 0, "pages_allocated": 0,
             "pages_shared": 0, "draft_calls": 0,
             "verify_calls": 0, "tokens_drafted": 0,
             "tokens_accepted": 0, "requests_rejected": 0,
             "requests_failed": 0, "preemptions": 0,
             "pages_spilled": 0, "pages_restored": 0,
             "preempt_recomputes": 0, "prefix_partial_hits": 0,
             "prefix_partial_tokens_shared": 0, "prefill_chunks": 0})
        m = self.obs.metrics
        m.gauge("pool.free_pages", lambda: self.alloc.n_free)
        m.gauge("scheduler.queue_depth", lambda: len(self.queue))
        m.gauge("scheduler.n_active", lambda: self.n_active)
        m.gauge("scheduler.accept_rate", self.accept_rate)
        m.gauge("trie.hit_rate", self._trie_hit_rate)
        m.gauge("engine.compiles_per_callable",
                lambda: obs_profile.compiles_per_callable(
                    self.backend.compile_counts))

    # -- submission ---------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               eos_id: Optional[int] = None, *, temperature: float = 0.0,
               top_k: int = 0, top_p: float = 1.0, seed: int = 0,
               priority: int = 0, ttft_target_s: Optional[float] = None,
               tpot_target_s: Optional[float] = None) -> int:
        """Queue a request; returns its rid. max_new_tokens is capped so
        prompt + output fits max_len (the engine-wide Request contract)."""
        return self.submit_request(
            prompt, max_new_tokens, eos_id, temperature=temperature,
            top_k=top_k, top_p=top_p, seed=seed, priority=priority,
            ttft_target_s=ttft_target_s, tpot_target_s=tpot_target_s).rid

    def submit_request(self, prompt: np.ndarray, max_new_tokens: int,
                       eos_id: Optional[int] = None, *,
                       temperature: float = 0.0, top_k: int = 0,
                       top_p: float = 1.0, seed: int = 0, priority: int = 0,
                       ttft_target_s: Optional[float] = None,
                       tpot_target_s: Optional[float] = None) \
            -> ScheduledRequest:
        """Like :meth:`submit` but returns the live ScheduledRequest (the
        streaming path watches its ``out`` list grow).

        Malformed parameters raise ``ValueError`` (a caller contract
        bug). A well-formed request the pool can *never* hold is instead
        rejected — returned already finished with ``error`` set — so one
        oversized request can't take down anything else (failure
        isolation; the old engine-wide ``RuntimeError`` is gone)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) >= self.max_len:
            raise ValueError(f"prompt ({len(prompt)}) >= max_len "
                             f"({self.max_len})")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (prefill always "
                             "yields the first token)")
        if temperature < 0.0:
            raise ValueError("temperature must be >= 0")
        if top_k < 0:
            raise ValueError("top_k must be >= 0 (0 disables)")
        if not 0.0 < top_p <= 1.0:
            raise ValueError("top_p must be in (0, 1]")
        if ttft_target_s is not None and ttft_target_s <= 0:
            raise ValueError("ttft_target_s must be > 0 (None disables)")
        if tpot_target_s is not None and tpot_target_s <= 0:
            raise ValueError("tpot_target_s must be > 0 (None disables)")
        max_new = min(int(max_new_tokens), self.max_len - len(prompt))
        req = ScheduledRequest(self._next_rid, prompt, max_new, eos_id,
                               temperature=float(temperature),
                               top_k=int(top_k), top_p=float(top_p),
                               seed=int(seed) & 0x7FFFFFFF,
                               priority=int(priority),
                               ttft_target_s=ttft_target_s,
                               tpot_target_s=tpot_target_s,
                               t_submit=time.perf_counter())
        self._next_rid += 1
        if self.trace is not None:
            self.trace.instant("submit", req.rid, args={
                "prompt_len": len(prompt), "max_new": max_new,
                "priority": req.priority,
                "ttft_target_s": ttft_target_s,
                "tpot_target_s": tpot_target_s})
        total = pages_needed(len(prompt) + max_new, self.page_size)
        limit = self.alloc.n_pages - 1
        if total > limit:
            self.stats["requests_rejected"] += 1
            self._fail(req, f"unservable: needs {total} pages "
                            f"({len(prompt)} prompt + {max_new} new tokens "
                            f"at page_size {self.page_size}) but the pool "
                            f"holds {limit}", rejected=True)
            return req
        self.queue.append(req)
        if self.trace is not None:
            self.trace.instant("queued", req.rid, wave=self._wave)
        return req

    def _fail(self, req: ScheduledRequest, msg: str,
              rejected: bool = False) -> None:
        """Per-request failure isolation: mark THIS request failed and
        finished; the engine and every other request keep serving.
        ``rejected`` distinguishes submit-time rejection in the trace."""
        req.error = msg
        req.t_done = time.perf_counter()
        self.finished[req.rid] = req
        self.stats["requests_failed"] += 1
        if self.trace is not None:
            self.trace.instant("fail", req.rid, wave=self._wave, args={
                "reason": msg, "rejected": rejected,
                "n_out": len(req.out), "ttft_s": req.ttft,
                "tpot_s": req.tpot, "latency_s": req.latency})
        self._observe_terminal(req)

    def _observe_terminal(self, req: ScheduledRequest) -> None:
        """Record a finished request's latency samples (histograms skip
        None — e.g. a request cancelled before prefill has no ttft)."""
        m = self.obs.metrics
        m.observe("request.ttft_s", req.ttft)
        m.observe("request.tpot_s", req.tpot)
        m.observe("request.latency_s", req.latency)

    def _trie_hit_rate(self) -> float:
        """Fraction of prompt tokens served from the prefix trie."""
        shared = self.stats["shared_tokens"]
        total = shared + self.stats["prefill_tokens"]
        return shared / total if total else 0.0

    # -- scheduler iteration ------------------------------------------------

    @property
    def n_active(self) -> int:
        """Occupied decode slots (in-flight requests, excluding queue)."""
        return sum(r is not None for r in self.slot_req)

    def effective_priority(self, req: ScheduledRequest) -> int:
        """Queue-ordering priority with aging applied: every
        ``age_every`` skipped admission waves promote the request one
        level, so low-priority work cannot starve behind a steady stream
        of later, nominally-higher-priority arrivals. Preemption
        compares *base* priorities only — aging orders the queue, it
        never evicts running work."""
        return req.priority - req.skips // self.age_every

    def _queue_key(self, req: ScheduledRequest, now: float):
        """(effective priority, deadline slack, arrival). Slack is how
        much of the TTFT budget remains (requests without a target sort
        last within their priority class); a preempted request resuming
        mid-generation sorts first — it holds spilled state and its
        tokens are already owed."""
        if req.out:
            slack = float("-inf")
        elif req.ttft_target_s is not None:
            slack = req.t_submit + req.ttft_target_s - now
        else:
            slack = float("inf")
        return (self.effective_priority(req), slack, req.rid)

    def _order_queue(self) -> None:
        if len(self.queue) > 1:
            now = time.perf_counter()
            self.queue = collections.deque(
                sorted(self.queue, key=lambda r: self._queue_key(r, now)))

    def _match_prefix(self, req: ScheduledRequest) -> List[int]:
        """Longest usable trie match for this prompt, with backend-capability
        adjustments applied, shared (refcount +1) before any allocator
        traffic could free the pages."""
        ps = self.page_size
        T = len(req.prompt)
        shared = self.prefix.match(req.prompt)
        if self.backend.snapshot_state:
            # snapshot pages are read at scan start, before this wave's
            # writes land: anything written by this same admission wave
            # is unusable — truncate the chain at the first pending page
            for i, p in enumerate(shared):
                if p in self._pending:
                    shared = shared[:i]
                    break
            # a full-prompt hit must recompute the final token, but a
            # snapshot can't rewind mid-page: drop the last page and
            # recompute its page_size tokens (no fork needed)
            if shared and len(shared) * ps >= T:
                shared.pop()
        elif shared and len(shared) * ps >= T and shared[-1] in self._pending:
            # KV pages: only a tail fork reads pages mid-flight; pending
            # pages can't be forked (their KV lands on device mid-call)
            shared.pop()
        self.backend.share(shared)
        return shared

    def _plan_admit(self, req: ScheduledRequest) \
            -> Optional[Tuple[List[int], int]]:
        """Map pages for one fresh request: the longest trie-cached
        prompt prefix is shared read-only, fresh pages cover the rest,
        and a COW fork detaches the last shared page when the recomputed
        tail must write into it. With token-granular sharing on, a
        partial-tail / near-miss match past the last full shared page is
        copied into a fresh private page (``fork_partial``) so only the
        genuinely-unshared remainder is recomputed. Returns
        (pages, cached_len) or None when the pool cannot serve the
        request right now."""
        ps = self.page_size
        T = len(req.prompt)
        total = pages_needed(T + req.max_new_tokens, ps)
        shared: List[int] = []
        if self.prefix is not None:
            shared = self._match_prefix(req)
        shared_len = len(shared) * ps
        fork_src = None
        if shared and shared_len >= T:
            # page-aligned full-prompt hit (positional pages only): the
            # final prompt token is recomputed for its logits, writing
            # into the last shared page -> COW fork
            shared_len = T - 1
            fork_src = shared[-1]
        partial = None                    # (src_page, n_tokens)
        if self.partial_prefix and fork_src is None:
            partial = self.prefix.match_tail(req.prompt, len(shared),
                                             self._pending)
            if partial is not None:
                # hold the source across any eviction below: a
                # trie-only page (refcount 1) would otherwise be an
                # eviction candidate while we still need its content
                self.alloc.share([partial[0]])
        n_fresh = total - len(shared) - (partial is not None)
        fresh = self.backend.alloc_view(n_fresh)
        if fresh is None and self.prefix is not None:
            self.prefix.evict(n_fresh - self.alloc.n_free)
            fresh = self.backend.alloc_view(n_fresh)
        if fresh is None:
            if partial is not None:
                self.alloc.free([partial[0]])
            self.backend.release(shared)
            return None
        if fork_src is not None:
            self.state, dst = self.backend.fork(self.state, fork_src)
            if dst is None and self.prefix is not None:
                self.prefix.evict(1)             # same fallback as alloc
                self.state, dst = self.backend.fork(self.state, fork_src)
            if dst is None:                      # needs one more page
                self.backend.release(fresh + shared)
                return None
            if dst != fork_src:
                self.stats["pages_allocated"] += 1
            shared[-1] = dst
        if partial is not None:
            src, n_tok = partial
            self.state, dst = self.backend.fork_partial(self.state, src,
                                                        n_tok)
            if dst is None and self.prefix is not None:
                self.prefix.evict(1)
                self.state, dst = self.backend.fork_partial(
                    self.state, src, n_tok)
            self.alloc.free([src])               # drop the eviction hold
            if dst is None:                      # needs one more page
                self.backend.release(fresh + shared)
                return None
            shared.append(dst)
            shared_len += n_tok
            self.stats["pages_allocated"] += 1
            self.stats["prefix_partial_hits"] += 1
            self.stats["prefix_partial_tokens_shared"] += n_tok
        self.stats["pages_allocated"] += n_fresh
        self.stats["pages_shared"] += len(shared) \
            - (fork_src is not None) - (partial is not None)
        self.stats["shared_tokens"] += shared_len
        return shared + fresh, shared_len

    def _plan_resume(self, req: ScheduledRequest) \
            -> Optional[Tuple[List[int], int]]:
        """Map pages for a preempted request re-entering a slot: its
        full capacity is allocated fresh (resumes never touch the trie —
        their sequence mixes prompt and generated tokens), the spilled
        pages are scattered back if it spilled, and the remainder — the
        whole sequence for a recompute resume — is re-prefilled."""
        total = pages_needed(len(req.prompt) + req.max_new_tokens,
                             self.page_size)
        fresh = self.backend.alloc_view(total)
        if fresh is None and self.prefix is not None:
            self.prefix.evict(total - self.alloc.n_free)
            fresh = self.backend.alloc_view(total)
        if fresh is None:
            return None
        self.stats["pages_allocated"] += total
        cached = req.spill.length if req.spill is not None else 0
        return fresh, cached

    def _plan(self, req: ScheduledRequest) \
            -> Optional[Tuple[List[int], int]]:
        if req.out:
            return self._plan_resume(req)
        return self._plan_admit(req)

    # -- preemption ---------------------------------------------------------

    def _pick_victim(self, priority: int, protected: Set[int]) \
            -> Optional[int]:
        """Least-urgent, latest-arrival running slot whose *base*
        priority is strictly less urgent than ``priority`` — or None
        (nothing may be preempted for an equal-or-less-urgent request).
        Slots filled this same wave are protected: their prefill hasn't
        run yet."""
        best = None
        for slot, r in enumerate(self.slot_req):
            if r is None or slot in protected or r.priority <= priority:
                continue
            key = (r.priority, r.rid)
            if best is None or key > best[0]:
                best = (key, slot)
        return None if best is None else best[1]

    def _restore_beats_recompute(self, n_pages: int, n_tokens: int) -> bool:
        """The preemption cost model: restoring spilled pages costs a
        host->device copy of their bytes; recomputing costs re-prefilling
        ``n_tokens`` at the measured batched-prefill rate. 'spill' /
        'recompute' policies force one side (tests pin paths with them;
        both resume bit-identically)."""
        if self.preempt_policy == "spill":
            return True
        if self.preempt_policy == "recompute":
            return False
        s = self.stats
        prefill_rate = s["prefill_tokens"] / s["prefill_s"] \
            if s["prefill_s"] > 0 else 1e4
        if not self._page_nbytes:
            self._page_nbytes = self.backend.page_nbytes(self.state)
        t_restore = n_pages * self._page_nbytes / HOST_RESTORE_BYTES_S
        return t_restore < n_tokens / prefill_rate

    def _preempt(self, slot: int) -> None:
        """Evict the running request in ``slot``: spill (or drop, per
        the cost model) its live pages, release its refcounts, and put
        it back on the queue to resume later. No timestamps are touched
        — the request is still in flight, just not resident."""
        req = self.slot_req[slot]
        L = int(self.lengths[slot])
        live = pages_needed(L, self.page_size)
        pages = self.slot_pages[slot]
        # a mid-ingest victim (chunked prefill, no token emitted yet)
        # always recomputes: it re-enters _plan_admit as a fresh request
        # whose page layout (trie shares + partial fork) need not match
        # a spill's, so a restored copy would scatter into the wrong map
        if req.out and self._restore_beats_recompute(live, L):
            req.spill = SpilledPages(
                length=L, leaves=self.backend.spill(self.state,
                                                    pages[:live]))
            self.stats["pages_spilled"] += live
        else:
            req.spill = None
            self.stats["preempt_recomputes"] += 1
        req.preemptions += 1
        self.stats["preemptions"] += 1
        self.backend.release(pages)
        self._clear_slot(slot)
        self._wave_preempted.add(req.rid)
        self.queue.append(req)       # re-ordered at the next admit wave
        if self.trace is not None:
            self.trace.instant(
                "preempt", req.rid, slot, self._wave,
                args={"mode": "recompute" if req.spill is None
                      else "spill", "tokens": L, "pages": live})

    def _plan_or_preempt(self, req: ScheduledRequest,
                         protected: Set[int]) \
            -> Optional[Tuple[List[int], int]]:
        """Plan pages for ``req``, preempting strictly-less-urgent
        running requests one at a time (worst first) until the plan
        fits or no victim remains."""
        plan = self._plan(req)
        if self.preempt_policy == "off":
            return plan
        while plan is None:
            victim = self._pick_victim(req.priority, protected)
            if victim is None:
                return None
            self._preempt(victim)
            plan = self._plan(req)
        return plan

    # -- admission ----------------------------------------------------------

    def _fill_slot(self, slot: int, req: ScheduledRequest,
                   pages: List[int], cached: int) -> None:
        self.slot_req[slot] = req
        self.slot_pages[slot] = pages
        self.page_table[slot, :] = SCRATCH_PAGE
        self.page_table[slot, :len(pages)] = pages
        self.lengths[slot] = cached
        self.temps[slot] = req.temperature
        self.top_ks[slot] = req.top_k
        self.top_ps[slot] = req.top_p
        self.seeds[slot] = req.seed
        if self.trace is not None:
            self.trace.instant("resume" if req.out else "admit",
                               req.rid, slot, self._wave,
                               args={"cached_tokens": cached,
                                     "pages": len(pages)})
        if req.spill is not None:
            # spilled resume: scatter the host copy back bit-identically
            live = pages_needed(req.spill.length, self.page_size)
            self.state = self.backend.restore(self.state, pages[:live],
                                              req.spill.leaves)
            self.stats["pages_restored"] += live
            req.spill = None
            if self.trace is not None:
                self.trace.instant("restore", req.rid, slot, self._wave,
                                   args={"pages": live})
        elif not req.out and self.prefix is not None \
                and self.prefill_chunk_tokens == 0:
            n_full = len(req.prompt) // self.page_size
            self.prefix.insert(req.prompt, pages[:n_full])
            self._pending.update(pages[cached // self.page_size:n_full])
            # chunked mode (prefill_chunk_tokens > 0) defers this insert
            # to ingest completion (_prefill_chunk): the pages hold no
            # content yet and nothing marks them pending across waves

    def _admit(self) -> int:
        """Fill free slots from the queue in (priority, slack, arrival)
        order, then prefill every admitted request in ONE batched jitted
        call. An unservable candidate is scanned past (bounded
        skip-ahead) so smaller requests behind it still admit — unless
        it has aged past ``starvation_limit``, which blocks skip-ahead
        until the pool drains for it. Returns how many were admitted (a
        request may finish during its own prefill, so admitted > 0 with
        n_active == 0 afterwards is normal — the caller re-admits)."""
        self._order_queue()
        self._wave_preempted.clear()
        t0 = time.perf_counter()
        plans = []
        deferred: List[ScheduledRequest] = []
        filled: Set[int] = set()
        scan = self.admit_lookahead
        while self.queue:
            req = self.queue[0]
            if req.rid in self._wave_preempted:
                # preempted moments ago for someone this wave — let it
                # re-enter next wave, not bounce straight back in
                deferred.append(self.queue.popleft())
                continue
            slot = next((s for s in range(self.max_batch)
                         if self.slot_req[s] is None), None)
            if slot is None:
                # every slot busy: a strictly-more-urgent head may
                # preempt its way in; anyone else waits for a reap
                victim = self._pick_victim(req.priority, filled) \
                    if self.preempt_policy != "off" else None
                if victim is None:
                    break
                self._preempt(victim)
                slot = victim
            plan = self._plan_or_preempt(req, filled)
            if plan is None:           # pool full for this request
                req.skips += 1
                if scan <= 0 or req.skips > self.starvation_limit:
                    break              # aged head: no skip-ahead past it
                scan -= 1
                deferred.append(self.queue.popleft())
                continue
            self.queue.popleft()
            req.skips = 0
            pages, cached = plan
            self._fill_slot(slot, req, pages, cached)
            filled.add(slot)
            plans.append((slot, req, cached))
        self.queue.extendleft(reversed(deferred))
        if plans:
            if self.spec is not None:
                self._draft_prefill(plans)
            if self.prefill_chunk_tokens > 0:
                # chunked mode: the admission wave only maps pages; the
                # prompts ingest in budget-bounded chunks between decode
                # waves (_prefill_chunk). Fully-cached resumes (restored
                # spills) have nothing to ingest and decode immediately.
                for slot, req, cached in plans:
                    if len(req.resume_seq) - cached > 0:
                        self._ingest[slot] = req.resume_seq
            else:
                self._batched_prefill(plans)
            self._pending.clear()
            if self.trace is not None:
                self.trace.span("admit_wave", t0, time.perf_counter(),
                                wave=self._wave,
                                args={"admitted": len(plans)})
        return len(plans)

    def _draft_prefill(self, plans) -> None:
        """Mirror an admission wave into the coarse draft: ONE jitted
        coarse-model call writes every admitted slot's FULL sequence into
        the draft's private pages (the draft has no prefix trie and no
        spill state, so its bucket is the whole prompt — or, for a
        resumed request, prompt + committed output — not the unshared
        remainder)."""
        seqs = [(slot, req.resume_seq) for slot, req, _ in plans]
        S = bucket_len(max(len(s) for _, s in seqs), hi=self.max_len)
        toks = np.zeros((self.max_batch, S), np.int32)
        n_new = np.zeros((self.max_batch,), np.int32)
        for slot, seq in seqs:
            toks[slot, :len(seq)] = seq
            n_new[slot] = len(seq)
        self.spec.prefill(toks, n_new)
        self.stats["draft_calls"] += 1

    def _slot_batch(self, n_new, counters) -> SlotBatch:
        return SlotBatch(self.lengths.copy(), n_new, self.page_table,
                         self.temps, self.top_ks, self.top_ps, self.seeds,
                         counters)

    def _batched_prefill(self, plans) -> None:
        """One jitted (max_batch, bucket) call writes every admitted
        sequence's non-cached remainder into its pages and samples each
        first token. Slots mid-decode ride along masked out (n_new == 0),
        so the call count per wave is 1 regardless of queue depth.
        Restored-resume slots (already fully cached) skip the call;
        recompute-resume slots re-ingest their sequence but discard the
        sampled token — their pending token was already emitted."""
        work = [(slot, req, req.resume_seq, cached)
                for slot, req, cached in plans
                if len(req.resume_seq) - cached > 0]
        if not work:
            return
        S = bucket_len(max(len(seq) - c for _, _, seq, c in work),
                       hi=self.max_len)
        toks = np.zeros((self.max_batch, S), np.int32)
        n_new = np.zeros((self.max_batch,), np.int32)
        counters = np.zeros((self.max_batch,), np.int32)
        for slot, req, seq, c in work:
            n = len(seq) - c
            toks[slot, :n] = seq[c:]
            n_new[slot] = n
            counters[slot] = len(req.out)
        t0 = time.perf_counter()
        self.state, nxt = self.backend.prefill(
            self.state, self._slot_batch(n_new, counters), toks)
        nxt = np.asarray(nxt)
        now = time.perf_counter()
        self.stats["prefill_tokens"] += int(n_new.sum())
        self.stats["prefill_s"] += now - t0
        self.stats["prefill_calls"] += 1
        self.obs.metrics.observe("wave.prefill_s", now - t0)
        if self.trace is not None:
            self.trace.span("prefill", t0, now, wave=self._wave,
                            args={"tokens": int(n_new.sum()),
                                  "bucket": S, "slots": len(work)})
            for slot, req, seq, c in work:
                self.trace.span("prefill", t0, now, req.rid, slot,
                                self._wave, args={"tokens": len(seq) - c})
        for slot, req, seq, _ in work:
            self.lengths[slot] = len(seq)
            if req.out:                # recompute resume: state only
                continue
            req.t_first = now
            tok = int(nxt[slot, 0])
            req.out.append(tok)
            if self.trace is not None:
                self.trace.instant("first_token", req.rid, slot,
                                   self._wave)
            if self._is_done(req, tok):
                self._reap(slot)

    def _prefill_chunk(self) -> None:
        """One budget-bounded ingest wave (chunked-prefill interleaving,
        ``prefill_chunk_tokens > 0``): take up to the budget of pending
        prompt tokens across the ingesting slots — lowest slot first —
        and write them with ONE jitted (max_batch, bucket) prefill call,
        exactly the shape universe ``_batched_prefill`` uses (no new jit
        shapes). A slot whose sequence completes this wave emits its
        first token from this call's logits; incomplete slots discard
        the mid-prompt sample (the sampling key folds in the emitted-
        token counter, not the call count, so the final chunk's sample
        is bitwise the serial prefill's). Decode waves run in the same
        scheduler iteration for every non-ingesting slot, so a long
        prompt delays decode by at most one chunk budget."""
        budget = self.prefill_chunk_tokens
        work = []                        # (slot, req, seq, start, take)
        for slot in sorted(self._ingest):
            if budget <= 0:
                break
            req = self.slot_req[slot]
            seq = self._ingest[slot]
            start = int(self.lengths[slot])
            take = min(len(seq) - start, budget)
            if take <= 0:
                continue
            budget -= take
            work.append((slot, req, seq, start, take))
        if not work:
            return
        S = bucket_len(max(t for *_, t in work), hi=self.max_len)
        toks = np.zeros((self.max_batch, S), np.int32)
        n_new = np.zeros((self.max_batch,), np.int32)
        counters = np.zeros((self.max_batch,), np.int32)
        for slot, req, seq, start, take in work:
            toks[slot, :take] = seq[start:start + take]
            n_new[slot] = take
            counters[slot] = len(req.out)
        t0 = time.perf_counter()
        self.state, nxt = self.backend.prefill(
            self.state, self._slot_batch(n_new, counters), toks)
        nxt = np.asarray(nxt)
        now = time.perf_counter()
        self.stats["prefill_tokens"] += int(n_new.sum())
        self.stats["prefill_s"] += now - t0
        self.stats["prefill_calls"] += 1
        self.stats["prefill_chunks"] += 1
        self.obs.metrics.observe("wave.prefill_s", now - t0)
        if self.trace is not None:
            self.trace.span("prefill_chunk", t0, now, wave=self._wave,
                            args={"tokens": int(n_new.sum()),
                                  "bucket": S, "slots": len(work)})
            for slot, req, _, start, take in work:
                self.trace.span("prefill_chunk", t0, now, req.rid, slot,
                                self._wave, args={"tokens": take})
        for slot, req, seq, start, take in work:
            self.lengths[slot] = start + take
            if start + take < len(seq):
                continue                 # more chunks to go
            del self._ingest[slot]
            if not req.out and self.prefix is not None:
                # the deferred trie publish: pages now hold real content
                n_full = len(req.prompt) // self.page_size
                self.prefix.insert(req.prompt,
                                   self.slot_pages[slot][:n_full])
            if req.out:                  # recompute resume: state only
                continue
            req.t_first = now
            tok = int(nxt[slot, 0])
            req.out.append(tok)
            if self.trace is not None:
                self.trace.instant("first_token", req.rid, slot,
                                   self._wave)
            if self._is_done(req, tok):
                self._reap(slot)

    def _check_cow(self, slot: int, req: ScheduledRequest) -> None:
        """COW invariant: the page this slot is about to write must be
        private. Replaces the bare ``assert`` (stripped under
        ``python -O``) with a debug-gated diagnostic raise."""
        page = int(self.page_table[slot,
                                   self.lengths[slot] // self.page_size])
        rc = self.alloc.refcount(page)
        if rc != 1:
            raise COWViolationError(
                f"slot {slot} (rid {req.rid}) is about to write page "
                f"{page} with refcount {rc}; pages in a slot's write "
                f"range must be private (refcount 1) when the decode "
                f"call launches")

    def _decode_once(self) -> None:
        toks = np.zeros((self.max_batch, 1), np.int32)
        n_new = np.zeros((self.max_batch,), np.int32)
        counters = np.zeros((self.max_batch,), np.int32)
        for slot, req in enumerate(self.slot_req):
            # mid-ingest slots (chunked prefill) have no pending token
            # yet — they ride along masked out (n_new == 0)
            if req is not None and slot not in self._ingest:
                toks[slot, 0] = req.out[-1]
                n_new[slot] = 1
                counters[slot] = len(req.out)
                if self._debug_checks:
                    self._check_cow(slot, req)
        t0 = time.perf_counter()
        self.state, nxt = self.backend.step(
            self.state, self._slot_batch(n_new, counters), toks)
        nxt = np.asarray(nxt)
        dt = time.perf_counter() - t0
        n_act = int(n_new.sum())
        self.stats["decode_tokens"] += n_act
        self.stats["decode_s"] += dt
        self.stats["decode_steps"] += 1
        self.obs.metrics.observe("wave.decode_s", dt)
        if self.trace is not None:
            # per-slot spans before the reap loop clears slots
            self.trace.span("decode", t0, t0 + dt, wave=self._wave,
                            args={"n_active": n_act})
            for slot, req in enumerate(self.slot_req):
                if req is not None and slot not in self._ingest:
                    self.trace.span("decode", t0, t0 + dt, req.rid,
                                    slot, self._wave)
        for slot, req in enumerate(self.slot_req):
            if req is None or slot in self._ingest:
                continue
            self.lengths[slot] += 1       # last token now lives in the cache
            tok = int(nxt[slot, 0])
            req.out.append(tok)
            if self._is_done(req, tok):
                self._reap(slot)

    def _spec_wave(self) -> None:
        """One speculative decode wave: coarse-propagator draft of up to
        ``k`` tokens per slot + ONE full-model verify call; each slot
        advances by ``accepted + 1`` tokens (greedy slots emit bitwise
        what plain decode would). Two jitted calls and one host sync for
        up to k+1 tokens per slot."""
        sp = self.spec
        k = sp.spec.k
        B = self.max_batch
        n_draft = np.zeros((B,), np.int32)
        n_in = np.zeros((B,), np.int32)
        ingest = np.zeros((B, k + 1), np.int32)
        counters = np.zeros((B,), np.int32)
        for b, req in enumerate(self.slot_req):
            if req is None or b in self._ingest:
                # mid-ingest slots (chunked prefill) have nothing to
                # verify yet: masked out like empty slots (n_in == 0)
                continue
            # never draft past the request's budget: accepted+1 <= room
            n_draft[b] = min(k, req.max_new_tokens - len(req.out) - 1)
            # canonical tokens the draft has not cached yet + the pending
            # token (position L); the catch-up is <= last wave's accepted
            # count, so k+1 columns always suffice
            row = req.out[int(sp.lengths[b]) - len(req.prompt):]
            if not 1 <= len(row) <= k + 1:
                raise COWViolationError(
                    f"spec ingest row for slot {b} has {len(row)} tokens "
                    f"(want 1..{k + 1}): draft cache length "
                    f"{int(sp.lengths[b])} drifted from the canonical "
                    "output — a previous wave committed the wrong count")
            ingest[b, :len(row)] = row
            n_in[b] = len(row)
            counters[b] = len(req.out)
        t0 = time.perf_counter()
        d, q = sp.wave(ingest, n_in, n_draft, self.temps, self.top_ks,
                       self.top_ps, self.seeds, counters)
        # verify window: [pending, d_1..d_k] per slot, assembled on device
        pending = jnp.take_along_axis(
            jnp.asarray(ingest), jnp.maximum(n_in - 1, 0)[:, None], axis=1)
        ver_toks = jnp.concatenate([pending, d], axis=1)
        slots = self._slot_batch(np.where(n_in > 0, n_draft + 1, 0),
                                 counters)
        self.state, acc, nxt = self.backend.verify(self.state, slots,
                                                   ver_toks, q)
        acc = np.asarray(acc)
        nxt = np.asarray(nxt)
        d_host = np.asarray(d)
        dt = time.perf_counter() - t0
        self.stats["draft_calls"] += 1
        self.stats["verify_calls"] += 1
        self.stats["tokens_drafted"] += int(n_draft.sum())
        self.stats["decode_s"] += dt
        self.stats["decode_steps"] += 1
        self.obs.metrics.observe("wave.decode_s", dt)
        if self.trace is not None:
            self.trace.span("spec_wave", t0, t0 + dt, wave=self._wave,
                            args={"drafted": int(n_draft.sum())})
            for b, req in enumerate(self.slot_req):
                if req is not None and b not in self._ingest:
                    self.trace.span("spec_wave", t0, t0 + dt, req.rid,
                                    b, self._wave)
        for b, req in enumerate(self.slot_req):
            if req is None or b in self._ingest:
                continue
            a = int(acc[b])
            self.stats["tokens_accepted"] += a
            self.lengths[b] += a + 1   # committed: pending + accepted
            for tok in [*d_host[b, :a], nxt[b]]:
                req.out.append(int(tok))
                self.stats["decode_tokens"] += 1
                if self._is_done(req, int(tok)):
                    self._reap(b)
                    break

    def _is_done(self, req: ScheduledRequest, tok: int) -> bool:
        return (len(req.out) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id))

    def _clear_slot(self, slot: int) -> None:
        """Reset one slot's host bookkeeping (shared by reap/preempt/
        cancel; page refcounts are the caller's business)."""
        self.slot_pages[slot] = []
        self.slot_req[slot] = None
        self.page_table[slot, :] = SCRATCH_PAGE
        self.lengths[slot] = 0
        self._ingest.pop(slot, None)
        self.temps[slot] = 0.0
        self.top_ks[slot] = 0
        self.top_ps[slot] = 1.0
        self.seeds[slot] = 0
        if self.spec is not None:
            self.spec.reset_slot(slot)

    def _reap(self, slot: int, outcome: str = "finish") -> None:
        """Release a slot and finish its request. ``outcome`` names the
        trace's terminal event — 'finish' for normal completion,
        'cancel' when the caller aborted a running request (the trace
        lifecycle invariant needs the distinction; the counters don't)."""
        req = self.slot_req[slot]
        req.t_done = time.perf_counter()
        self.finished[req.rid] = req
        if (self.partial_prefix and self.prefix is not None
                and int(self.lengths[slot]) >= len(req.prompt)
                and len(req.prompt) % self.page_size):
            # token-granular publish: the prompt's partial tail page is
            # fully ingested by now (the length guard excludes a request
            # cancelled mid-ingest), so index it in the trie before the
            # release below could free it
            self.prefix.insert_tail(
                req.prompt,
                self.slot_pages[slot][len(req.prompt) // self.page_size])
        self.backend.release(self.slot_pages[slot])
        self._clear_slot(slot)
        if self.trace is not None:
            self.trace.instant(outcome, req.rid, slot, self._wave, args={
                "n_out": len(req.out), "ttft_s": req.ttft,
                "tpot_s": req.tpot, "latency_s": req.latency})
        self._observe_terminal(req)

    def cancel(self, req: ScheduledRequest) -> None:
        """Abort a queued or in-flight request: its slot and pages return
        to the pool immediately and nothing more is generated (streaming
        early termination). Finished/unknown requests are a no-op; a
        never-prefilled cancel reports ``ttft``/``tpot`` of None, not a
        negative time."""
        if req.done:
            return
        try:
            self.queue.remove(req)
            req.spill = None             # drop any preempted host copy
            req.t_done = time.perf_counter()
            self.finished[req.rid] = req
            if self.trace is not None:
                self.trace.instant("cancel", req.rid, wave=self._wave,
                                   args={"n_out": len(req.out),
                                         "ttft_s": req.ttft,
                                         "tpot_s": req.tpot,
                                         "latency_s": req.latency})
            self._observe_terminal(req)
            return
        except ValueError:
            pass
        for slot, r in enumerate(self.slot_req):
            if r is req:
                self._reap(slot, outcome="cancel")
                return

    def drop_prefix_cache(self) -> None:
        """Release every trie-pinned page (pages still mapped by live
        requests stay allocated until those finish). Used between
        benchmark phases and by tests verifying the pool drains."""
        if self.prefix is not None:
            self.prefix.clear()

    def step(self) -> bool:
        """One scheduler iteration (admit wave + one decode). Returns
        False when idle (nothing queued or running). Never raises for a
        per-request condition: a request the pool cannot serve even on
        an idle engine fails alone (``ScheduledRequest.error``) while
        everything else keeps decoding."""
        if not self.queue and not self.n_active:
            return False
        self._wave += 1
        admitted = self._admit()
        if self._ingest:
            # chunked-prefill interleaving: one budget-bounded ingest
            # call, then the decode wave below still runs for every
            # slot that is not mid-ingest
            self._prefill_chunk()
        if self.trace is not None:
            # counter tracks sample on change only: at steady state (no
            # admissions/reaps) both values repeat wave after wave, and
            # Perfetto counter tracks render step-wise anyway
            sample = (self.alloc.n_free, len(self.queue))
            if sample != self._last_counters:
                self._last_counters = sample
                self.trace.counter("pool.free_pages", sample[0])
                self.trace.counter("scheduler.queue_depth", sample[1])
        if self.n_active:
            # skip the decode call when every occupied slot is still
            # ingesting its prompt (nothing has a pending token)
            if any(r is not None and s not in self._ingest
                   for s, r in enumerate(self.slot_req)):
                if self.spec is not None:
                    self._spec_wave()
                else:
                    self._decode_once()
        elif self.queue and admitted == 0:
            # nothing running and nothing admissible: the ordered head
            # cannot get pages even with the machine to itself (e.g.
            # pages pinned outside the scheduler). Fail it alone and
            # keep draining the rest — never kill the engine.
            req = self.queue.popleft()
            self._fail(req, f"admission failed on an idle engine: needs "
                            f"{pages_needed(len(req.prompt) + req.max_new_tokens, self.page_size)} "
                            f"pages, pool holds {self.alloc.n_pages - 1} "
                            f"({self.alloc.n_free} free)")
        return True

    def run(self) -> Dict[int, ScheduledRequest]:
        """Drain the queue; returns {rid: finished request} (failed
        requests included, with ``error`` set)."""
        while self.step():
            pass
        return self.finished

    # -- reporting ----------------------------------------------------------

    def accept_rate(self) -> float:
        """Fraction of spec-drafted tokens the verifier accepted (0 when
        spec decode is off) — the single owner of this derivation."""
        return self.stats["tokens_accepted"] / max(
            self.stats["tokens_drafted"], 1)

    def throughput(self) -> Dict[str, float]:
        """Aggregate rates derived from the counters: prefill/decode
        tokens per second of call wall-time, call counts, prompt tokens
        reused via prefix sharing, and the spec-decode accept rate."""
        s = self.stats
        return {
            "prefill_tok_s": s["prefill_tokens"] / max(s["prefill_s"], 1e-9),
            "decode_tok_s": s["decode_tokens"] / max(s["decode_s"], 1e-9),
            "decode_steps": float(s["decode_steps"]),
            "prefill_calls": float(s["prefill_calls"]),
            "shared_tokens": float(s["shared_tokens"]),
            "accept_rate": self.accept_rate(),
        }
