"""Encoder-decoder layer-parallel training (the paper's MT task + novel
enc-dec neural-ODE formulation, Eq. 3), reduced for CPU.

Two chained MGRIT grids: encoder solve feeds the decoder's cross-attention.

Run:  PYTHONPATH=src python examples/translation.py --steps 100
"""
import argparse
import dataclasses
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.configs import registry
from repro.configs.base import OptimizerConfig, ShapeConfig
from repro.configs.reduce import reduce_config
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    args = ap.parse_args()

    base = registry.get_config("mt_marian")
    rcfg = reduce_config(base, seq=24, batch=8)
    rcfg = dataclasses.replace(
        rcfg,
        mgrit=dataclasses.replace(rcfg.mgrit, fwd_iters=2, bwd_iters=2,
                                  check_every=40),
        optimizer=OptimizerConfig(name="adamw", lr=1e-3, warmup_steps=20,
                                  total_steps=args.steps),
        shape=ShapeConfig("mt", "train", 24, 8))

    print("=== enc-dec layer-parallel (Eq. 3, two chained MGRIT grids) ===")
    t_lp = Trainer(rcfg, seed=0)
    rep_lp = t_lp.train(args.steps, log_every=20)

    print("=== enc-dec serial baseline ===")
    ser = dataclasses.replace(
        rcfg, mgrit=dataclasses.replace(rcfg.mgrit, enabled=False))
    t_s = Trainer(ser, seed=0)
    rep_s = t_s.train(args.steps, log_every=20, probe=False)

    lp, ls = np.array(rep_lp.losses), np.array(rep_s.losses)
    print(f"\nfinal loss  serial={ls[-5:].mean():.4f}  lp={lp[-5:].mean():.4f}"
          f"  (paper Fig. 3 right: LP tracks serial; a late-training gap is"
          f" recovered by the serial switch)")


if __name__ == "__main__":
    main()
