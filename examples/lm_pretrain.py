"""End-to-end LM pre-training driver (paper's GPT2 setup, reduced for CPU).

Demonstrates the full production path:
  * layer-parallel MGRIT training with buffer layers (App. B),
  * the adaptive indicator probe + automatic LP -> serial switch (§3.2.3),
  * periodic fault-tolerant checkpointing and resume.

Run:  PYTHONPATH=src python examples/lm_pretrain.py --steps 200
      (add --full for the paper-size 20-layer d=768 nanoGPT config)
"""
import argparse
import dataclasses
import shutil
import sys
import tempfile

sys.path.insert(0, "src")

from repro.configs import registry
from repro.configs.base import OptimizerConfig, ShapeConfig
from repro.configs.reduce import reduce_config
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="paper-size GPT2 (20L, d=768) instead of reduced")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    rcfg = registry.get_config("gpt2_nanogpt")
    if not args.full:
        rcfg = reduce_config(rcfg, seq=64, batch=8)
        # keep the paper's buffer-layer structure in the reduction
        rcfg = dataclasses.replace(
            rcfg, mgrit=dataclasses.replace(
                rcfg.mgrit, n_open=1, n_close=1, fwd_iters=1, bwd_iters=1,
                check_every=50, enabled=True))
    rcfg = dataclasses.replace(
        rcfg,
        optimizer=OptimizerConfig(name="adamw", lr=3e-3, warmup_steps=20,
                                  total_steps=args.steps),
        shape=rcfg.shape if args.full else ShapeConfig(
            "lm", "train", 64, 8))

    ckpt_dir = args.ckpt or tempfile.mkdtemp(prefix="lmckpt-")
    trainer = Trainer(rcfg, ckpt_dir=ckpt_dir, seed=0)
    print(f"params: {sum(x.size for x in __import__('jax').tree.leaves(trainer.params)):,}")
    report = trainer.train(args.steps, ckpt_every=max(args.steps // 4, 1),
                           log_every=25)

    print(f"\nsteps/sec: {report.steps_per_sec:.2f}")
    if report.switched_at is not None:
        print(f"adaptive controller switched LP->serial at step "
              f"{report.switched_at} (paper Fig. 4/5 behavior)")
    else:
        print("controller kept layer-parallel mode (indicator < 1)")
    print(f"loss: {report.losses[0]:.3f} -> {report.losses[-1]:.3f}")

    # resume-from-checkpoint demonstration (fault tolerance)
    resumed = Trainer(rcfg, ckpt_dir=ckpt_dir, seed=0)
    print(f"resume check: restarted trainer resumes at step {resumed.step}")
    if not args.ckpt:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
