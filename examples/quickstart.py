"""Quickstart: layer-parallel (MGRIT) vs serial training of a small
encoder-only neural-ODE transformer (the paper's MC setup, reduced).

Run:  PYTHONPATH=src python examples/quickstart.py [--steps 100]
"""
import argparse
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.configs.base import (MGRITConfig, ModelConfig, OptimizerConfig,
                                RunConfig, ShapeConfig)
from repro.train.trainer import Trainer


def make_rcfg(mode_lp: bool, steps: int) -> RunConfig:
    model = ModelConfig(
        name="quickstart-mc", family="encoder", n_layers=16, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
        act="gelu", norm="layernorm")
    mgrit = MGRITConfig(enabled=mode_lp, cf=2, levels=2, fwd_iters=2,
                        bwd_iters=1, pad_to=16, check_every=50)
    return RunConfig(
        model=model, mgrit=mgrit,
        optimizer=OptimizerConfig(name="sgd", lr=0.05, warmup_steps=10,
                                  total_steps=steps, grad_clip=1.0),
        shape=ShapeConfig("quickstart", "train", 32, 8))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    args = ap.parse_args()

    print("=== serial (exact) training ===")
    t_serial = Trainer(make_rcfg(False, args.steps), seed=0)
    rep_s = t_serial.train(args.steps, log_every=25, probe=False)

    print("=== layer-parallel (MGRIT, 2 fwd / 1 bwd V-cycles) ===")
    t_lp = Trainer(make_rcfg(True, args.steps), seed=0)
    rep_p = t_lp.train(args.steps, log_every=25)

    ls, lp = np.array(rep_s.losses), np.array(rep_p.losses)
    print(f"\nfinal loss  serial={ls[-5:].mean():.4f}  "
          f"layer-parallel={lp[-5:].mean():.4f}")
    print(f"max |serial - lp| over run: {np.max(np.abs(ls - lp)):.4f}")
    print("Layer-parallel training tracks serial training (paper Fig. 3).")


if __name__ == "__main__":
    main()
