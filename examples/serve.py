"""Serve a small model with the continuous-batching engine.

A queue of mixed-length requests streams through chunked prefill into the
paged KV cache; the scheduler keeps the decode slots full and reports
per-request latency plus aggregate throughput.

Run:  PYTHONPATH=src python examples/serve.py
"""
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs import registry
from repro.configs.reduce import reduce_config
from repro.models import transformer
from repro.serve.engine import Request, ServeEngine


def main():
    rcfg = reduce_config(registry.get_config("qwen3_1p7b"))
    params = transformer.init_model(jax.random.PRNGKey(0), rcfg)
    engine = ServeEngine(rcfg, params, max_len=64, max_batch=4, page_size=8)
    print(f"engine: paged={engine.paged} "
          f"(pool: {engine.scheduler.alloc.n_pages} pages x "
          f"{engine.scheduler.page_size} tokens)")

    # 10 mixed-length requests through 4 decode slots
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, rcfg.model.vocab_size,
                                        size=int(rng.integers(4, 24))).astype(
                        np.int32),
                    max_new_tokens=int(rng.integers(4, 12)))
            for _ in range(10)]
    out = engine.generate(reqs)
    for i, r in enumerate(out):
        print(f"request {i}: prompt[{len(r.prompt):2d}] -> "
              f"{list(map(int, r.output))}  "
              f"ttft={r.ttft_s*1e3:6.1f}ms  lat={r.latency_s*1e3:6.1f}ms")

    thr = engine.scheduler.throughput()
    print(f"aggregate: prefill {thr['prefill_tok_s']:.1f} tok/s, "
          f"decode {thr['decode_tok_s']:.1f} tok/s")
    tps = engine.throughput_probe(batch=4, steps=8)
    print(f"steady-state decode probe (batch 4): {tps:.1f} tok/s")
    print(f"chunked-prefill probe (64-tok prompt): "
          f"{engine.prefill_probe(64):.0f} tok/s")


if __name__ == "__main__":
    main()
