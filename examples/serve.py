"""Serve a small model with batched requests (prefill + KV-cache decode).

Run:  PYTHONPATH=src python examples/serve.py
"""
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs import registry
from repro.configs.reduce import reduce_config
from repro.models import transformer
from repro.serve.engine import Request, ServeEngine


def main():
    rcfg = reduce_config(registry.get_config("qwen3_1p7b"))
    params = transformer.init_model(jax.random.PRNGKey(0), rcfg)
    engine = ServeEngine(rcfg, params, max_len=64)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, rcfg.model.vocab_size,
                                        size=rng.integers(4, 12)).astype(
                        np.int32),
                    max_new_tokens=8) for _ in range(4)]
    out = engine.generate(reqs)
    for i, r in enumerate(out):
        print(f"request {i}: prompt[{len(r.prompt)}] -> "
              f"generated {list(map(int, r.output))}")

    tps = engine.throughput_probe(batch=8, steps=8)
    print(f"steady-state decode throughput (CPU, batch 8): {tps:.1f} tok/s")


if __name__ == "__main__":
    main()
