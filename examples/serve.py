"""Serve a small model with the continuous-batching engine.

A queue of requests sharing a common "system prompt" prefix streams
through batched chunked prefill into the paged KV cache: the first wave
publishes the prefix pages in the prefix trie, later requests map them
read-only (copy-on-write) and prefill only their private tail. Half the
requests decode greedily, half sample with per-request
temperature/top-k/top-p — all lock-step in the same jitted call.

The last request is streamed: ``engine.submit(req, stream=True)`` returns
an iterator yielding ``(token_id, text_piece)`` with incremental
detokenization, while the queued batch decodes lock-step alongside it.

Run:  PYTHONPATH=src python examples/serve.py
"""
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs import registry
from repro.configs.reduce import reduce_config
from repro.models import transformer
from repro.serve.engine import Request, ServeEngine
from repro.serve.spec import SpecConfig


def main():
    rcfg = reduce_config(registry.get_config("qwen3_1p7b"))
    params = transformer.init_model(jax.random.PRNGKey(0), rcfg)
    engine = ServeEngine(rcfg, params, max_len=64, max_batch=4, page_size=8)
    print(f"engine: {type(engine.backend).__name__} "
          f"(pool: {engine.scheduler.alloc.n_pages} pages x "
          f"{engine.scheduler.page_size} tokens)")

    # 10 requests with a 24-token shared system prompt + private tails;
    # even ids greedy, odd ids sampled with their own temperature/seed
    rng = np.random.default_rng(0)
    system = rng.integers(0, rcfg.model.vocab_size, size=24).astype(np.int32)
    reqs = []
    for i in range(10):
        tail = rng.integers(0, rcfg.model.vocab_size,
                            size=int(rng.integers(2, 10))).astype(np.int32)
        reqs.append(Request(
            prompt=np.concatenate([system, tail]),
            max_new_tokens=int(rng.integers(4, 12)),
            temperature=0.0 if i % 2 == 0 else 0.8 + 0.1 * i,
            top_k=0 if i % 2 == 0 else 20,
            top_p=1.0 if i % 2 == 0 else 0.95,
            seed=i))
    out = engine.generate(reqs)
    for i, r in enumerate(out):
        mode = "greedy" if r.temperature == 0 else \
            f"T={r.temperature:.1f}"
        print(f"request {i}: prompt[{len(r.prompt):2d}] {mode:6s} -> "
              f"{list(map(int, r.output))}  "
              f"ttft={r.ttft_s*1e3:6.1f}ms  lat={r.latency_s*1e3:6.1f}ms")

    # streaming: tokens surface as they are emitted, detokenized
    # incrementally (the demo detokenizer renders ids as ⟨id⟩ pieces)
    streamed = Request(
        prompt=np.concatenate([system, np.array([42, 7], np.int32)]),
        max_new_tokens=8, temperature=0.9, top_k=20, seed=99)
    print("streamed request: ", end="", flush=True)
    for _tok, piece in engine.submit(streamed, stream=True):
        print(piece, end="", flush=True)
    print(f"  ({len(streamed.output)} tokens, "
          f"lat={streamed.latency_s*1e3:.1f}ms)")

    st = engine.scheduler.stats
    thr = engine.scheduler.throughput()
    print(f"aggregate: prefill {thr['prefill_tok_s']:.1f} tok/s over "
          f"{thr['prefill_calls']:.0f} batched calls, "
          f"decode {thr['decode_tok_s']:.1f} tok/s")
    print(f"prefix sharing: {st['shared_tokens']} of "
          f"{st['shared_tokens'] + st['prefill_tokens']} prompt tokens "
          f"served from shared pages ({st['pages_shared']} page mappings, "
          f"{st['pages_allocated']} pages allocated)")
    tps = engine.throughput_probe(batch=4, steps=8)
    print(f"steady-state decode probe (batch 4): {tps:.1f} tok/s")
    print(f"chunked-prefill probe (64-tok prompt): "
          f"{engine.prefill_probe(64):.0f} tok/s")

    # speculative decoding: the paper's coarse propagator (every cf-th
    # layer, ODE step rescaled by cf) drafts k tokens per wave from the
    # SAME weights; one full-model call verifies them. Greedy output is
    # bitwise identical to plain decode — only the wave count shrinks.
    seng = ServeEngine(rcfg, params, max_len=64, max_batch=4, page_size=8,
                       spec=SpecConfig(cf=2, k=4))
    greedy = Request(prompt=np.concatenate(
        [system, np.array([13, 5], np.int32)]), max_new_tokens=12)
    (sout,) = seng.generate([greedy])
    st = seng.stats
    print(f"spec decode (cf=2, k=4, "
          f"{seng.scheduler.spec.n_coarse} coarse layers): "
          f"{list(map(int, sout.output))}")
    print(f"  {st['tokens_accepted']}/{st['tokens_drafted']} drafts "
          f"accepted ({100 * st['accept_rate']:.0f}%) -> "
          f"{st['decode_tokens']} tokens in {st['verify_calls']} verify "
          f"waves instead of {st['decode_tokens']} serial steps")


if __name__ == "__main__":
    main()
